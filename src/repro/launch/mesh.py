"""Production mesh construction.

``make_production_mesh()`` is a FUNCTION (importing this module never touches
jax device state). Single-pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
only data-parallel gradient reduction (DCI-friendly), ``model`` stays inside
a pod's ICI domain.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    dev = jax.devices()
    n = len(dev)
    return jax.make_mesh((1, n), ("data", "model"))


# TPU v5e hardware constants (roofline targets; see launch/roofline.py)
PEAK_BF16_FLOPS = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~4 links usable)
