"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST set XLA_FLAGS before any jax import (jax locks the device count on
first init): 512 host-platform devices emulate 2 pods x 256 chips.
"""

# --- these two lines must run before ANY other import --------------------
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))
# --------------------------------------------------------------------------

import argparse
import gc
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, applicable_shapes, get_config, ARCH_IDS
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.sharding.rules import make_plan
from repro.train.optimizer import OptimizerConfig
from repro.train.steps import (StepConfig, init_caches, init_train_state,
                               make_decode_step, make_prefill_step,
                               make_train_step)

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "reports")


def input_specs(cfg: ModelConfig, shape: ShapeSpec,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b = shape.global_batch
    l = 1 if shape.is_decode else shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, l), jnp.int32),
    }
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, l), jnp.int32)
    if cfg.modality in ("audio", "vision") and not shape.is_decode:
        specs["frontend"] = jax.ShapeDtypeStruct(
            (b, cfg.frontend_seq, cfg.d_model), dtype)
    return specs


def _shape_tree(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _sanitize(mesh, spec: P, shape: tuple[int, ...]) -> NamedSharding:
    """Drop mesh axes from any dim they do not evenly divide (decode steps
    have degenerate length-1 axes, batch=1 long-context cells, etc.)."""
    dims = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, size in zip(dims, shape):
        if d is None:
            out.append(None)
            continue
        axes = d if isinstance(d, tuple) else (d,)
        keep = []
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if size % n == 0:
            keep = list(axes)
        else:
            # try a prefix of the axis tuple
            n = 1
            for a in axes:
                if size % (n * mesh.shape[a]) == 0:
                    keep.append(a)
                    n *= mesh.shape[a]
                else:
                    break
        out.append(tuple(keep) if len(keep) > 1 else
                   (keep[0] if keep else None))
    return NamedSharding(mesh, P(*out))


def _with_sharding(tree_specs, shardings):
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_specs, shardings)


COLLECTIVE_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
DTYPE_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "f64": 8, "s32": 4, "u32": 4,
               "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f8e4m3": 1,
               "f8e5m2": 1, "s16": 2, "u16": 2, "c64": 8, "c128": 16}


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum result-shape bytes of every collective op in the HLO."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes_blob, kind = m.group(1), m.group(2)
        total = 0
        for sm in SHAPE_RE.finditer(shapes_blob):
            dt, dims = sm.group(1), sm.group(2)
            n = 1
            for d in dims.split(","):
                if d.strip():
                    n *= int(d)
            total += n * DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0.0) + total
    return out


def _cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               step_overrides: dict | None = None,
               plan_overrides: dict | None = None,
               cfg_transform=None) -> dict:
    """Lower + compile one cell; returns the roofline-input record.

    Three compiles: the production artifact (scan-over-layers: small HLO,
    exact memory analysis) plus two reduced-depth fully-unrolled compiles
    (exact flops/bytes/collectives at 1 and 2 layer-units) from which the
    full-depth costs extrapolate linearly — XLA's cost model counts a
    while-loop body once regardless of trip count, so rolled-scan costs
    alone would undercount depth.
    """
    import dataclasses as _dc

    from repro.models import transformer as _tf

    t0 = time.monotonic()
    cfg = get_config(arch_id)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    shape = SHAPES[shape_name]
    app = applicable_shapes(cfg)
    if app[shape_name] is None:
        return {"arch": arch_id, "shape": shape_name,
                "multi_pod": multi_pod, "status": "skipped",
                "reason": "quadratic attention at 512k seq "
                          "(assignment rule)"}
    mesh = make_production_mesh(multi_pod=multi_pod)

    def one_compile(c: ModelConfig, unroll):
        _tf.SCAN_UNROLL = unroll
        try:
            return _lower_one(c, shape, mesh, step_overrides,
                              plan_overrides)
        finally:
            _tf.SCAN_UNROLL = 1

    # cost slope from two small exact compiles
    unit = cfg.attn_every if cfg.family == "hybrid" else 1
    if cfg.family == "encdec":
        c1 = _dc.replace(cfg, encoder_layers=1, n_layers=1)
        c2 = _dc.replace(cfg, encoder_layers=2, n_layers=2)
        n_units = float(cfg.n_layers)   # enc and dec depths are equal (24)
    else:
        c1 = _dc.replace(cfg, n_layers=unit)
        c2 = _dc.replace(cfg, n_layers=2 * unit)
        n_units = cfg.n_layers / unit
    f1 = one_compile(c1, True)
    f2 = one_compile(c2, True)

    def extrap(a, b):
        # clamp: one-time (depth-independent) costs can make f2 < f1 for a
        # given collective kind; never extrapolate below the measured floor
        return max(a + (n_units - 1.0) * (b - a), min(a, b), 0.0)

    flops = extrap(f1["flops"], f2["flops"])
    mem_bytes = extrap(f1["bytes"], f2["bytes"])
    coll = {k: extrap(f1["coll"].get(k, 0.0), f2["coll"].get(k, 0.0))
            for k in set(f1["coll"]) | set(f2["coll"])}

    # production artifact: full depth, rolled scans, exact memory analysis
    full = one_compile(cfg, 1)

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "multi_pod": multi_pod,
        "status": "ok",
        "mesh": dict(zip(mesh.axis_names,
                         [int(s) for s in mesh.devices.shape])),
        "flops_per_device": flops,
        "bytes_per_device": mem_bytes,
        "collective_bytes_per_device": coll,
        "flops_rolled_module": full["flops"],
        "memory": full["memory"],
        "seconds": round(time.monotonic() - t0, 1),
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "cost_extrapolation": {"unit_layers": unit, "n_units": n_units,
                               "f1": f1["flops"], "f2": f2["flops"]},
    }
    gc.collect()
    return rec


def _lower_one(cfg: ModelConfig, shape: ShapeSpec, mesh,
               step_overrides: dict | None,
               plan_overrides: dict | None) -> dict:
    plan = make_plan(mesh, cfg, shape)
    if plan_overrides:
        for k, v in plan_overrides.items():
            object.__setattr__(plan, k, v)
    step_cfg = StepConfig(**{"remat": True, "microbatches": 1,
                             **(step_overrides or {})})
    shard = plan.shard_fn()

    # parameter / state shape trees (eval_shape: zero allocation)
    rng = jax.random.PRNGKey(0)
    with mesh:
        if shape.kind == "train":
            state_shapes = jax.eval_shape(
                lambda k: init_train_state(k, cfg, step_cfg), rng)
            state_sh = plan.params_shardings(state_shapes)
            batch_specs = input_specs(cfg, shape)
            batch_sh = {k: _sanitize(mesh, plan.batch_spec(), v.shape)
                        if v.ndim >= 2 else NamedSharding(mesh, P())
                        for k, v in batch_specs.items()}
            step = make_train_step(cfg, OptimizerConfig(), step_cfg, shard)
            jitted = jax.jit(step,
                             in_shardings=(state_sh, batch_sh),
                             donate_argnums=(0,))
            args = (_with_sharding(state_shapes, state_sh),
                    {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=batch_sh[k])
                     for k, v in batch_specs.items()})
        elif shape.kind == "prefill":
            params_shapes = jax.eval_shape(
                lambda k: init_train_state(k, cfg, step_cfg).params, rng)
            params_sh = plan.params_shardings(params_shapes)
            batch_specs = input_specs(cfg, shape)
            batch_sh = {k: _sanitize(mesh, plan.batch_spec(), v.shape)
                        if v.ndim >= 2 else NamedSharding(mesh, P())
                        for k, v in batch_specs.items()}
            step = make_prefill_step(cfg, step_cfg, shard)
            # pin output cache shardings (otherwise XLA replicates the KV
            # cache across the model axis for non-TP'able kv head counts)
            out_shapes = jax.eval_shape(
                step, params_shapes,
                {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in batch_specs.items()})
            logits_sh = _sanitize(mesh, plan.batch_spec(),
                                  out_shapes[0].shape)
            cache_out_sh = _cache_shardings(plan, out_shapes[1])
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh),
                             out_shardings=(logits_sh, cache_out_sh))
            args = (_with_sharding(params_shapes, params_sh),
                    {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=batch_sh[k])
                     for k, v in batch_specs.items()})
        else:  # decode
            params_shapes = jax.eval_shape(
                lambda k: init_train_state(k, cfg, step_cfg).params, rng)
            params_sh = plan.params_shardings(params_shapes)
            cache_shapes = jax.eval_shape(
                lambda: init_caches(cfg, shape.global_batch, shape.seq_len))
            cache_sh = _cache_shardings(plan, cache_shapes)
            batch_specs = input_specs(cfg, shape)
            batch_sh = {k: _sanitize(mesh, plan.batch_spec(), v.shape)
                        for k, v in batch_specs.items()}
            step = make_decode_step(cfg, step_cfg, shard)
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh,
                                                 cache_sh),
                             donate_argnums=(2,))
            args = (_with_sharding(params_shapes, params_sh),
                    {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                             sharding=batch_sh[k])
                     for k, v in batch_specs.items()},
                    _with_sharding(cache_shapes, cache_sh))

        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        # collectives exist only in the post-SPMD-partitioning module
        coll = collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)

    out = {
        "flops": cost.get("flops", -1.0),
        "bytes": cost.get("bytes accessed", -1.0),
        "coll": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", -1),
            "output_bytes": getattr(mem, "output_size_in_bytes", -1),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", -1),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", -1),
        },
    }
    del compiled, lowered, jitted
    gc.collect()
    return out


def _cache_shardings(plan, cache_shapes):
    mesh = plan.mesh

    def spec_for(path, leaf):
        nd = leaf.ndim
        if nd == 5:
            # (L,B,S,KV,hd) KV caches are compute-dtype; (L,B,H,P,N) SSM
            # states accumulate in f32.
            kind = "ssm_h" if leaf.dtype == jnp.float32 else "kv"
        elif nd == 4:
            kind = "ssm_conv"
        elif nd == 2:
            kind = "kv_len"
        elif nd == 3:
            return NamedSharding(mesh, plan.batch_spec())
        else:
            return NamedSharding(mesh, P())
        spec = plan.cache_spec(kind)
        if len(spec) > nd:
            spec = P(*list(spec)[:nd])
        return _sanitize(mesh, spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch_id in archs:
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch_id}__{shape_name}__{'multi' if mp else 'single'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[skip cached] {tag}")
                    continue
                print(f"[lower] {tag} ...", flush=True)
                try:
                    rec = lower_cell(arch_id, shape_name, multi_pod=mp)
                except Exception as e:
                    rec = {"arch": arch_id, "shape": shape_name,
                           "multi_pod": mp, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    failures += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"   -> {rec['status']} "
                      f"({rec.get('seconds', '-')}s)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
