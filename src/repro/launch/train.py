"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b \
        --steps 200 --reduced --batch 8 --seq 64

Wires together: config registry -> model init (sharded) -> deterministic
data pipeline -> train_step (pjit) -> checkpoint manager (+restart) ->
heartbeat/straggler policies. On this CPU container use --reduced; on real
hardware the full config + production mesh apply unchanged.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerPolicy
from repro.sharding.rules import make_plan
from repro.configs.base import ShapeSpec
from repro.train.optimizer import OptimizerConfig
from repro.train.steps import StepConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_production_mesh() if args.production_mesh \
        else make_host_mesh()
    plan = make_plan(mesh, cfg, shape)
    # minicpm trains with the WSD schedule (its paper's contribution)
    schedule = "wsd" if args.arch.startswith("minicpm") else "cosine"
    opt_cfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 20,
                                                           5),
                              total_steps=args.steps, schedule=schedule)
    step_cfg = StepConfig(microbatches=args.microbatches, remat=True,
                          compute_dtype=jnp.float32 if args.reduced
                          else jnp.bfloat16)

    data = SyntheticLMData(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch,
        frontend_seq=cfg.frontend_seq if cfg.modality != "text" else 0,
        d_model=cfg.d_model))

    with mesh:
        state = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
        state_sh = plan.params_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         state))
        state = jax.tree.map(
            lambda x, sh: jax.device_put(x, sh), state, state_sh)
        step = jax.jit(make_train_step(cfg, opt_cfg, step_cfg,
                                       plan.shard_fn()),
                       donate_argnums=(0,))

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
            state, start_step, _ = ckpt.restore_or_init(state, state_sh)
            if start_step:
                print(f"[restore] resumed from step {start_step}")

        hb = HeartbeatMonitor(n_hosts=1)
        straggler = StragglerPolicy()
        bspec = NamedSharding(mesh, plan.batch_spec())
        losses = []
        for s in range(start_step, args.steps):
            t0 = time.monotonic()
            host_batch = data.batch(s)
            batch = {k: jax.device_put(jnp.asarray(v), bspec if
                                       np.asarray(v).ndim >= 2 else None)
                     for k, v in host_batch.items()}
            state, metrics = step(state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.monotonic() - t0
            hb.beat(0)
            straggler.record(0, dt)
            if s % args.log_every == 0 or s == args.steps - 1:
                print(f"step {s:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"{dt*1e3:.0f}ms", flush=True)
            if ckpt:
                ckpt.maybe_save(s, state, {"loss": loss})
        print(f"[done] first-10 mean loss {np.mean(losses[:10]):.4f} -> "
              f"last-10 mean loss {np.mean(losses[-10:]):.4f}")
        return losses


if __name__ == "__main__":
    main()
