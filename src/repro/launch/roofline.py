"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch × shape × mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / PEAK_BF16_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW_EFFECTIVE

plus MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE) and the useful-compute
ratio MODEL_FLOPS / HLO_FLOPS (catches remat/padding/replication waste).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_BF16_FLOPS

ICI_LINKS = 4  # usable ICI links per chip on a v5e 2D torus (bidirectional)


def model_flops(arch_id: str, shape_name: str) -> float:
    """Useful model FLOPs per step: 6·N_active·D (train) / 2·N_active·D
    (inference) for parameter matmuls, plus the sequence-mixer terms the
    6ND convention omits — causal-half attention score/value matmuls
    (2·B·L²·H·hd fwd) and SSD intra-chunk matmuls. 'Useful' credits only
    the causal half; full-L² HLO compute shows up as waste in
    useful_compute_ratio (motivating the flash kernel path)."""
    cfg = get_config(arch_id)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    b = shape.global_batch
    l = shape.seq_len
    tokens = b * (1 if shape.is_decode else l)
    train = shape.kind == "train"
    fb = 3.0 if train else 1.0           # fwd(+2x bwd)
    total = (6.0 if train else 2.0) * n_active * tokens
    hd = cfg.resolved_head_dim
    # attention mixer
    n_attn = 0
    if cfg.family in ("dense", "moe", "vlm"):
        n_attn = cfg.n_layers
    elif cfg.family == "encdec":
        n_attn = cfg.n_layers + cfg.encoder_layers
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
    if n_attn and cfg.n_heads:
        if shape.is_decode:
            total += fb * 4.0 * b * l * cfg.n_heads * hd * n_attn
        else:
            total += fb * 2.0 * b * l * l * cfg.n_heads * hd * n_attn
    # SSD mixer (intra-chunk scores + value matmuls, chunk=256)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
        d_inner = cfg.ssm_expand * cfg.d_model
        n_h = d_inner // cfg.ssm_head_dim
        chunk = 256
        per_tok = 2.0 * chunk * n_h * (cfg.ssm_state + cfg.ssm_head_dim)
        if not shape.is_decode:
            total += fb * b * l * per_tok * cfg.n_layers
        else:
            total += fb * 2.0 * b * n_h * cfg.ssm_head_dim * \
                cfg.ssm_state * cfg.n_layers
    return total


def roofline_terms(rec: dict) -> dict:
    """rec: one dry-run JSON record (per-device quantities)."""
    if rec.get("status") != "ok":
        return {"status": rec.get("status", "missing"),
                "reason": rec.get("reason", rec.get("error", ""))}
    n_dev = 1
    for v in rec["mesh"].values():
        n_dev *= v
    flops = float(rec["flops_per_device"])
    mem_bytes = float(rec["bytes_per_device"])
    coll = rec.get("collective_bytes_per_device", {})
    # legacy records may hold negative per-kind extrapolations (one-time
    # collectives); clamp at zero
    coll = {k: max(v, 0.0) for k, v in coll.items()}
    coll_bytes = float(sum(coll.values()))
    t_compute = flops / PEAK_BF16_FLOPS
    t_memory = mem_bytes / HBM_BW
    t_coll = coll_bytes / (ICI_BW * ICI_LINKS)
    mflops = model_flops(rec["arch"], rec["shape"]) / n_dev
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)
    return {
        "status": "ok",
        "n_devices": n_dev,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_device": mflops,
        "useful_compute_ratio": mflops / flops if flops > 0 else 0.0,
        "roofline_fraction": (mflops / PEAK_BF16_FLOPS) / bound
        if bound > 0 else 0.0,
        # CPU-backend memory_analysis: argument bytes are per-device, temp
        # bytes are summed across the module's devices (measured: see
        # DESIGN.md §Decisions) — divide temps by device count.
        "hbm_gb_per_device": (
            max(rec["memory"]["argument_bytes"], 0) +
            max(rec["memory"]["temp_bytes"], 0) / n_dev) / 1e9,
        "collective_breakdown": coll,
    }


def load_reports(report_dir: str) -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def format_table(report_dir: str, multi_pod: bool = False) -> str:
    rows = []
    hdr = (f"| arch | shape | t_comp(ms) | t_mem(ms) | t_coll(ms) | "
           f"dominant | useful | roofline-frac | HBM GB/dev |")
    sep = "|" + "---|" * 9
    rows += [hdr, sep]
    for rec in load_reports(report_dir):
        if rec.get("multi_pod") != multi_pod:
            continue
        t = roofline_terms(rec)
        if t["status"] != "ok":
            rows.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | "
                        f"{t['status']}: {t.get('reason','')[:40]} | - | - "
                        f"| - |")
            continue
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{t['t_compute_s']*1e3:.2f} | {t['t_memory_s']*1e3:.2f} | "
            f"{t['t_collective_s']*1e3:.2f} | {t['dominant']} | "
            f"{t['useful_compute_ratio']:.2f} | "
            f"{t['roofline_fraction']:.3f} | "
            f"{t['hbm_gb_per_device']:.2f} |")
    return "\n".join(rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--reports", default="reports/dryrun")
    ap.add_argument("--multi", action="store_true")
    a = ap.parse_args()
    print(format_table(a.reports, a.multi))
