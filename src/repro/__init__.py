"""repro: MIREDO (MIP-driven CIM dataflow optimization) as a JAX framework."""

__version__ = "1.0.0"
