"""Gradient compression for the cross-pod hop: int8 quantization with
error feedback (residual carried across steps). Used when the mesh has a
``pod`` axis — DCI bandwidth is the scarce resource at 1000+ nodes.

The compression is simulated faithfully in-graph (quantize -> dequantize ->
all-reduce semantics under shardings); the error-feedback state is part of
the training state and checkpoints with it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), keepdims=False)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads_with_feedback(grads, residuals):
    """Returns (decompressed grads as would arrive post-allreduce,
    new residuals). Error feedback: residual = g - Q(g + r)."""
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = quantize_int8(g32)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), (g32 - deq)
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), \
        treedef.unflatten([o[1] for o in out])


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
