"""Fault tolerance & elasticity runtime (1000+-node posture).

What runs *on this container* is the control-plane logic, driven by the
training driver (launch/train.py) and exercised by tests with simulated
failures; the data plane (actual re-slicing) is jax shardings + the
mesh-agnostic checkpoint layer:

  * ``HeartbeatMonitor`` — per-host liveness with deadline-based failure
    detection; on failure the driver triggers restore-from-checkpoint with
    the surviving mesh (elastic re-mesh), because checkpoints are saved in
    logical layout (see repro/checkpoint).
  * ``StragglerPolicy`` — per-step wall-time tracker; hosts slower than
    ``threshold x`` rolling median for ``patience`` consecutive steps are
    reported for eviction/replacement (the standard large-fleet mitigation;
    synchronous SPMD cannot skip a straggler's shard, so the action is
    evict-and-resize, not skip).
  * ``ElasticPlan`` — given the surviving device count, choose the largest
    feasible (data, model) mesh consistent with the arch's divisibility
    constraints, and recompute per-host batch shards.
  * ``RetryPolicy`` — bounded exponential backoff for transient infra
    errors (preemptions, DCN timeouts).
"""

from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class HeartbeatMonitor:
    n_hosts: int
    deadline_s: float = 60.0
    _last: dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: float | None = None) -> None:
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: float | None = None) -> list[int]:
        t = time.monotonic() if now is None else now
        return [h for h in range(self.n_hosts)
                if t - self._last.get(h, t) > self.deadline_s]

    def all_alive(self, now: float | None = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5        # x rolling median
    patience: int = 3
    window: int = 32
    _times: dict[int, list[float]] = dataclasses.field(default_factory=dict)
    _strikes: dict[int, int] = dataclasses.field(default_factory=dict)

    def record(self, host: int, step_seconds: float) -> None:
        self._times.setdefault(host, []).append(step_seconds)
        self._times[host] = self._times[host][-self.window:]

    def _median_all(self) -> float:
        xs = sorted(t for ts in self._times.values() for t in ts)
        return xs[len(xs) // 2] if xs else 0.0

    def evictions(self) -> list[int]:
        """Hosts whose last ``patience`` recorded steps all exceed
        threshold x fleet median."""
        med = self._median_all()
        if med <= 0:
            return []
        out = []
        for host, ts in sorted(self._times.items()):
            if len(ts) >= self.patience and \
                    all(t > self.threshold * med
                        for t in ts[-self.patience:]):
                out.append(host)
        return out


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    pod: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pod


def plan_elastic_mesh(devices_alive: int, *, model_axis: int = 16,
                      min_data: int = 1) -> ElasticPlan:
    """Largest (data, model) mesh that fits the surviving devices, keeping
    the model axis intact (TP degree is baked into layer shapes; shrinking
    it requires a re-shard, which the checkpoint layer supports but costs a
    full re-layout — prefer shrinking data)."""
    if devices_alive < model_axis * min_data:
        # degrade TP as a last resort, by powers of two
        m = model_axis
        while m > 1 and devices_alive < m:
            m //= 2
        return ElasticPlan(data=max(devices_alive // m, 1), model=m)
    return ElasticPlan(data=devices_alive // model_axis, model=model_axis)


@dataclasses.dataclass
class RetryPolicy:
    max_retries: int = 6
    base_s: float = 2.0
    cap_s: float = 120.0

    def delays(self):
        d = self.base_s
        for _ in range(self.max_retries):
            yield min(d, self.cap_s)
            d *= 2
