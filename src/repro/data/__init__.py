from repro.data.pipeline import DataConfig, SyntheticLMData
