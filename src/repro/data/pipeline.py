"""Deterministic synthetic LM data pipeline.

Design mirrors a production host-sharded loader:
  * step-indexed determinism — batch(step) is a pure function of
    (seed, step), so restarts and elastic re-meshes replay identically with
    no data loss or duplication (the checkpoint stores only the step),
  * per-host sharding — each host materializes only its slice
    (host_id, n_hosts), then forms a globally-sharded array via
    ``jax.make_array_from_process_local_data`` on real multi-host systems
    (single-host fallback: device_put with the batch sharding),
  * structured stream — a deterministic Markov-ish token stream rather than
    iid noise, so training loss measurably decreases (examples/train_lm.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_seq: int = 0
    d_model: int = 0


class SyntheticLMData:
    """Deterministic, restartable synthetic token stream."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.host_id)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Markov stream: next token = (a*prev + noise) % V; learnable."""
        cfg = self.cfg
        rng = self._rng(step)
        b, l, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        start = rng.integers(0, v, size=(b, 1))
        mult = 31
        noise = rng.integers(0, 7, size=(b, l))
        toks = np.zeros((b, l + 1), np.int64)
        toks[:, 0] = start[:, 0]
        for t in range(l):
            toks[:, t + 1] = (mult * toks[:, t] + noise[:, t]) % v
        out = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        if cfg.frontend_seq:
            out["frontend"] = rng.standard_normal(
                (b, cfg.frontend_seq, cfg.d_model)).astype(np.float32)
        return out

    def global_batch_shape(self) -> dict[str, tuple]:
        cfg = self.cfg
        shapes = {
            "tokens": (cfg.global_batch, cfg.seq_len),
            "labels": (cfg.global_batch, cfg.seq_len),
        }
        if cfg.frontend_seq:
            shapes["frontend"] = (cfg.global_batch, cfg.frontend_seq,
                                  cfg.d_model)
        return shapes
