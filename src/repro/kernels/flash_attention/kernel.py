"""Causal flash attention Pallas kernel (online-softmax, VMEM-tiled).

Grid (batch*heads, Lq/block_q); each step streams K/V blocks up to the
causal frontier with running (max, sum, acc) in VMEM scratch. Block sizes
are MXU/VPU aligned (multiples of 128 lanes); the MIREDO TPU bridge checks
the VMEM working set (q + k + v + acc blocks, x2 for pipelining) against
capacity — eq. (9) with psi^DM = 1.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, seq_k: int, sm_scale: float,
                  causal: bool):
    qi = pl.program_id(1)
    kv_step = pl.program_id(2)

    @pl.when(kv_step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kv_step * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def attend():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale
        if causal:
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip fully-masked KV blocks beyond the causal frontier
        first_masked = (qi + 1) * block_q  # k positions >= this are masked
        pl.when(kv_step * block_k < first_masked)(attend)
    else:
        attend()

    @pl.when(kv_step == (seq_k // block_k) - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal",
                                             "interpret"))
def flash_attention_bh(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       block_q: int = 256, block_k: int = 256,
                       causal: bool = True,
                       interpret: bool = True) -> jax.Array:
    """q, k, v: (BH, L, hd) -> (BH, L, hd)."""
    bh, lq, hd = q.shape
    lk = k.shape[1]
    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    assert lq % block_q == 0 and lk % block_k == 0
    sm_scale = 1.0 / math.sqrt(hd)
    grid = (bh, lq // block_q, lk // block_k)
    return pl.pallas_call(
        functools.partial(_flash_kernel, block_q=block_q, block_k=block_k,
                          seq_k=lk, sm_scale=sm_scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, s: (b, s, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, s: (b, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, s: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
