"""Pure-jnp oracle for flash attention."""

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal=True):
    """q,k,v: (B, L, H, hd) -> (B, L, H, hd)."""
    hd = q.shape[-1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        lq, lk = q.shape[1], k.shape[1]
        mask = jnp.arange(lk)[None, :] <= jnp.arange(lq)[:, None]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
