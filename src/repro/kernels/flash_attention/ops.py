"""Public flash-attention op over (B, L, H, hd) layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


def legal_block(l: int, requested: int) -> int:
    """Largest block <= ``requested`` that tiles a length-``l`` sequence
    exactly, preferring sublane (8) multiples. Real sequence lengths are
    not always 128-multiples (e.g. VLM prefill = text + patch tokens), and
    the Pallas grid needs exact tiling — so bridge/default picks are
    clamped to a divisor instead of failing the kernel's assert."""
    divs = [b for b in range(1, min(requested, l) + 1) if l % b == 0]
    aligned = [b for b in divs if b % 8 == 0]
    return max(aligned or divs)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True) -> jax.Array:
    """q, k, v: (B, L, H, hd) with H already GQA-expanded. Block sizes are
    clamped to exact divisors of L (`legal_block`)."""
    b, l, h, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, t.shape[1], hd)
    out = flash_attention_bh(fold(q), fold(k), fold(v), causal=causal,
                             block_q=legal_block(l, block_q),
                             block_k=legal_block(k.shape[1], block_k),
                             interpret=interpret)
    return out.reshape(b, h, l, hd).transpose(0, 2, 1, 3)
