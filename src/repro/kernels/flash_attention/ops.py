"""Public flash-attention op over (B, L, H, hd) layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bh


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 256,
                    block_k: int = 256, interpret: bool = True) -> jax.Array:
    """q, k, v: (B, L, H, hd) with H already GQA-expanded."""
    b, l, h, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, t.shape[1], hd)
    out = flash_attention_bh(fold(q), fold(k), fold(v), causal=causal,
                             block_q=block_q, block_k=block_k,
                             interpret=interpret)
    return out.reshape(b, h, l, hd).transpose(0, 2, 1, 3)
