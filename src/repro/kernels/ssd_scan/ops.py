"""Public op: SSD intra-chunk over the (B, NC, Q, H, ...) layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_intra_chunk_bh


def ssd_intra_chunk(c: jax.Array, b: jax.Array, s: jax.Array,
                    dt: jax.Array, x: jax.Array, *,
                    interpret: bool = True) -> jax.Array:
    """c, b: (B,NC,Q,H,N); s, dt: (B,NC,Q,H); x: (B,NC,Q,H,P)."""
    bsz, nc, q, h, n = c.shape
    p = x.shape[-1]
    f5 = lambda t: t.transpose(0, 1, 3, 2, 4).reshape(bsz * nc * h, q,
                                                      t.shape[-1])
    f4 = lambda t: t.transpose(0, 1, 3, 2).reshape(bsz * nc * h, q)
    y = ssd_intra_chunk_bh(f5(c), f5(b), f4(s), f4(dt), f5(x),
                           interpret=interpret)
    return y.reshape(bsz, nc, h, q, p).transpose(0, 1, 3, 2, 4)


def ssd_intra_chunk_and_ref(c: jax.Array, b: jax.Array, s: jax.Array,
                            dt: jax.Array, x: jax.Array, *,
                            interpret: bool = True
                            ) -> tuple[jax.Array, jax.Array]:
    """Kernel and pure-jnp oracle on identical inputs — the executor's
    per-invocation numerics check (`core/executor.py`). Returns
    ``(kernel, ref)``."""
    from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref
    return (ssd_intra_chunk(c, b, s, dt, x, interpret=interpret),
            ssd_intra_chunk_ref(c, b, s, dt, x))
