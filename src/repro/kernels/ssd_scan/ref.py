"""Pure-jnp oracle for the SSD intra-chunk kernel (and the sequential
recurrence oracle used to validate the whole chunked algorithm)."""

import jax
import jax.numpy as jnp


def ssd_intra_chunk_ref(c, b, s, dt, x):
    """c,b: (B,NC,Q,H,N); s,dt: (B,NC,Q,H); x: (B,NC,Q,H,P)."""
    seg = s[:, :, :, None, :] - s[:, :, None, :, :]        # (B,NC,Q,Q,H)
    q = s.shape[2]
    tri = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(tri[None, None, :, :, None],
                      jnp.exp(jnp.maximum(seg, -60.0)), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", c.astype(jnp.float32),
                        b.astype(jnp.float32))
    scores = scores * decay * dt[:, :, None, :, :]
    return jnp.einsum("bcqkh,bckhp->bcqhp", scores,
                      x.astype(jnp.float32)).astype(x.dtype)


def ssd_sequential_ref(x, dt, a, b, c, d_skip):
    """Step-by-step recurrence oracle for the full SSD layer.
    x: (B,L,H,P); dt: (B,L,H); a: (H,); b,c: (B,L,G,N)."""
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    rep = h // g
    bb = jnp.repeat(b, rep, axis=2)
    cc = jnp.repeat(c, rep, axis=2)

    def step(hstate, t):
        xt, dtt, bt, ct = t
        dec = jnp.exp(dtt * a)                             # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhpn", dtt, bt, xt)
        hstate = hstate * dec[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", hstate, ct)
        return hstate, y

    h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(bb.astype(jnp.float32), 1, 0),
          jnp.moveaxis(cc.astype(jnp.float32), 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x.astype(jnp.float32) * \
        d_skip[None, None, :, None]
    return y.astype(x.dtype), h_final
