"""SSD intra-chunk Pallas kernel (Mamba2 state-space duality).

Computes, per (batch-chunk, head) grid cell:
    y[t] = Σ_{τ<=t} (C_t·B_τ) · exp(s_t − s_τ) · dt_τ · x_τ

Fusion win vs the jnp reference: the (Q, Q) decay matrix is built inside
VMEM from the (Q,) cumsum vector instead of materializing a
(B, NC, Q, Q, H) tensor in HBM — the dominant memory term of the SSD
prefill path at 32k+ sequence lengths.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_CLIP = -60.0   # exp(-60) == 0 in f32; avoids inf-inf NaNs


def _ssd_kernel(c_ref, b_ref, s_ref, dt_ref, x_ref, y_ref):
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    s = s_ref[0].astype(jnp.float32)          # (Q,)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    q = c.shape[0]
    seg = s[:, None] - s[None, :]             # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.exp(jnp.where(tri, jnp.maximum(seg, NEG_CLIP), NEG_CLIP))
    decay = jnp.where(tri, decay, 0.0)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    scores = scores * decay * dt[None, :]
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk_bh(c: jax.Array, b: jax.Array, s: jax.Array,
                       dt: jax.Array, x: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """c, b: (BCH, Q, N); s, dt: (BCH, Q); x: (BCH, Q, P) -> (BCH, Q, P).
    BCH = batch * n_chunks * heads (flattened grid)."""
    bch, qq, n = c.shape
    p = x.shape[-1]
    grid = (bch,)
    return pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qq, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, qq, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, qq), lambda i: (i, 0)),
            pl.BlockSpec((1, qq), lambda i: (i, 0)),
            pl.BlockSpec((1, qq, p), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qq, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bch, qq, p), x.dtype),
        interpret=interpret,
    )(c, b, s, dt, x)
