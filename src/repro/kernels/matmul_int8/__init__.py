from repro.kernels.matmul_int8.ops import quantized_matmul
