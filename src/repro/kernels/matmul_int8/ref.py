"""Pure-jnp oracle for the INT8 matmul kernel."""

import jax.numpy as jnp


def matmul_int8_ref(x_q, w_q, x_scale, w_scale, out_dtype=jnp.bfloat16):
    acc = jnp.dot(x_q.astype(jnp.int32), w_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    out = acc.astype(jnp.float32) * x_scale.astype(jnp.float32)[:, None] * \
        w_scale.astype(jnp.float32)[None, :]
    return out.astype(out_dtype)


def quantize_rowwise(x, axis=-1):
    """Symmetric per-row INT8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.squeeze(axis)
