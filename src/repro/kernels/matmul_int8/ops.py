"""Public op: quantize-and-matmul with MIREDO-selected block shapes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.matmul_int8.kernel import matmul_int8
from repro.kernels.matmul_int8.ref import matmul_int8_ref, quantize_rowwise


def quantized_matmul(x: jax.Array, w: jax.Array, *,
                     block_shapes: tuple[int, int, int] | None = None,
                     use_kernel: bool = True, interpret: bool = True,
                     out_dtype=jnp.bfloat16) -> jax.Array:
    """bf16/f32 (M,K) @ (K,N) via INT8 quantization (CIM-style W8A8).

    ``block_shapes`` come from the MIREDO TPU bridge
    (core/tpu_bridge.py:select_matmul_blocks); defaults are MXU-aligned.
    ``interpret=True`` executes the Pallas kernel in Python on CPU (this
    container has no TPU); on real hardware pass interpret=False.
    """
    m, k = x.shape
    _, n = w.shape
    x_q, x_s = quantize_rowwise(x, axis=1)
    w_q, w_s = quantize_rowwise(w, axis=0)
    if not use_kernel:
        return matmul_int8_ref(x_q, w_q, x_s, w_s, out_dtype)
    bm, bk, bn = block_shapes or default_blocks(m, k, n)
    # The bridge may return MXU-aligned blocks that do not divide the dims
    # (dims without an aligned divisor are padded up): zero-pad the
    # quantized operands to block multiples — padded K contributes 0 to the
    # int32 accumulator, padded M/N rows/cols are sliced off the output.
    mp, kp, np_ = (-(-d // b) * b for d, b in ((m, bm), (k, bk), (n, bn)))
    if (mp, kp, np_) != (m, k, n):
        x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
        w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
        x_s = jnp.pad(x_s, (0, mp - m))
        w_s = jnp.pad(w_s, (0, np_ - n))
    out = matmul_int8(x_q, w_q, x_s, w_s, bm=bm, bk=bk, bn=bn,
                      out_dtype=out_dtype, interpret=interpret)
    return out[:m, :n]


def quantized_matmul_and_ref(x: jax.Array, w: jax.Array, *,
                             block_shapes: tuple[int, int, int] | None = None,
                             interpret: bool = True,
                             out_dtype=jnp.float32
                             ) -> tuple[jax.Array, jax.Array]:
    """Kernel and pure-jnp oracle on identical quantized operands.

    The measured-execution backend (`core/executor.py`) checks every kernel
    invocation against its ``ref.py``; both paths quantize the same way, so
    the int32 accumulations are bit-identical and only the final scale
    multiply can differ by float rounding. Returns ``(kernel, ref)``."""
    out = quantized_matmul(x, w, block_shapes=block_shapes, use_kernel=True,
                           interpret=interpret, out_dtype=out_dtype)
    ref = quantized_matmul(x, w, use_kernel=False, out_dtype=out_dtype)
    return out, ref


def default_blocks(m: int, k: int, n: int) -> tuple[int, int, int]:
    def pick(d, pref):
        for b in (pref, 512, 256, 128, 64, 32, 16, 8):
            if d % b == 0 and b <= d:
                return b
        return d
    return pick(m, 256), pick(k, 512), pick(n, 256)
