"""INT8 quantized matmul Pallas kernel — the CIM MVM primitive, TPU-native.

CIM -> TPU adaptation (DESIGN.md §TPU bridge): the CIM macro holds an INT8 weight
tile and streams bit-serial inputs; on TPU the analogous structure is an
MXU-aligned weight block resident in VMEM while activation blocks stream
HBM->VMEM through Pallas' pipelined (double-buffered) BlockSpecs — the same
capacity/overlap trade-off MIREDO's psi^DM models (double-buffering halves
usable VMEM). Block shapes (bm, bk, bn) are selected by the MIREDO MIP via
core/tpu_bridge.py.

Grid (M/bm, N/bn, K/bk); INT8 x INT8 -> INT32 accumulation in a VMEM
scratch accumulator, dequantized on the final K step with per-channel
weight scales x per-row activation scales.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *,
                   n_k_steps: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_step == n_k_steps - 1)
    def _finish():
        scale = sx_ref[...].astype(jnp.float32)[:, None] * \
            sw_ref[...].astype(jnp.float32)[None, :]
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * scale).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "bn", "interpret",
                                             "out_dtype"))
def matmul_int8(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array, *, bm: int = 256, bk: int = 256,
                bn: int = 256, out_dtype=jnp.bfloat16,
                interpret: bool = False) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M,) f32;
    w_scale: (N,) f32 -> (M, N) out_dtype."""
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (m, k, n, bm, bk, bn)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bm,), lambda i, j, s: (i,)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
