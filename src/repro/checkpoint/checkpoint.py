"""Fault-tolerant checkpointing.

Properties required at 1000+-node scale, all implemented here:
  * atomicity — write to ``<dir>/tmp.<step>`` then ``os.replace`` to
    ``step_<n>``; a crash mid-save never corrupts the latest checkpoint,
  * mesh-agnostic restore — arrays are saved in logical (unsharded) layout
    with a manifest; on restore they are re-sharded onto whatever mesh the
    restarted job brings up (elastic scaling: 256 -> 512 chips works),
  * retention — keep the newest ``keep`` checkpoints, delete older,
  * self-describing — msgpack manifest with tree structure, dtypes, shapes,
    step, and data-pipeline cursor so the synthetic stream resumes exactly.

On a real multi-host system each host writes its addressable shards and the
restore path re-assembles per device; on this single-process container the
gather is trivial but flows through the same code path.
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, extra: dict | None
                    = None, keep: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"tmp.{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        manifest["leaves"].append(
            {"dtype": str(arr.dtype), "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _retain(directory, keep)
    return final


def _retain(directory: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def load_checkpoint(directory: str, tree_like, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``tree_like`` (shapes must match);
    ``shardings`` (same pytree) re-shards onto the current mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"leaf count mismatch: {manifest['n_leaves']} vs {len(leaves_like)}"
    out = []
    shard_leaves = (treedef.flatten_up_to(shardings)
                    if shardings is not None else [None] * len(leaves_like))
    for i, (like, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        want = np.asarray(like)
        assert tuple(arr.shape) == tuple(want.shape), \
            f"leaf {i}: {arr.shape} vs {want.shape}"
        x = jax.numpy.asarray(arr, dtype=want.dtype)
        if shd is not None:
            x = jax.device_put(x, shd)
        out.append(x)
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Step-driven orchestration: periodic saves + crash-safe resume."""

    def __init__(self, directory: str, every: int = 50, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, step: int, tree, extra: dict | None = None):
        if step % self.every == 0 and step > 0:
            return save_checkpoint(self.directory, step, tree, extra,
                                   self.keep)
        return None

    def restore_or_init(self, tree_init, shardings=None):
        step = latest_step(self.directory)
        if step is None:
            return tree_init, 0, {}
        return load_checkpoint(self.directory, tree_init, step, shardings)
