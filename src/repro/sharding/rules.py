"""Sharding rules: logical-axis -> mesh-axis resolution with divisibility
fallbacks (the production pattern: Megatron/MaxText-style logical rules, but
resolved per-architecture at mesh-build time).

Mesh axes:
  pod    (multi-pod only) — outermost data-parallel hop (DCI links)
  data   — FSDP: parameters/optimizer sharded, all-gathered per layer;
           batch (and long-sequence) dimension of activations
  model  — TP: attention heads / FFN hidden / vocab; EP: MoE experts

Strategy per tensor class (see DESIGN.md §Sharding rules):
  * dense kernels (d_in, d_out): P("data", "model") — FSDP x TP
  * attention projections: TP over heads when divisible, else fully-FSDP
    (P(("data","model"), None)) with replicated attention compute
  * MoE experts (E, d, f): EP P("model", "data", None) when E % model == 0,
    else TP inside experts P(None, "data", "model")
  * embeddings (V, d): P("model", "data") — vocab-sharded
  * activations (B, L, D): P(("pod","data"), None, None); batch=1
    long-context shards the sequence axis instead: P(None, ("pod","data"), None)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass
class ShardingPlan:
    mesh: Mesh
    cfg: ModelConfig
    shape: ShapeSpec
    data_axes: tuple[str, ...]      # ("pod","data") or ("data",)
    model_axis: str
    shard_seq: bool                 # batch too small -> shard sequence
    attn_tp: bool                   # heads divisible by model axis
    kv_tp: bool                     # kv heads divisible
    moe_ep: bool

    # ---- parameter specs ---------------------------------------------------
    def param_spec(self, path: tuple[str, ...], leaf: Any) -> P:
        """Spec for one parameter leaf. Layer-stacked subtrees (scan-over-
        layers: 'blocks', 'enc_blocks', 'tail') carry a leading layer axis
        that is never sharded — the logical rule applies to the remaining
        dims."""
        name = "/".join(str(p) for p in path)
        nd = leaf.ndim if hasattr(leaf, "ndim") else 0
        model = self.model_axis
        data = "data"
        stacked = any(seg in name for seg in ("blocks", "tail/"))
        if "tail" in name.split("/"):
            stacked = True
        if "shared_attn" in name:
            stacked = False
        end = nd - (1 if stacked else 0)   # effective (logical) rank

        def wrap(*spec_dims):
            return P(None, *spec_dims) if stacked else P(*spec_dims)

        if end <= 1:
            return P()
        # embeddings
        if "embed" in name and "table" in name:
            return P(model, data)
        # MoE expert banks (E, d_in, d_out)
        if ("experts" in name or "shared/" in name or
                name.endswith("shared")) and end == 3:
            if self.moe_ep and "experts" in name:
                return wrap(model, data, None)
            return wrap(None, data, model)
        if "router" in name:
            return wrap(data, None)
        # attention projections
        if any(k in name for k in ("wq", "wk", "wv")):
            tp_ok = self.attn_tp if "wq" in name else self.kv_tp
            return wrap(data, model) if tp_ok else wrap((data, model), None)
        if "wo" in name:
            return wrap(model, data) if self.attn_tp \
                else wrap((data, model), None)
        # MLP
        if any(k in name for k in ("up", "gate")) and end == 2:
            return wrap(data, model) if self._ff_tp() \
                else wrap((data, model), None)
        if "down" in name and end == 2:
            return wrap(model, data) if self._ff_tp() \
                else wrap((data, model), None)
        # SSM projections
        if "in_proj" in name:
            return wrap(data, None)     # split boundaries misalign with TP
        if "out_proj" in name:
            return wrap(model, data) if self._ssm_tp() \
                else wrap((data, model), None)
        if "conv_w" in name:
            return wrap(None, None)
        if end == 2:
            return wrap(data, None)
        return P()

    def _ff_tp(self) -> bool:
        ms = self.mesh.shape[self.model_axis]
        ff = self.cfg.moe_d_ff or self.cfg.d_ff
        return ff % ms == 0 if ff else False

    def _ssm_tp(self) -> bool:
        # shard the SSD head dimension (d_inner) across model axis
        ms = self.mesh.shape[self.model_axis]
        d_inner = self.cfg.ssm_expand * self.cfg.d_model
        n_heads = d_inner // max(self.cfg.ssm_head_dim, 1)
        return n_heads % ms == 0 if n_heads else False

    def params_shardings(self, params_shape) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda path, leaf: NamedSharding(
                self.mesh, self.param_spec(
                    tuple(getattr(p, "key", getattr(p, "idx", p))
                          for p in path), leaf)),
            params_shape)

    # ---- activation / batch specs ------------------------------------------
    def batch_spec(self) -> P:
        if self.shard_seq:
            return P(None, self.data_axes)
        return P(self.data_axes, None)

    def act_spec(self, logical: str) -> P:
        data = self.data_axes
        model = self.model_axis
        batch = None if self.shard_seq else data
        seq = data if self.shard_seq else None
        return {
            "hidden": P(batch, seq, None),
            "logits": P(batch, seq, model),
            "ffn_hidden": P(batch, seq, model) if self._ff_tp()
            else P(batch, seq, None),
            "attn_q": P(batch, seq, model if self.attn_tp else None, None),
            "attn_out": P(batch, seq, model if self.attn_tp else None, None),
            "moe_expert_in": P(model if self.moe_ep else None, None, None),
            "moe_expert_out": P(model if self.moe_ep else None, None, None),
            "ssm_x": P(batch, seq, model if self._ssm_tp() else None, None),
        }.get(logical, P())

    def shard_fn(self):
        def fn(logical: str, x):
            try:
                spec = self.act_spec(logical)
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(self.mesh, spec))
            except (ValueError, KeyError):
                return x
        return fn

    # ---- KV cache / SSM state specs -----------------------------------------
    def cache_spec(self, kind: str) -> P:
        data = self.data_axes
        model = self.model_axis
        batch = None if self.shard_seq else data
        seq = data if self.shard_seq else None
        if kind == "kv":           # (layers, B, S, KV, hd)
            if self.kv_tp:
                return P(None, batch, seq, model, None)
            # kv heads not divisible: shard the cache's sequence axis on the
            # model axis instead of replicating 16x (HBM capacity!)
            if seq is None:
                return P(None, batch, model, None, None)
            return P(None, batch, seq, None, None)
        if kind == "kv_len":       # (layers, B)
            return P(None, batch)
        if kind == "ssm_h":        # (layers, B, H, P, N)
            return P(None, batch, model if self._ssm_tp() else None,
                     None, None)
        if kind == "ssm_conv":     # (layers, B, K-1, conv_dim)
            return P(None, batch, None, model if self._ssm_tp() else None)
        return P()


#: Mesh shard-choice names (kept string-identical to `core/mesh.py`'s
#: constants; asserted in tests/test_mesh.py so they cannot drift).
m_REPLICATE = "replicate"
m_SPLIT_N = "split_n"
m_SPLIT_K = "split_k"


def mesh_tp_choices(n_chips: int, *, out_channels: int, reduce_dim: int,
                    n_heads: int | None = None,
                    n_experts: int | None = None) -> tuple[str, ...]:
    """Valid CIM-mesh shard choices for one canonical layer, under the same
    divisibility discipline `make_plan` applies per tensor class — the
    mesh path (`core/mesh.py`) resolves its per-layer TP choices here so
    the JAX-side rules and the analytical mesh model can never disagree
    on when TP engages.

    Returned names (preference order): ``replicate`` (always — the
    fully-FSDP / replicated-compute fallback analog, the layer whole on
    one chip), ``split_n`` (TP over output channels — attention heads for
    qkv/o projections, FFN hidden for MLPs; the `attn_tp` rule) and
    ``split_k`` (TP over the reduction dim with a partial-sum all-reduce).

    Fallback semantics, mirroring `make_plan`:
      * ``n_heads`` given and ``n_heads % n_chips != 0`` → the `attn_tp`
        rule fails, both splits are withheld (splitting inside a head
        misaligns attention compute — the rules replicate instead of
        raising), leaving ``("replicate",)``.
      * ``n_experts`` given and ``n_experts % n_chips == 0`` → expert
        parallelism: whole expert GEMMs distribute across chips as
        replicated instances (the mesh placement layer spreads the
        ``count=E`` instances), so no intra-GEMM split is offered.
      * ``n_experts`` given and ``E % n_chips != 0`` → the `moe_ep` rule
        fails and falls back to TP *inside* each expert (the
        ``P(None, "data", "model")`` branch): splits by plain
        divisibility, ``replicate`` when neither divides.

    Pure arithmetic — no jax objects — so the mesh path can resolve
    choices without building a device mesh."""
    choices = [m_REPLICATE]
    if n_chips <= 1:
        return tuple(choices)
    if n_heads is not None and (n_heads <= 0 or n_heads % n_chips != 0):
        return tuple(choices)
    if n_experts is not None and n_experts > 0 and \
            n_experts % n_chips == 0:
        return tuple(choices)
    if out_channels % n_chips == 0 and out_channels >= n_chips:
        choices.append(m_SPLIT_N)
    if reduce_dim % n_chips == 0 and reduce_dim >= n_chips:
        choices.append(m_SPLIT_K)
    return tuple(choices)


def mesh_grad_choices(n_chips: int, *, out_channels: int,
                      reduce_dim: int) -> tuple[str, ...]:
    """Valid CIM-mesh shard choices for one weight-grad GEMM
    (`workload.OP_WGRAD`, canonical dims N=K_fwd, K=N_fwd, C=M tokens) —
    the FSDP side of the rules, mirroring the ``data`` axis strategy
    `make_plan` applies to parameters/optimizer state:

      * ``replicate`` — always valid: one chip computes the full gradient.
      * ``split_n`` — FSDP sharded gradients: each chip computes the 1/n
        slice of delta_W along the forward weight's output channels it
        owns (the P("data", ...) parameter shard), when divisible.
      * ``split_k`` — data parallelism: chips split the token reduction
        dim and ring-all-reduce fp32 partial gradients (the classic DP
        gradient sync; `mesh.shard_eval` prices the all-reduce at
        accumulator width), when divisible.

    No head/expert fallbacks: gradients have no attention-compute or
    routing alignment constraint — a grad shard never has to follow the
    head boundary the forward TP rule protects. Pure arithmetic, like
    `mesh_tp_choices`."""
    choices = [m_REPLICATE]
    if n_chips <= 1:
        return tuple(choices)
    if out_channels % n_chips == 0 and out_channels >= n_chips:
        choices.append(m_SPLIT_N)
    if reduce_dim % n_chips == 0 and reduce_dim >= n_chips:
        choices.append(m_SPLIT_K)
    return tuple(choices)


def make_plan(mesh: Mesh, cfg: ModelConfig, shape: ShapeSpec) -> ShardingPlan:
    axes = mesh.axis_names
    model_axis = "model"
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    ms = mesh.shape[model_axis]
    total_data = int(np.prod([mesh.shape[a] for a in data_axes]))
    shard_seq = shape.global_batch < total_data
    attn_tp = cfg.n_heads % ms == 0 if cfg.n_heads else False
    kv_tp = cfg.n_kv_heads % ms == 0 if cfg.n_kv_heads else False
    moe_ep = (cfg.moe_sharding == "ep" or
              (cfg.moe_sharding == "auto" and cfg.n_experts % ms == 0)) \
        and cfg.n_experts > 0 and cfg.n_experts % ms == 0
    return ShardingPlan(mesh=mesh, cfg=cfg, shape=shape,
                        data_axes=data_axes, model_axis=model_axis,
                        shard_seq=shard_seq, attn_tp=attn_tp, kv_tp=kv_tp,
                        moe_ep=moe_ep)
