from repro.sharding.rules import ShardingPlan, make_plan
