"""Architecture registry: ``--arch <id>`` lookup for all assigned configs."""

from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, \
    applicable_shapes

ARCH_IDS = (
    "internlm2-20b",
    "glm4-9b",
    "starcoder2-7b",
    "minicpm-2b",
    "qwen2-moe-a2.7b",
    "arctic-480b",
    "seamless-m4t-large-v2",
    "mamba2-1.3b",
    "pixtral-12b",
    "zamba2-1.2b",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
            for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
