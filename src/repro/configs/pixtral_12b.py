"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409] — VLM: pixtral-ViT frontend
(STUBBED: input_specs() provides precomputed patch embeddings) feeding a
mistral-nemo-like dense GQA decoder backbone."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    gated_mlp=True,
    modality="vision",
    frontend_seq=1024,      # precomputed image patch embeddings
    rope_theta=1_000_000_000.0,
    source="hf:mistralai/Pixtral-12B-2409",
)
