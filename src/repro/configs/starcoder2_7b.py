"""StarCoder2-7B [arXiv:2402.19173; hf] — dense GQA, RoPE."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    gated_mlp=False,        # starcoder2 uses plain GELU MLP
    source="arXiv:2402.19173; hf:bigcode/starcoder2-7b",
)
