"""Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B] — 60 routed experts top-4
plus 4 shared experts, fine-grained (d_ff=1408 per expert)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,                 # no dense FFN; MoE in every layer
    moe_d_ff=1408,
    n_experts=60,
    n_shared_experts=4,
    top_k=4,
    vocab_size=151936,
    gated_mlp=True,
    moe_sharding="tp",      # 60 experts % 16 != 0 -> TP inside experts
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
