from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES,
                                applicable_shapes)
from repro.configs.registry import ARCH_IDS, all_configs, get_config
