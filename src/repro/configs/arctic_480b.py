"""Snowflake Arctic (480B) [hf:Snowflake/snowflake-arctic-base] — 128 routed
experts top-2 with a dense-residual MLP in parallel (dense+MoE hybrid FFN)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,              # dense residual MLP path
    moe_d_ff=4864,
    n_experts=128,
    n_shared_experts=0,
    top_k=2,
    dense_residual=True,
    vocab_size=32000,
    gated_mlp=True,
    moe_sharding="ep",      # 128 % 16 == 0 -> expert parallel on model axis
    source="hf:Snowflake/snowflake-arctic-base",
)
