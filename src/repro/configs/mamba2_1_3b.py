"""Mamba2-1.3B [arXiv:2405.21060] — attention-free SSM with SSD (state-space
duality) blocks; d_state=128, expand=2, head_dim=64."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,                 # attention-free, MLP-free (Mamba2 pure stack)
    vocab_size=50280,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    source="arXiv:2405.21060; hf:state-spaces/mamba2-1.3b",
)
