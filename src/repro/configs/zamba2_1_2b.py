"""Zamba2-1.2B [arXiv:2411.15242; hf] — hybrid: Mamba2 backbone with a
shared full-attention block applied periodically (parameter-shared)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,              # shared attn block's MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,           # shared attention block every 6 mamba blocks
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-1.2B",
)
