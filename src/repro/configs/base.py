"""Config system: one frozen dataclass per architecture + input-shape sets.

Every assigned architecture (``--arch <id>``) is a ``ModelConfig``; input
shapes are ``ShapeSpec`` entries (train / prefill / decode / long-decode).
``reduced()`` derives the CPU smoke-test configuration of the same family.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                 # per-expert hidden dim
    dense_residual: bool = False      # arctic: dense MLP in parallel w/ MoE
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    # --- hybrid (zamba2) ---
    attn_every: int = 0               # shared attn block period (0 = none)
    # --- encoder-decoder (seamless) ---
    encoder_layers: int = 0           # 0 -> decoder-only
    # --- frontends (stubbed modalities) ---
    modality: str = "text"            # text | audio | vision
    frontend_seq: int = 0             # precomputed frame/patch positions
    # --- misc ---
    rope_theta: float = 10_000.0
    gated_mlp: bool = True
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- sharding hints (resolved by sharding/rules.py) ---
    moe_sharding: str = "auto"        # auto | ep | tp
    source: str = ""                  # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def padded_vocab(self, multiple: int = 2048) -> int:
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + \
            self.n_heads * hd * d
        mlp_mult = 3 if self.gated_mlp else 2
        dense_mlp = mlp_mult * d * self.d_ff if self.d_ff else 0
        moe = 0
        if self.n_experts:
            per = mlp_mult * d * self.moe_d_ff
            moe = (self.n_experts + self.n_shared_experts) * per
            if not self.dense_residual:
                dense_mlp = 0
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            d_in = self.ssm_expand * d
            ssm = d * (2 * d_in + 2 * self.ssm_groups * self.ssm_state) + \
                d_in * d + d_in * self.ssm_conv
        layers = self.n_layers * (attn + dense_mlp + moe + ssm)
        if self.family == "ssm":
            layers = self.n_layers * (ssm + dense_mlp)
        elif self.family == "hybrid":
            # mamba blocks per layer; ONE parameter-shared attention block
            # (with its MLP) reused every `attn_every` layers (Zamba2)
            layers = self.n_layers * ssm + (attn + dense_mlp)
        elif self.family == "encdec":
            layers = (self.n_layers + self.encoder_layers) * \
                (attn + dense_mlp) + self.n_layers * attn  # + cross-attn
        return emb + layers

    def active_param_count(self) -> int:
        """Active parameters per token (MoE top-k routing)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        mlp_mult = 3 if self.gated_mlp else 2
        per = mlp_mult * d * self.moe_d_ff
        active_moe = (self.top_k + self.n_shared_experts) * per
        total_moe = (self.n_experts + self.n_shared_experts) * per
        return self.param_count() - self.n_layers * (total_moe - active_moe)

    def reduced(self) -> "ModelConfig":
        """Small same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 2),
            top_k=min(self.top_k, 2),
            moe_d_ff=32 if self.n_experts else 0,
            ssm_state=16 if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            attn_every=min(self.attn_every, 2) if self.attn_every else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            frontend_seq=8 if self.frontend_seq else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell.

    How the kinds lower (`core/frontend.py`; M = GEMM rows, mult =
    workload multiplicity, per instance of each weight-GEMM):

    ========  ==============  ============  =================================
    kind      M               mult          extras
    ========  ==============  ============  =================================
    train     seq_len         global_batch  + backward pass: one dGrad + one
                                            wGrad per forward GEMM (same
                                            multiplicities; MoE wGrads scale
                                            to experts hit by seq_len*top_k
                                            tokens), LM head at M = seq_len
                                            (loss at every position), plus a
                                            once-per-step optimizer bill
                                            (`training.optimizer_update_cost`)
    prefill   seq_len         global_batch  LM head at M = 1 (last position)
    decode    global_batch    1             one token per sequence, batched
                                            into a single MVM
    ========  ==============  ============  =================================
    """
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    # -- model-frontend lowering (core/frontend.py; DESIGN.md §Model
    # frontend). Scenarios differ only in where tokens land: prefill/train
    # GEMMs see the full sequence as the M dim with the batch as workload
    # multiplicity; a decode step sees one token per sequence, batched into
    # a single M = global_batch MVM.
    @property
    def m_tokens(self) -> int:
        """GEMM M dim of one extracted weight-GEMM instance."""
        return self.global_batch if self.is_decode else self.seq_len

    @property
    def instance_count(self) -> int:
        """Workload multiplicity contributed by the batch."""
        return 1 if self.is_decode else self.global_batch

    @classmethod
    def serving_iteration(cls, prefill_lens: "tuple[int, ...]",
                          n_decode: int, *, context_len: int = 4096,
                          name: str | None = None) -> "ShapeSpec":
        """One continuous-batching iteration as a scenario cell.

        The serving engine (`core/serving.py`) batches whole-prompt
        prefills with single-token decode steps into ONE forward pass; its
        GEMMs see the *total* token count as the M dim.  Lowered as a
        decode-kind cell so ``m_tokens = sum(prefill_lens) + n_decode``
        with ``instance_count = 1`` (one fused MVM batch, not a per-batch
        multiplicity), and ``seq_len = context_len`` bounds the attention
        / KV reach of the iteration."""
        m = int(sum(prefill_lens)) + int(n_decode)
        if m < 1:
            raise ValueError("a serving iteration must carry >= 1 token")
        return cls(name or f"serve_iter_m{m}", seq_len=int(context_len),
                   global_batch=m, kind="decode")


SHAPES = {
    "train_2k": ShapeSpec("train_2k", 2_048, 512, "train"),
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "train_8k": ShapeSpec("train_8k", 8_192, 128, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict[str, ShapeSpec | None]:
    """Shape cells for an arch; None = skipped (with reason in dryrun log).

    ``long_500k`` requires sub-quadratic sequence mixing: run for SSM/hybrid
    archs only (assignment rule; see DESIGN.md §Arch-applicability).
    """
    out: dict[str, ShapeSpec | None] = {}
    for name, spec in SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            out[name] = None
        else:
            out[name] = spec
    return out
