"""MiniCPM-2B [arXiv:2404.06395; hf] — llama-like dense (MHA), WSD schedule.

The WSD (warmup-stable-decay) learning-rate schedule the paper introduces is
implemented in repro/train/optimizer.py and selected by this config.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122753,
    gated_mlp=True,
    tie_embeddings=True,
    source="arXiv:2404.06395; hf:openbmb/MiniCPM-2B (WSD schedule)",
)

TRAIN_SCHEDULE = "wsd"
