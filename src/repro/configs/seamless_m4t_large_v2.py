"""SeamlessM4T-large-v2 [arXiv:2308.11596] — encoder-decoder backbone.

The audio frontend (w2v-BERT conformer feature extractor) is a STUB per the
assignment: ``input_specs()`` provides precomputed frame embeddings of shape
(batch, frontend_seq, d_model); the transformer backbone (24L enc + 24L dec)
is fully implemented.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,            # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    gated_mlp=False,
    modality="audio",
    frontend_seq=1024,      # precomputed audio frame embeddings
    source="arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large",
)
