"""GQA attention with RoPE, causal masking, KV caching and an optional
flash-attention Pallas kernel path (repro/kernels/flash_attention)."""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import Identity, apply_rope, dense, init_dense


class KVCache(NamedTuple):
    k: jax.Array          # (B, S, KV, hd) — bf16, or int8 when quantized
    v: jax.Array          # (B, S, KV, hd)
    length: jax.Array     # (B,) int32 — valid prefix length
    k_scale: jax.Array | None = None   # (B, S, KV, 1) f32 when int8
    v_scale: jax.Array | None = None


# Module-level implementation switches (same pattern as
# transformer.SCAN_UNROLL / moe.MOE_DISPATCH — flipped per-variant by the
# dry-run and the perf harness, defaults = baseline):
ATTN_IMPL = "chunked"     # "naive" | "chunked" (flash-style online softmax)
KV_QUANT = False          # int8 KV cache (capacity optimization)


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, dtype=jnp.float32) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": init_dense(kq, d_model, n_heads * head_dim, dtype),
        "wk": init_dense(kk, d_model, n_kv_heads * head_dim, dtype),
        "wv": init_dense(kv, d_model, n_kv_heads * head_dim, dtype),
        "wo": init_dense(ko, n_heads * head_dim, d_model, dtype),
    }


def _repeat_kv(x: jax.Array, rep: int) -> jax.Array:
    if rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :],
                            (b, s, kv, rep, hd)).reshape(b, s, kv * rep, hd)


def dot_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool, q_offset=None,
                  kv_length=None) -> jax.Array:
    """q: (B,Lq,H,hd); k,v: (B,Lk,H,hd). Returns (B,Lq,H,hd)."""
    b, lq, h, hd = q.shape
    lk = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(lk)
    neg = jnp.finfo(jnp.float32).min
    if causal:
        qpos = jnp.arange(lq)
        if q_offset is not None:
            qpos = qpos + q_offset[..., None] if q_offset.ndim else \
                qpos + q_offset
        mask = kpos[None, :] <= qpos[:, None]
        scores = jnp.where(mask[None, None], scores, neg)
    if kv_length is not None:
        valid = kpos[None, :] < kv_length[:, None]
        scores = jnp.where(valid[:, None, None, :], scores, neg)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def dot_attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool, block_k: int = 1024,
                          kv_length=None) -> jax.Array:
    """Flash-style online-softmax attention expressed in XLA (scan over KV
    blocks, f32 running statistics, bf16 score/prob tensors): the (Lq, Lk)
    f32 score tensor is never materialized — the HBM-traffic reduction the
    Pallas kernel realizes on TPU, available to the dry-run cost model.
    Fully-masked causal blocks are skipped via the score mask (XLA DCEs the
    constant branch under unrolled scans)."""
    bsz, lq, h, hd = q.shape
    lk = k.shape[1]
    block_k = min(block_k, lk)
    assert lk % block_k == 0
    nb = lk // block_k
    scale = 1.0 / math.sqrt(hd)
    kb = k.reshape(bsz, nb, block_k, h, hd)
    vb = v.reshape(bsz, nb, block_k, h, hd)
    qpos = jnp.arange(lq)
    neg = jnp.float32(-1e30)

    def body(carry, inp):
        m, s_sum, acc = carry
        kc, vc, ib = inp
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                            preferred_element_type=jnp.float32) * scale
        kpos = ib * block_k + jnp.arange(block_k)
        if causal:
            mask = kpos[None, :] <= qpos[:, None]
            scores = jnp.where(mask[None, None], scores, neg)
        if kv_length is not None:
            valid = kpos[None, :] < kv_length[:, None]
            scores = jnp.where(valid[:, None, None, :], scores, neg)
        m_new = jnp.maximum(m, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None]).astype(q.dtype)
        s_sum = s_sum * alpha + jnp.sum(p, axis=-1,
                                        dtype=jnp.float32)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p, vc)
        acc = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, s_sum, acc), None

    m0 = jnp.full((bsz, h, lq), -1e30, jnp.float32)
    s0 = jnp.zeros((bsz, h, lq), jnp.float32)
    a0 = jnp.zeros((bsz, h, lq, hd), jnp.float32)
    ks = jnp.moveaxis(kb, 1, 0)
    vs = jnp.moveaxis(vb, 1, 0)
    (m, s_sum, acc), _ = jax.lax.scan(
        body, (m0, s0, a0), (ks, vs, jnp.arange(nb)))
    out = acc / jnp.maximum(s_sum, 1e-20)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def quantize_kv(x: jax.Array):
    """Per-(position, head) symmetric int8 KV quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def attention(params: dict, x: jax.Array, *, n_heads: int, n_kv_heads: int,
              head_dim: int, rope_theta: float, causal: bool = True,
              positions: jax.Array | None = None,
              cache: KVCache | None = None,
              shard=Identity, use_flash: bool = False):
    """Returns (out, new_cache). Prefill: cache=None, full seq. Decode:
    x is (B, 1, D) and cache holds past K/V."""
    b, l, _ = x.shape
    q = dense(params["wq"], x).reshape(b, l, n_heads, head_dim)
    k = dense(params["wk"], x).reshape(b, l, n_kv_heads, head_dim)
    v = dense(params["wv"], x).reshape(b, l, n_kv_heads, head_dim)
    q = shard("attn_q", q)
    rep = n_heads // n_kv_heads
    if cache is None:
        pos = positions if positions is not None else jnp.arange(l)
        if rope_theta:
            q = apply_rope(q, pos, rope_theta)
            k = apply_rope(k, pos, rope_theta)
        kf, vf = _repeat_kv(k, rep), _repeat_kv(v, rep)
        if use_flash and causal and l >= 512:
            from repro.kernels.flash_attention.ops import flash_attention
            out = flash_attention(q, kf, vf, causal=True)
        elif ATTN_IMPL == "chunked" and l >= 2048:
            out = dot_attention_chunked(q, kf, vf, causal=causal)
        else:
            out = dot_attention(q, kf, vf, causal=causal)
        if KV_QUANT:
            qk, sk = quantize_kv(k)
            qv, sv = quantize_kv(v)
            new_cache = KVCache(k=qk, v=qv,
                                length=jnp.full((b,), l, jnp.int32),
                                k_scale=sk, v_scale=sv)
        else:
            new_cache = KVCache(k=k, v=v,
                                length=jnp.full((b,), l, jnp.int32))
    else:
        # single-token decode against the cache
        pos = cache.length                                  # (B,)
        if rope_theta:
            q = apply_rope(q, pos[:, None], rope_theta)
            k = apply_rope(k, pos[:, None], rope_theta)
        oh = jax.nn.one_hot(cache.length, cache.k.shape[1],
                            dtype=jnp.float32)              # (B, S)
        quant = cache.k_scale is not None
        if quant:
            qk, sk = quantize_kv(k)
            qv, sv = quantize_kv(v)
            ohq = oh[:, :, None, None]
            k_cache = cache.k + (ohq * qk.astype(jnp.float32)).astype(
                cache.k.dtype)
            v_cache = cache.v + (ohq * qv.astype(jnp.float32)).astype(
                cache.v.dtype)
            k_scale = cache.k_scale + ohq * sk
            v_scale = cache.v_scale + ohq * sv
            kf = _repeat_kv(dequantize_kv(k_cache, k_scale, x.dtype), rep)
            vf = _repeat_kv(dequantize_kv(v_cache, v_scale, x.dtype), rep)
            new_cache = KVCache(k=k_cache, v=v_cache,
                                length=cache.length + 1,
                                k_scale=k_scale, v_scale=v_scale)
        else:
            ohq = oh[:, :, None, None].astype(cache.k.dtype)
            k_cache = cache.k + ohq * k.astype(cache.k.dtype)
            v_cache = cache.v + ohq * v.astype(cache.v.dtype)
            kf = _repeat_kv(k_cache, rep)
            vf = _repeat_kv(v_cache, rep)
            new_cache = KVCache(k=k_cache, v=v_cache,
                                length=cache.length + 1)
        out = dot_attention(q, kf, vf, causal=False,
                            kv_length=cache.length + 1)
    out = shard("attn_out", out)
    out = out.reshape(b, l, n_heads * head_dim)
    return dense(params["wo"], out), new_cache


def init_kv_cache(batch: int, max_seq: int, n_kv_heads: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
        v=jnp.zeros((batch, max_seq, n_kv_heads, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32))
