"""Mixture-of-Experts layer: top-k routing with fixed expert capacity
(dispatch/combine einsums — the standard TPU-friendly formulation that
shards cleanly under EP), plus optional shared experts (Qwen-MoE) and a
dense residual branch (Arctic)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import Identity, init_dense, init_mlp, mlp

# Dispatch implementation. "einsum" is the textbook dense dispatch/combine
# (one-hot (T,E,C) tensors — O(T·E·C) memory: simple but catastrophic at
# arctic scale); "scatter" is the production path (sorted scatter/gather,
# O(T·K + E·C·D) memory). See EXPERIMENTS.md §Perf iteration 1.
MOE_DISPATCH = "scatter"


def init_moe(key, d_model: int, d_ff: int, n_experts: int,
             n_shared: int, gated: bool, dtype=jnp.float32) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    def expert_bank(key, n):
        kk = jax.random.split(key, 3)
        mult = 1.0 / jnp.sqrt(d_model)
        p = {
            "up": {"w": mult * jax.random.normal(
                kk[0], (n, d_model, d_ff), jnp.float32).astype(dtype)},
            "down": {"w": (1.0 / jnp.sqrt(d_ff)) * jax.random.normal(
                kk[1], (n, d_ff, d_model), jnp.float32).astype(dtype)},
        }
        if gated:
            p["gate"] = {"w": mult * jax.random.normal(
                kk[2], (n, d_model, d_ff), jnp.float32).astype(dtype)}
        return p
    p = {"router": init_dense(kr, d_model, n_experts, dtype),
         "experts": expert_bank(ke, n_experts)}
    if n_shared:
        p["shared"] = expert_bank(ks, n_shared)
    return p


def _expert_ffn(bank: dict, x: jax.Array, gated: bool) -> jax.Array:
    """x: (E, C, D) -> (E, C, D) with per-expert weights (E, D, F)."""
    up = jnp.einsum("ecd,edf->ecf", x, bank["up"]["w"].astype(x.dtype))
    if gated:
        g = jnp.einsum("ecd,edf->ecf", x, bank["gate"]["w"].astype(x.dtype))
        h = jax.nn.silu(g) * up
    else:
        h = jax.nn.gelu(up)
    return jnp.einsum("ecf,efd->ecd", h, bank["down"]["w"].astype(x.dtype))


def moe(params: dict, x: jax.Array, *, n_experts: int, top_k: int,
        gated: bool, capacity_factor: float = 1.25,
        shard=Identity) -> tuple[jax.Array, jax.Array]:
    """x: (B, L, D). Returns (out, aux_loss)."""
    b, l, d = x.shape
    tokens = x.reshape(b * l, d)
    n_tok = b * l
    logits = tokens @ params["router"]["w"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    capacity = max(1, int(capacity_factor * n_tok * top_k / n_experts))
    # position of each token within its expert's buffer, per routing slot
    onehot = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.int32)  # T,K,E
    flat = onehot.reshape(n_tok * top_k, n_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat)
    pos_in_expert = jnp.sum(
        pos_in_expert.reshape(n_tok, top_k, n_experts) * onehot, axis=-1)
    keep = pos_in_expert < capacity                             # (T, K)
    gate_vals = gate_vals * keep

    if MOE_DISPATCH == "scatter":
        # production path: indexed scatter/gather, no (T,E,C) tensors
        dest = expert_idx * capacity + jnp.minimum(pos_in_expert,
                                                   capacity - 1)  # (T,K)
        dest = jnp.where(keep, dest, n_experts * capacity)        # dropped
        flat_dest = dest.reshape(-1)                              # (T*K,)
        src = jnp.repeat(jnp.arange(n_tok), top_k)
        buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
        expert_in = buf.at[flat_dest].add(tokens[src])[:-1]
        expert_in = expert_in.reshape(n_experts, capacity, d)
        expert_in = shard("moe_expert_in", expert_in)
        expert_out = _expert_ffn(params["experts"], expert_in, gated)
        expert_out = shard("moe_expert_out", expert_out)
        flat_out = expert_out.reshape(n_experts * capacity, d)
        flat_out = jnp.concatenate(
            [flat_out, jnp.zeros((1, d), x.dtype)], axis=0)
        picked = flat_out[flat_dest].reshape(n_tok, top_k, d)
        out = jnp.sum(picked * gate_vals[..., None].astype(x.dtype),
                      axis=1)
    else:
        # dense one-hot dispatch (textbook formulation; O(T*E*C) memory —
        # kept as the measurable baseline, see EXPERIMENTS.md §Perf)
        pos_oh = jax.nn.one_hot(jnp.where(keep, pos_in_expert, capacity),
                                capacity, dtype=x.dtype)        # (T,K,C)
        disp = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), pos_oh)
        expert_in = jnp.einsum("td,tec->ecd", tokens, disp)
        expert_in = shard("moe_expert_in", expert_in)
        expert_out = _expert_ffn(params["experts"], expert_in, gated)
        expert_out = shard("moe_expert_out", expert_out)
        combine = jnp.einsum("tec,tk,tke->tec", disp,
                             gate_vals.astype(x.dtype),
                             onehot.astype(x.dtype))
        out = jnp.einsum("ecd,tec->td", expert_out, combine)

    if "shared" in params:
        n_sh = params["shared"]["up"]["w"].shape[0]
        sh_in = jnp.broadcast_to(tokens[None], (n_sh, n_tok, d))
        out = out + jnp.sum(_expert_ffn(params["shared"], sh_in, gated),
                            axis=0)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], n_experts, dtype=jnp.float32),
        axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return out.reshape(b, l, d), aux
