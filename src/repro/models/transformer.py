"""Unified model assembly for every assigned architecture family.

One ``init_model`` / ``forward`` pair covers:
  dense / vlm  : pre-norm GQA blocks + (gated) MLP        (scan over layers)
  moe          : GQA blocks + routed experts (+ shared / dense-residual)
  ssm          : Mamba2 (SSD) blocks, attention-free
  hybrid       : Mamba2 backbone + parameter-shared attention block every
                 ``attn_every`` layers (Zamba2)
  encdec       : bidirectional encoder + causal decoder w/ cross-attention
                 (Seamless backbone; audio frontend stubbed)

Layers are stacked and driven by ``jax.lax.scan`` (small HLO, fast 512-way
compile); training wraps the block in ``jax.checkpoint``. Modes:
  "train"   tokens -> logits                  (full seq, causal)
  "prefill" tokens -> logits + caches
  "decode"  one token + caches -> logits + caches
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import (KVCache, attention, init_attention,
                                    init_kv_cache)
from repro.models.layers import (Identity, embed, init_embedding, init_mlp,
                                 init_rmsnorm, mlp, rms_norm, unembed)
from repro.models.moe import init_moe, moe
from repro.models.ssm import (SSMState, init_mamba2, init_ssm_state,
                              mamba2_block)


# Scan-over-layers unrolling. XLA's cost model counts a while-loop body
# once regardless of trip count; the dry-run sets this to True for its two
# small exact-cost compiles (launch/dryrun.py) and leaves scans rolled for
# the real (memory-accurate, fast-compile) artifact.
SCAN_UNROLL: int | bool = 1


def _scan(f, init, xs):
    return jax.lax.scan(f, init, xs, unroll=SCAN_UNROLL)


def _stack_init(init_fn, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# Block initializers
# ---------------------------------------------------------------------------

def _init_attn_block(key, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    hd = cfg.resolved_head_dim
    p = {
        "ln1": init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               hd, dtype),
        "ln2": init_rmsnorm(cfg.d_model),
    }
    if cfg.n_experts and cfg.family == "moe":
        p["moe"] = init_moe(k2, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                            cfg.n_shared_experts, cfg.gated_mlp, dtype)
        if cfg.dense_residual:
            p["mlp"] = init_mlp(k3, cfg.d_model, cfg.d_ff, cfg.gated_mlp,
                                dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.gated_mlp, dtype)
    return p


def _init_mamba_block(key, cfg: ModelConfig, dtype) -> dict:
    return {
        "ln": init_rmsnorm(cfg.d_model),
        "mamba": init_mamba2(key, cfg.d_model, expand=cfg.ssm_expand,
                             head_dim=cfg.ssm_head_dim, groups=cfg.ssm_groups,
                             state=cfg.ssm_state, conv=cfg.ssm_conv,
                             dtype=dtype),
    }


def _init_cross_block(key, cfg: ModelConfig, dtype) -> dict:
    """Decoder block with cross-attention (encdec family)."""
    p = _init_attn_block(key, cfg, dtype)
    k = jax.random.fold_in(key, 7)
    hd = cfg.resolved_head_dim
    p["ln_x"] = init_rmsnorm(cfg.d_model)
    p["xattn"] = init_attention(k, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                hd, dtype)
    return p


def init_model(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    ke, kb, ks, kf = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": init_embedding(ke, cfg.padded_vocab(), cfg.d_model, dtype),
        "ln_f": init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = init_embedding(
            jax.random.fold_in(ke, 1), cfg.padded_vocab(), cfg.d_model, dtype)
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        params["blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, dtype), kb, cfg.n_layers)
    elif fam == "ssm":
        params["blocks"] = _stack_init(
            lambda k: _init_mamba_block(k, cfg, dtype), kb, cfg.n_layers)
    elif fam == "hybrid":
        n_groups = cfg.n_layers // cfg.attn_every
        grouped = n_groups * cfg.attn_every
        params["blocks"] = _stack_init(
            lambda k: _init_mamba_block(k, cfg, dtype), kb, grouped)
        params["tail"] = _stack_init(
            lambda k: _init_mamba_block(k, cfg, dtype),
            jax.random.fold_in(kb, 3), cfg.n_layers - grouped) \
            if cfg.n_layers - grouped else None
        params["shared_attn"] = _init_attn_block(ks, cfg, dtype)
    elif fam == "encdec":
        params["enc_blocks"] = _stack_init(
            lambda k: _init_attn_block(k, cfg, dtype), kb, cfg.encoder_layers)
        params["blocks"] = _stack_init(
            lambda k: _init_cross_block(k, cfg, dtype),
            jax.random.fold_in(kb, 5), cfg.n_layers)
        params["ln_enc"] = init_rmsnorm(cfg.d_model)
    else:
        raise ValueError(fam)
    if fam in ("hybrid",) and params.get("tail") is None:
        params.pop("tail")
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ForwardOut:
    logits: jax.Array
    caches: Any = None
    aux_loss: jax.Array | None = None


def _attn_block_apply(blk, x, cfg: ModelConfig, cache, *, causal, shard,
                      use_flash, memory=None, mem_cross_kv=None):
    hd = cfg.resolved_head_dim
    h = rms_norm(blk["ln1"], x, cfg.norm_eps)
    attn_out, new_cache = attention(
        blk["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=hd, rope_theta=cfg.rope_theta, causal=causal, cache=cache,
        shard=shard, use_flash=use_flash)
    x = x + attn_out
    aux = jnp.zeros((), jnp.float32)
    cross_kv = None
    if memory is not None or mem_cross_kv is not None:
        # cross-attention (encdec decoder)
        hx = rms_norm(blk["ln_x"], x, cfg.norm_eps)
        from repro.models.attention import dot_attention
        from repro.models.layers import dense
        b, l, _ = hx.shape
        q = dense(blk["xattn"]["wq"], hx).reshape(b, l, cfg.n_heads, hd)
        if mem_cross_kv is None:
            m = memory
            k = dense(blk["xattn"]["wk"], m).reshape(
                b, m.shape[1], cfg.n_kv_heads, hd)
            v = dense(blk["xattn"]["wv"], m).reshape(
                b, m.shape[1], cfg.n_kv_heads, hd)
            cross_kv = (k, v)
        else:
            k, v = mem_cross_kv
            cross_kv = mem_cross_kv
        rep = cfg.n_heads // cfg.n_kv_heads
        from repro.models.attention import _repeat_kv
        o = dot_attention(q, _repeat_kv(k, rep), _repeat_kv(v, rep),
                          causal=False)
        x = x + dense(blk["xattn"]["wo"], o.reshape(b, l, -1))
    h = rms_norm(blk["ln2"], x, cfg.norm_eps)
    if "moe" in blk:
        mo, aux = moe(blk["moe"], h, n_experts=cfg.n_experts,
                      top_k=cfg.top_k, gated=cfg.gated_mlp, shard=shard)
        if "mlp" in blk:            # arctic dense residual
            mo = mo + mlp(blk["mlp"], h, cfg.gated_mlp, shard)
        x = x + mo
    else:
        x = x + mlp(blk["mlp"], h, cfg.gated_mlp, shard)
    return x, new_cache, aux, cross_kv


def _scan_attn_layers(params_stack, x, cfg, caches, *, causal, shard,
                      use_flash, remat):
    """caches: stacked per-layer KVCache for decode, or None (train /
    prefill / encode — prefill collects fresh caches from the scan ys)."""
    def body(carry, layer_in):
        x, aux = carry
        blk, cache = layer_in
        x, new_cache, aux_l, _ = _attn_block_apply(
            blk, x, cfg, cache, causal=causal, shard=shard,
            use_flash=use_flash)
        return (x, aux + aux_l), new_cache

    fn = jax.checkpoint(body) if remat else body
    (x, aux), new_caches = _scan(
        fn, (x, jnp.zeros((), jnp.float32)), (params_stack, caches))
    return x, aux, new_caches


def _dummy_caches(n_layers, batch, max_seq, cfg, dtype):
    return KVCache(
        k=jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads,
                     cfg.resolved_head_dim), dtype),
        v=jnp.zeros((n_layers, batch, max_seq, cfg.n_kv_heads,
                     cfg.resolved_head_dim), dtype),
        length=jnp.zeros((n_layers, batch), jnp.int32))


def forward(params: dict, cfg: ModelConfig, tokens: jax.Array, *,
            mode: str = "train", caches: Any = None,
            frontend_embeds: jax.Array | None = None,
            shard=Identity, use_flash: bool = False,
            remat: bool = False, compute_dtype=jnp.bfloat16) -> ForwardOut:
    """tokens: (B, L) int32. frontend_embeds: (B, S_front, D) for
    audio/vision modalities (precomputed stub embeddings)."""
    fam = cfg.family
    b, l = tokens.shape
    x = embed(params["embed"], tokens, compute_dtype)
    if frontend_embeds is not None and fam in ("vlm",) and mode != "decode":
        x = jnp.concatenate([frontend_embeds.astype(compute_dtype), x],
                            axis=1)
    x = shard("hidden", x)
    causal = mode != "encode"
    is_decode = mode == "decode"
    aux = jnp.zeros((), jnp.float32)

    if fam in ("dense", "moe", "vlm"):
        x, aux, new_caches = _scan_attn_layers(
            params["blocks"], x, cfg, caches if is_decode else None,
            causal=True, shard=shard, use_flash=use_flash,
            remat=remat and mode == "train")
    elif fam == "ssm":
        x, new_caches, aux = _ssm_stack(params["blocks"], x, cfg, caches,
                                        shard, remat and mode == "train",
                                        is_decode)
    elif fam == "hybrid":
        x, new_caches, aux = _hybrid_stack(params, x, cfg, caches, shard,
                                           remat and mode == "train",
                                           is_decode, compute_dtype,
                                           use_flash)
    elif fam == "encdec":
        x, new_caches, aux = _encdec_stack(params, x, cfg, caches,
                                           frontend_embeds, shard,
                                           remat and mode == "train",
                                           is_decode, compute_dtype,
                                           use_flash)
    else:
        raise ValueError(fam)

    x = rms_norm(params["ln_f"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    if frontend_embeds is not None and fam == "vlm" and mode != "decode":
        x = x[:, frontend_embeds.shape[1]:]
    logits = unembed(table, x)
    logits = shard("logits", logits)
    return ForwardOut(logits=logits, caches=new_caches, aux_loss=aux)


# ---------------------------------------------------------------------------
# family-specific stacks
# ---------------------------------------------------------------------------

def _ssm_stack(stack, x, cfg, states, shard, remat, is_decode):
    b = x.shape[0]
    if states is None:
        proto = init_ssm_state(b, cfg, cfg.d_model)
        states = jax.tree.map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), proto)

    def body(carry, layer_in):
        x = carry
        blk, st = layer_in
        h = rms_norm(blk["ln"], x, cfg.norm_eps)
        out, new_st = mamba2_block(blk["mamba"], h, cfg, state=st if
                                   is_decode else None, shard=shard)
        if not is_decode:
            new_st = SSMState(h=new_st.h, conv=new_st.conv)
        return x + out, new_st

    fn = jax.checkpoint(body) if remat else body
    x, new_states = _scan(fn, x, (stack, states))
    return x, new_states, jnp.zeros((), jnp.float32)


def _hybrid_stack(params, x, cfg, caches, shard, remat, is_decode,
                  compute_dtype, use_flash):
    b = x.shape[0]
    n_groups = cfg.n_layers // cfg.attn_every
    grouped = n_groups * cfg.attn_every
    tail_n = cfg.n_layers - grouped
    if caches is None:
        proto = init_ssm_state(b, cfg, cfg.d_model)
        ssm_states = jax.tree.map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), proto)
        kv = None
    else:
        ssm_states, kv = caches
    max_seq = x.shape[1] if kv is None else kv.k.shape[2]
    main_states = jax.tree.map(lambda t: t[:grouped], ssm_states)
    grouped_states = jax.tree.map(
        lambda t: t.reshape((n_groups, cfg.attn_every) + t.shape[1:]),
        main_states)

    def mamba_body(carry, layer_in):
        x = carry
        blk, st = layer_in
        h = rms_norm(blk["ln"], x, cfg.norm_eps)
        out, new_st = mamba2_block(blk["mamba"], h, cfg,
                                   state=st if is_decode else None,
                                   shard=shard)
        return x + out, new_st

    mamba_fn = jax.checkpoint(mamba_body) if remat else mamba_body
    grouped_params = jax.tree.map(
        lambda t: t.reshape((n_groups, cfg.attn_every) + t.shape[1:]),
        params["blocks"])

    def group_body(carry, layer_in):
        x = carry
        blocks_g, states_g, kv_g = layer_in
        x, new_states_g = _scan(mamba_fn, x, (blocks_g, states_g))
        # parameter-shared attention block
        x, new_kv, aux, _ = _attn_block_apply(
            params["shared_attn"], x, cfg,
            kv_g if is_decode else None, causal=True, shard=shard,
            use_flash=use_flash)
        return x, (new_states_g, new_kv)

    if kv is None:
        kv_stack = _dummy_caches(n_groups, b, max_seq, cfg, compute_dtype)
    else:
        kv_stack = kv
    gfn = group_body
    x, (new_grouped_states, new_kv_stack) = _scan(
        gfn, x, (grouped_params, grouped_states, kv_stack))
    new_main = jax.tree.map(
        lambda t: t.reshape((grouped,) + t.shape[2:]), new_grouped_states)
    if tail_n:
        tail_states = jax.tree.map(lambda t: t[grouped:], ssm_states)
        x, new_tail = _scan(mamba_fn, x,
                                   (params["tail"], tail_states))
        new_states = jax.tree.map(
            lambda a, c: jnp.concatenate([a, c], axis=0), new_main, new_tail)
    else:
        new_states = new_main
    return x, (new_states, new_kv_stack), jnp.zeros((), jnp.float32)


def _encdec_stack(params, x, cfg, caches, frontend_embeds, shard, remat,
                  is_decode, compute_dtype, use_flash):
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    if is_decode:
        kv, cross_kvs, memory = caches
        enc_out = None
    else:
        # encode the (stubbed) frontend embeddings bidirectionally
        assert frontend_embeds is not None, "encdec needs frontend embeds"
        m = frontend_embeds.astype(compute_dtype)
        m, _, _ = _scan_attn_layers(
            params["enc_blocks"], m, cfg, None, causal=False, shard=shard,
            use_flash=False, remat=remat)
        memory = rms_norm(params["ln_enc"], m, cfg.norm_eps)
        kv, cross_kvs = None, None

    # decoder with cross-attention — layer loop unrolled via python for
    # cross-KV handling (cross K/V shapes differ from self K/V); n_layers is
    # modest for the encdec arch (24) and the blocks still share code.
    n = cfg.n_layers
    blocks = params["blocks"]
    new_kv_list, new_ckv_list = [], []
    aux = jnp.zeros((), jnp.float32)
    for i in range(n):
        blk = jax.tree.map(lambda t: t[i], blocks)
        cache_i = jax.tree.map(lambda t: t[i], kv) if kv is not None else None
        ckv_i = jax.tree.map(lambda t: t[i], cross_kvs) \
            if cross_kvs is not None else None
        x, new_cache, aux_l, new_ckv = _attn_block_apply(
            blk, x, cfg, cache_i, causal=True, shard=shard,
            use_flash=use_flash,
            memory=memory if ckv_i is None else None,
            mem_cross_kv=ckv_i)
        aux = aux + aux_l
        new_kv_list.append(new_cache)
        new_ckv_list.append(new_ckv if new_ckv is not None else ckv_i)
    new_kv = jax.tree.map(lambda *ts: jnp.stack(ts), *new_kv_list)
    new_ckvs = jax.tree.map(lambda *ts: jnp.stack(ts), *new_ckv_list)
    return x, (new_kv, new_ckvs, memory), aux
