"""Core pure-JAX layers: norms, dense, MLPs, RoPE, embeddings.

All modules are (init, apply) function pairs over plain dict pytrees — no
framework dependency. ``shard`` is an optional callback
``(logical_name, array) -> array`` used by the distribution layer to insert
``with_sharding_constraint``; models stay mesh-agnostic.
"""

from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

Identity = lambda name, x: x


def truncated_normal(key, shape, std, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape,
                                             jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32)}


def rms_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


def init_layernorm(dim: int) -> dict:
    return {"scale": jnp.ones((dim,), jnp.float32),
            "bias": jnp.zeros((dim,), jnp.float32)}


def layer_norm(params: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * params["scale"] + params["bias"]).astype(dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    std = 1.0 / math.sqrt(d_in)
    return {"w": truncated_normal(key, (d_in, d_out), std, dtype)}


def dense(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["w"].astype(x.dtype)


def init_mlp(key, d_model: int, d_ff: int, gated: bool,
             dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": init_dense(k1, d_model, d_ff, dtype),
         "down": init_dense(k2, d_ff, d_model, dtype)}
    if gated:
        p["gate"] = init_dense(k3, d_model, d_ff, dtype)
    return p


def mlp(params: dict, x: jax.Array, gated: bool, shard=Identity) -> jax.Array:
    h = dense(params["up"], x)
    if gated:
        h = jax.nn.silu(dense(params["gate"], x)) * h
    else:
        h = jax.nn.gelu(h)
    h = shard("ffn_hidden", h)
    return dense(params["down"], h)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (.., L, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> dict:
    return {"table": truncated_normal(key, (vocab, d_model), 0.02, dtype)}


def embed(params: dict, ids: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return params["table"].astype(dtype)[ids]


def unembed(params: dict, x: jax.Array) -> jax.Array:
    return x @ params["table"].astype(x.dtype).T
