"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Prefill/training uses the chunked SSD algorithm: quadratic attention-like
intra-chunk term + inter-chunk state recurrence (lax.scan over chunks).
Decode is the O(1) recurrent update. The intra-chunk einsum stack is the
compute hot-spot backed by the ``ssd_scan`` Pallas kernel; this module is
also its jnp reference semantics.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import (Identity, dense, init_dense, init_rmsnorm,
                                 rms_norm, truncated_normal)


class SSMState(NamedTuple):
    h: jax.Array           # (B, H, P, N)
    conv: jax.Array        # (B, K-1, conv_dim)


def ssd_dims(d_model: int, expand: int, head_dim: int, groups: int,
             state: int) -> tuple[int, int, int]:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * groups * state
    return d_inner, n_heads, conv_dim


def init_mamba2(key, d_model: int, *, expand: int, head_dim: int,
                groups: int, state: int, conv: int,
                dtype=jnp.float32) -> dict:
    d_inner, n_heads, conv_dim = ssd_dims(d_model, expand, head_dim,
                                          groups, state)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_proj = 2 * d_inner + 2 * groups * state + n_heads
    return {
        "in_proj": init_dense(k1, d_model, d_proj, dtype),
        "conv_w": truncated_normal(k2, (conv, conv_dim), 0.1, dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(
            jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner),
        "out_proj": init_dense(k4, d_inner, d_model, dtype),
    }


def _split_proj(cfgd: dict, zxbcdt: jax.Array):
    d_inner, gn, h = cfgd["d_inner"], cfgd["gn"], cfgd["n_heads"]
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner:d_inner * 2 + 2 * gn]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                c: jax.Array, d_skip: jax.Array, chunk: int = 256,
                h0: jax.Array | None = None, use_kernel: bool = False):
    """Chunked SSD.

    x: (B, L, H, P); dt: (B, L, H); a: (H,) (negative);
    b, c: (B, L, G, N); d_skip: (H,).
    Returns (y (B,L,H,P), h_final (B,H,P,N)).
    """
    bsz, l, h, p = x.shape
    g, n = b.shape[2], b.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = h // g

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = jnp.repeat(b.reshape(bsz, nc, chunk, g, n), rep, axis=3)
    cc = jnp.repeat(c.reshape(bsz, nc, chunk, g, n), rep, axis=3)

    da = dtc * a                                    # (B,NC,Q,H), negative
    s = jnp.cumsum(da, axis=2)                      # within-chunk cumsum
    # intra-chunk: scores[t, tau] = (C_t . B_tau) exp(s_t - s_tau) dt_tau
    if use_kernel:
        # Pallas kernel builds the (Q,Q) decay in VMEM from s — no
        # (B,NC,Q,Q,H) HBM tensor.
        from repro.kernels.ssd_scan.ops import ssd_intra_chunk
        y_intra = ssd_intra_chunk(cc, bc, s, dtc, xc).astype(x.dtype)
    else:
        seg = s[:, :, :, None, :] - s[:, :, None, :, :]      # (B,NC,Q,Q,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        decay = jnp.where(tri[None, None, :, :, None], jnp.exp(seg), 0.0)
        scores = jnp.einsum("bcqhn,bckhn->bcqkh", cc, bc,
                            preferred_element_type=jnp.float32)
        scores = scores * decay * dtc[:, :, None, :, :]
        y_intra = jnp.einsum("bcqkh,bckhp->bcqhp",
                             scores.astype(x.dtype), xc)

    # chunk summary state: S = sum_tau exp(s_Q - s_tau) dt_tau B_tau x_tau^T
    tail = s[:, :, -1:, :] - s                                  # (B,NC,Q,H)
    w = (jnp.exp(tail) * dtc).astype(x.dtype)
    s_chunk = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", bc, w, xc)

    # inter-chunk recurrence over chunk index
    chunk_decay = jnp.exp(s[:, :, -1, :])                       # (B,NC,H)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hprev, inp):
        dec, s_c = inp                                         # (B,H), (B,H,P,N)
        hnext = hprev * dec[:, :, None, None] + s_c.astype(jnp.float32)
        return hnext, hprev

    dec_t = jnp.moveaxis(chunk_decay, 1, 0)                     # (NC,B,H)
    s_t = jnp.moveaxis(s_chunk, 1, 0)                           # (NC,B,H,P,N)
    h_final, h_prevs = jax.lax.scan(step, h0, (dec_t, s_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                       # (B,NC,H,P,N)

    # inter-chunk contribution: y_t += (C_t . h_prev) * exp(s_t)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp",
                         (cc * jnp.exp(s)[..., None]).astype(x.dtype),
                         h_prevs.astype(x.dtype))
    y = y_intra + y_inter + \
        xc * d_skip[None, None, None, :, None].astype(x.dtype)
    return y.reshape(bsz, l, h, p), h_final


def ssd_recurrent_step(x, dt, a, b, c, d_skip, h):
    """O(1) decode update. x:(B,H,P) dt:(B,H) b,c:(B,G,N) h:(B,H,P,N)."""
    bsz, nh, p = x.shape
    g = b.shape[1]
    rep = nh // g
    bb = jnp.repeat(b, rep, axis=1)                 # (B,H,N)
    cc = jnp.repeat(c, rep, axis=1)
    dec = jnp.exp(dt * a)                           # (B,H)
    upd = jnp.einsum("bh,bhn,bhp->bhpn", dt, bb, x)
    h_new = h * dec[:, :, None, None] + upd.astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", h_new.astype(x.dtype), cc)
    return y + x * d_skip[None, :, None].astype(x.dtype), h_new


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array,
                 conv_state: jax.Array | None = None):
    """Depthwise causal conv over seq. xbc: (B, L, C); w: (K, C).
    Returns (out, new_conv_state=(B, K-1, C))."""
    k = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i][None, None, :].astype(
        xbc.dtype) for i in range(k))
    out = out + bias[None, None, :].astype(xbc.dtype)
    new_state = xp[:, -(k - 1):, :] if k > 1 else pad
    return jax.nn.silu(out), new_state


def mamba2_block(params: dict, x: jax.Array, cfg, *,
                 state: SSMState | None = None, chunk: int = 256,
                 shard=Identity, use_kernel: bool = False):
    """x: (B, L, D) (prefill/train) or (B, 1, D) with state (decode).
    Returns (out, new_state)."""
    d_inner, n_heads, conv_dim = ssd_dims(
        x.shape[-1], cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups,
        cfg.ssm_state)
    gn = cfg.ssm_groups * cfg.ssm_state
    meta = {"d_inner": d_inner, "gn": gn, "n_heads": n_heads}
    bsz, l, _ = x.shape
    zxbcdt = dense(params["in_proj"], x)
    z, xbc, dt = _split_proj(meta, zxbcdt)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["a_log"])                   # (H,) negative

    decode = state is not None and l == 1
    conv_state = state.conv if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"],
                                 conv_state)
    xs = xbc[..., :d_inner].reshape(bsz, l, n_heads, cfg.ssm_head_dim)
    bmat = xbc[..., d_inner:d_inner + gn].reshape(
        bsz, l, cfg.ssm_groups, cfg.ssm_state)
    cmat = xbc[..., d_inner + gn:].reshape(
        bsz, l, cfg.ssm_groups, cfg.ssm_state)
    xs = shard("ssm_x", xs)

    if decode:
        y, h_new = ssd_recurrent_step(
            xs[:, 0], dt[:, 0], a, bmat[:, 0], cmat[:, 0],
            params["d_skip"], state.h)
        y = y[:, None]
    else:
        h0 = state.h if state is not None else None
        pad_to = (-l) % chunk
        if pad_to:
            padc = lambda t: jnp.pad(t, [(0, 0), (0, pad_to)] +
                                     [(0, 0)] * (t.ndim - 2))
            xs, dt = padc(xs), padc(dt)
            bmat, cmat = padc(bmat), padc(cmat)
        y, h_new = ssd_chunked(xs, dt, a, bmat, cmat, params["d_skip"],
                               chunk=min(chunk, xs.shape[1]), h0=h0,
                               use_kernel=use_kernel)
        y = y[:, :l]
    y = y.reshape(bsz, l, d_inner)
    y = rms_norm(params["norm"], y * jax.nn.silu(z.astype(y.dtype)))
    out = dense(params["out_proj"], y)
    return out, SSMState(h=h_new, conv=new_conv)


def init_ssm_state(batch: int, cfg, d_model: int,
                   dtype=jnp.float32) -> SSMState:
    d_inner, n_heads, conv_dim = ssd_dims(
        d_model, cfg.ssm_expand, cfg.ssm_head_dim, cfg.ssm_groups,
        cfg.ssm_state)
    return SSMState(
        h=jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype))
