"""Model -> workload frontend: lower a ``ModelConfig`` under a ``ShapeSpec``
into the exact GEMM loop-nest list MIREDO optimizes (DESIGN.md §Model
frontend).

This is the bridge `workload.py` promises: every weight-bearing matmul of
every registry architecture — GQA attention projections, (gated) FFN mats,
top-k-routed MoE expert GEMMs, SSD block matmuls, the LM head — becomes a
`workload.Layer` with a network multiplicity, and the whole model flows
through the network pipeline (`core/network.py`): structurally identical
GEMMs across depth, batch and even scenarios dedup to one MIP solve each.

Scenario semantics (`configs.base.ShapeSpec`): prefill/train GEMMs carry
the sequence length as the M dim and the batch as multiplicity; a decode
step carries M = global_batch (one token per sequence, batched into one
MVM). Decode-vs-prefill GEMMs therefore differ only in M, and everything
downstream of the projections (weights, reduction dims) is shared.

    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.core.frontend import extract_workload, optimize_model

    work = extract_workload(get_config("glm4-9b"), SHAPES["decode_32k"])
    res = optimize_model(get_config("glm4-9b"), SHAPES["decode_32k"],
                         default_arch())          # -> NetworkResult
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (ModelConfig, ShapeSpec, SHAPES,
                                applicable_shapes)
from repro.core import workload as wl
from repro.core.lm_workloads import (Emitted, attn_gemms, ffn_gemms,
                                     lm_head_gemm, moe_gemms, ssd_gemms)


@dataclasses.dataclass(frozen=True)
class ModelWorkload:
    """One model under one scenario, lowered to (Layer, count) pairs."""

    model: str
    scenario: str
    layers: tuple[wl.Layer, ...]
    counts: tuple[int, ...]

    def __post_init__(self):
        assert len(self.layers) == len(self.counts)
        assert all(c >= 1 for c in self.counts), (self.model, self.scenario)

    def __len__(self) -> int:
        return len(self.layers)

    @property
    def total_macs(self) -> int:
        """Multiplicity-weighted MACs of the whole network."""
        return sum(l.macs * c for l, c in zip(self.layers, self.counts))

    @property
    def n_unique(self) -> int:
        from repro.core.network import dedup_layers
        return len(dedup_layers(list(self.layers))[0])


def _attn_block(prefix: str, cfg: ModelConfig, m: int, *, count: int,
                kv_m: int | None = None) -> Emitted:
    return attn_gemms(prefix, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim, m, kv_m=kv_m, count=count)


def _mlp_block(prefix: str, cfg: ModelConfig, m: int, *,
               count: int) -> Emitted:
    """Dense FFN or MoE (routed + shared + arctic's dense residual)."""
    if cfg.n_experts:
        out = moe_gemms(prefix, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                        cfg.n_shared_experts, cfg.top_k, m,
                        gated=cfg.gated_mlp, count=count)
        if cfg.dense_residual:
            out += ffn_gemms(prefix + ".res", cfg.d_model, cfg.d_ff, m,
                             gated=cfg.gated_mlp, count=count)
        return out
    return ffn_gemms(prefix, cfg.d_model, cfg.d_ff, m, gated=cfg.gated_mlp,
                     count=count)


def _ssd_block(prefix: str, cfg: ModelConfig, m: int, *, decode: bool,
               count: int) -> Emitted:
    return ssd_gemms(prefix, cfg.d_model, expand=cfg.ssm_expand,
                     head_dim=cfg.ssm_head_dim, groups=cfg.ssm_groups,
                     state=cfg.ssm_state, m=m, decode=decode, count=count)


def extract_workload(cfg: ModelConfig, spec: ShapeSpec) -> ModelWorkload:
    """Lower ``cfg`` under ``spec`` to the full weight-GEMM workload.

    Family lowering rules (DESIGN.md §Model frontend):

      dense      per layer: GQA attn projections + (gated) FFN
      moe        per layer: attn + top-k routed expert GEMMs (+ shared
                 experts, + arctic's dense-residual MLP)
      ssm        per layer: SSD block (projections + duality matmuls)
      hybrid     n_layers SSD blocks + ONE parameter-shared attention+MLP
                 block *executed* every ``attn_every`` layers (shared
                 params, repeated compute -> count = n_layers//attn_every)
      encdec     encoder self-attn+FFN over the frontend sequence, decoder
                 self-attn + cross-attn (K/V project the encoder memory;
                 cached at decode) + FFN
      vlm        dense decoder over text + prepended patch embeddings at
                 prefill/train; decode is text-only

    Plus the LM head for every family. Embedding lookups, norms, rotary,
    softmax, depthwise convs and attention score matmuls are non-MVM work
    (SIMD / attention unit) and are not extracted.

    ``kind="train"`` additionally appends the backward pass: one dGrad +
    one wGrad GEMM per forward GEMM in reversed order
    (`training.backward_gemms` — transposed dims, MoE wGrads scaled to
    the experts actually hit, LM head at M = every position).
    """
    m, inst = spec.m_tokens, spec.instance_count
    decode = spec.is_decode
    fam = cfg.family
    name = cfg.name
    out: Emitted = []

    if fam in ("dense", "moe", "vlm"):
        m_blk = m
        if fam == "vlm" and not decode and cfg.frontend_seq:
            m_blk = m + cfg.frontend_seq      # patch tokens prepended
        per = cfg.n_layers * inst
        out += _attn_block(f"{name}.blk", cfg, m_blk, count=per)
        out += _mlp_block(f"{name}.blk", cfg, m_blk, count=per)
    elif fam == "ssm":
        out += _ssd_block(f"{name}.blk", cfg, m, decode=decode,
                          count=cfg.n_layers * inst)
    elif fam == "hybrid":
        out += _ssd_block(f"{name}.blk", cfg, m, decode=decode,
                          count=cfg.n_layers * inst)
        n_apply = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        if n_apply:
            out += _attn_block(f"{name}.shared", cfg, m,
                               count=n_apply * inst)
            out += ffn_gemms(f"{name}.shared", cfg.d_model, cfg.d_ff, m,
                             gated=cfg.gated_mlp, count=n_apply * inst)
    elif fam == "encdec":
        m_enc = cfg.frontend_seq or m
        if not decode:
            per_enc = cfg.encoder_layers * inst
            out += _attn_block(f"{name}.enc", cfg, m_enc, count=per_enc)
            out += ffn_gemms(f"{name}.enc", cfg.d_model, cfg.d_ff, m_enc,
                             gated=cfg.gated_mlp, count=per_enc)
        per = cfg.n_layers * inst
        out += _attn_block(f"{name}.dec", cfg, m, count=per)
        # cross-attention: K/V project the encoder memory (cached at
        # decode -> kv_m=0 skips them), Q/O project the decoder stream
        out += _attn_block(f"{name}.xattn", cfg, m,
                           kv_m=0 if decode else m_enc, count=per)
        out += ffn_gemms(f"{name}.dec", cfg.d_model, cfg.d_ff, m,
                         gated=cfg.gated_mlp, count=per)
    else:
        raise ValueError(fam)

    # LM head: training computes logits (and loss) at every position, but
    # a serving prefill only materializes the last position's logits
    # (`train/steps.make_prefill_step`); a decode step already has one
    # token per sequence in M.
    m_head = 1 if spec.kind == "prefill" else m
    out += lm_head_gemm(name, cfg.d_model, cfg.padded_vocab(), m_head,
                        count=inst)

    # Training expands every forward GEMM into its dGrad + wGrad pair
    # (reversed order, transposed dims, written-residency wGrads) — see
    # `core/training.py`; the optimizer-step traffic is priced separately
    # (`training.optimizer_update_cost`), not lowered as layers.
    if spec.kind == "train":
        from repro.core.training import backward_gemms
        out += backward_gemms(out, cfg, spec)
    layers, counts = zip(*out)
    return ModelWorkload(model=name, scenario=spec.name, layers=layers,
                         counts=counts)


def extract_all(cfg: ModelConfig,
                scenarios: tuple[str | ShapeSpec, ...] | None = None
                ) -> dict[str, ModelWorkload]:
    """Every applicable scenario's workload (``None`` cells skipped).

    ``scenarios`` entries are ShapeSpec *names* (filtering the registered
    SHAPES cells; unknown names raise — a typo must not silently produce
    an empty, green benchmark run) or ad-hoc ``ShapeSpec`` objects, e.g.
    the serving engine's per-iteration batch compositions
    (``ShapeSpec.serving_iteration``), keyed by their own name."""
    names: set[str] = set()
    extra: list[ShapeSpec] = []
    if scenarios:
        for s in scenarios:
            (extra.append if isinstance(s, ShapeSpec) else names.add)(s)
        unknown = names - set(SHAPES)
        if unknown:
            raise KeyError(f"unknown scenario(s) {sorted(unknown)}; "
                           f"known: {sorted(SHAPES)}")
    out = {}
    for sname, spec in applicable_shapes(cfg).items():
        if spec is None or (scenarios and sname not in names):
            continue
        out[sname] = extract_workload(cfg, spec)
    for spec in extra:
        out[spec.name] = extract_workload(cfg, spec)
    return out


def optimize_model(cfg: ModelConfig, spec: ShapeSpec, arch,
                   mode: str = "miredo", **net_kwargs):
    """Extract + run the network pipeline; returns a ``NetworkResult``."""
    from repro.core.network import optimize_network
    work = extract_workload(cfg, spec)
    return optimize_network(list(work.layers), arch, mode,
                            counts=list(work.counts), **net_kwargs)
