"""MIREDO core: the paper's contribution (arch abstraction, flexible
factorization, analytical latency model, MIP formulation, baselines)."""

from repro.core.arch import CimArch, default_arch, INPUT, WEIGHT, OUTPUT
from repro.core.workload import Layer, conv, gemm
from repro.core.mapping import Mapping
from repro.core.frontend import (ModelWorkload, extract_all,
                                 extract_workload, optimize_model)
from repro.core.scheduler import Schedule, schedule_network
