from repro.core.mip.model import LinExpr, MipModel, Status, Var
