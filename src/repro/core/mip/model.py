"""A small MIP modeling layer over ``scipy.optimize.milp`` (HiGHS).

Plays the role Gurobi's Python API plays in the paper: named variables,
linear constraints, big-M indicator constraints, one-hot selections and
AND/OR linearizations. Everything compiles to one sparse LinearConstraint
block; HiGHS runs exact branch-and-bound with a wall-clock cap (the paper
caps Gurobi at 5 min/layer; we default lower).
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Iterable, Sequence

import numpy as np
import scipy.sparse as sp
from scipy.optimize import Bounds, LinearConstraint, milp


class Status(enum.Enum):
    OPTIMAL = 0
    FEASIBLE = 1          # time-capped incumbent
    INFEASIBLE = 2
    UNBOUNDED = 3
    ERROR = 4
    #: HiGHS reported a status outside the documented 0-3 range (e.g.
    #: scipy's 4 = "numerical trouble") but still handed back an
    #: assignment. The incumbent may violate constraints beyond feasibility
    #: tolerances, so callers must re-validate/re-score it before trusting
    #: it — exactly what `formulation.optimize_layer` does (decode ->
    #: `mapping.validate` -> `latency.evaluate`, never-worse-than-incumbent
    #: fallback). Mapped distinctly so such solves are *flagged* instead of
    #: silently passing as FEASIBLE.
    SUSPECT = 5


def status_of(raw_status: int, has_solution: bool) -> Status:
    """Map a scipy ``milp`` result status to `Status`.

    scipy documents 0=optimal, 1=iteration/time limit, 2=infeasible,
    3=unbounded, 4=other (e.g. numerical trouble). A limit-stopped solve
    with an incumbent is FEASIBLE; any *undocumented* status that still
    carries an assignment is SUSPECT (not FEASIBLE — see `Status.SUSPECT`);
    no assignment at all is ERROR. Pinned by
    ``tests/test_portfolio.py::test_status_mapping_table``."""
    if raw_status == 0:
        return Status.OPTIMAL
    if raw_status == 1:
        return Status.FEASIBLE if has_solution else Status.ERROR
    if raw_status == 2:
        return Status.INFEASIBLE
    if raw_status == 3:
        return Status.UNBOUNDED
    return Status.SUSPECT if has_solution else Status.ERROR


@dataclasses.dataclass(frozen=True)
class Var:
    idx: int
    name: str
    is_int: bool

    # Arithmetic sugar -> LinExpr
    def __mul__(self, k: float) -> "LinExpr":
        return LinExpr({self.idx: float(k)}, 0.0)

    __rmul__ = __mul__

    def __add__(self, other) -> "LinExpr":
        return LinExpr({self.idx: 1.0}, 0.0) + other

    __radd__ = __add__

    def __sub__(self, other) -> "LinExpr":
        return LinExpr({self.idx: 1.0}, 0.0) - other

    def __rsub__(self, other) -> "LinExpr":
        return LinExpr.of(other) - LinExpr({self.idx: 1.0}, 0.0)

    def __neg__(self) -> "LinExpr":
        return LinExpr({self.idx: -1.0}, 0.0)


@dataclasses.dataclass
class LinExpr:
    coef: dict[int, float]
    const: float = 0.0

    @staticmethod
    def of(x) -> "LinExpr":
        if isinstance(x, LinExpr):
            return x
        if isinstance(x, Var):
            return LinExpr({x.idx: 1.0}, 0.0)
        return LinExpr({}, float(x))

    def __add__(self, other) -> "LinExpr":
        o = LinExpr.of(other)
        c = dict(self.coef)
        for k, v in o.coef.items():
            c[k] = c.get(k, 0.0) + v
        return LinExpr(c, self.const + o.const)

    __radd__ = __add__

    def __sub__(self, other) -> "LinExpr":
        o = LinExpr.of(other)
        return self + LinExpr({k: -v for k, v in o.coef.items()}, -o.const)

    def __rsub__(self, other) -> "LinExpr":
        return LinExpr.of(other) - self

    def __mul__(self, k: float) -> "LinExpr":
        return LinExpr({i: v * k for i, v in self.coef.items()},
                       self.const * k)

    __rmul__ = __mul__

    def __neg__(self) -> "LinExpr":
        return self * -1.0


class MipModel:
    def __init__(self, name: str = "model"):
        self.name = name
        self._lb: list[float] = []
        self._ub: list[float] = []
        self._int: list[bool] = []
        self._names: list[str] = []
        # constraint triplets
        self._rows: list[dict[int, float]] = []
        self._rlb: list[float] = []
        self._rub: list[float] = []
        self._obj: dict[int, float] = {}
        self._obj_const = 0.0

    # ---- variables --------------------------------------------------------
    def add_var(self, name: str, lb: float = 0.0, ub: float = math.inf,
                integer: bool = False) -> Var:
        self._names.append(name)
        self._lb.append(lb)
        self._ub.append(ub)
        self._int.append(integer)
        return Var(len(self._names) - 1, name, integer)

    def add_binary(self, name: str) -> Var:
        return self.add_var(name, 0.0, 1.0, integer=True)

    def add_binaries(self, prefix: str, n: int) -> list[Var]:
        return [self.add_binary(f"{prefix}[{i}]") for i in range(n)]

    @property
    def n_vars(self) -> int:
        return len(self._names)

    @property
    def n_rows(self) -> int:
        return len(self._rows)

    # ---- constraints -------------------------------------------------------
    def _add_row(self, expr: LinExpr, lb: float, ub: float) -> None:
        self._rows.append(expr.coef)
        self._rlb.append(lb - expr.const)
        self._rub.append(ub - expr.const)

    def add_le(self, expr, rhs: float = 0.0) -> None:
        e = LinExpr.of(expr)
        self._add_row(e, -math.inf, rhs)

    def add_ge(self, expr, rhs: float = 0.0) -> None:
        e = LinExpr.of(expr)
        self._add_row(e, rhs, math.inf)

    def add_eq(self, expr, rhs: float = 0.0) -> None:
        e = LinExpr.of(expr)
        self._add_row(e, rhs, rhs)

    def add_indicator_le(self, binary: Var, expr, rhs: float,
                         big_m: float) -> None:
        """binary == 1  ->  expr <= rhs   (big-M)."""
        e = LinExpr.of(expr) + big_m * binary
        self._add_row(e, -math.inf, rhs + big_m)

    def add_indicator_ge(self, binary: Var, expr, rhs: float,
                         big_m: float) -> None:
        """binary == 1  ->  expr >= rhs   (big-M)."""
        e = LinExpr.of(expr) - big_m * binary
        self._add_row(e, rhs - big_m, math.inf)

    # ---- logical helpers ----------------------------------------------------
    def add_and(self, name: str, terms: Sequence[Var]) -> Var:
        z = self.add_binary(name)
        for t in terms:
            self.add_le(z - t, 0.0)                      # z <= t
        # z >= sum(t) - (n-1)
        self.add_le(sum(terms, LinExpr({}, 0.0)) - z, len(terms) - 1)
        return z

    def add_or(self, name: str, terms: Sequence[Var]) -> Var:
        z = self.add_binary(name)
        for t in terms:
            self.add_ge(z - t, 0.0)                      # z >= t
        self.add_le(z - sum(terms, LinExpr({}, 0.0)), 0.0)
        return z

    def add_max_ge(self, out: Var, exprs: Iterable) -> None:
        """out >= each expr; exact under minimization pressure."""
        for e in exprs:
            self.add_ge(out - LinExpr.of(e), 0.0)

    def add_one_hot(self, prefix: str, n: int, active=1) -> list[Var]:
        vs = self.add_binaries(prefix, n)
        e = sum(vs, LinExpr({}, 0.0))
        if isinstance(active, (int, float)):
            self.add_eq(e, float(active))
        else:
            self.add_eq(e - active, 0.0)
        return vs

    def add_choice(self, prefix: str, options: Sequence) -> dict:
        """One-hot selection over arbitrary hashable options: one binary
        per option, exactly one active. Returns ``{option: Var}`` so
        callers index by the option itself (e.g. a ``(chip, cores)`` pair
        — the mesh placement MIP, `scheduler.schedule_mesh`) instead of a
        positional list."""
        vs = {opt: self.add_binary(f"{prefix}[{opt}]") for opt in options}
        assert len(vs) == len(options), f"duplicate options in {prefix}"
        self.add_eq(sum(vs.values(), LinExpr({}, 0.0)), 1.0)
        return vs

    # ---- objective -----------------------------------------------------------
    def minimize(self, expr) -> None:
        e = LinExpr.of(expr)
        self._obj = dict(e.coef)
        self._obj_const = e.const

    # ---- solve -----------------------------------------------------------------
    def solve(self, time_limit_s: float = 60.0, mip_rel_gap: float = 0.01,
              verbose: bool = False, node_limit: int | None = None,
              presolve: bool | None = None):
        """``node_limit`` caps branch-and-bound nodes (a *deterministic*
        termination criterion — the solver portfolio's determinism lever,
        `core/portfolio.py`); ``presolve`` toggles HiGHS presolve (None =
        solver default, i.e. on)."""
        n = self.n_vars
        c = np.zeros(n)
        for i, v in self._obj.items():
            c[i] = v
        if self._rows:
            data, ri, ci = [], [], []
            for r, row in enumerate(self._rows):
                for i, v in row.items():
                    ri.append(r)
                    ci.append(i)
                    data.append(v)
            a = sp.csr_matrix((data, (ri, ci)),
                              shape=(len(self._rows), n))
            constraints = LinearConstraint(a, np.array(self._rlb),
                                           np.array(self._rub))
        else:
            constraints = ()
        # a negative limit would reach HiGHS as "unlimited" — clamp
        options = {"time_limit": max(0.0, time_limit_s),
                   "mip_rel_gap": mip_rel_gap,
                   "disp": verbose}
        if node_limit is not None:
            options["node_limit"] = int(node_limit)
        if presolve is not None:
            options["presolve"] = bool(presolve)
        res = milp(
            c=c,
            constraints=constraints,
            integrality=np.array([1 if b else 0 for b in self._int]),
            bounds=Bounds(np.array(self._lb), np.array(self._ub)),
            options=options,
        )
        status = status_of(res.status, res.x is not None)
        gap = getattr(res, "mip_gap", math.nan)
        return Solution(status=status,
                        objective=(res.fun + self._obj_const)
                        if res.fun is not None else math.nan,
                        values=res.x, model=self,
                        mip_gap=float(gap) if gap is not None else math.nan,
                        raw_status=int(res.status),
                        mip_node_count=_opt_float(
                            getattr(res, "mip_node_count", None)),
                        mip_dual_bound=_opt_float(
                            getattr(res, "mip_dual_bound", None)))


def _opt_float(v) -> float:
    return float(v) if v is not None else math.nan


@dataclasses.dataclass
class Solution:
    status: Status
    objective: float
    values: np.ndarray | None
    model: MipModel
    mip_gap: float = math.nan
    #: scipy's untranslated result status — kept so a SUSPECT solve's
    #: origin (e.g. 4 = numerical trouble) stays inspectable.
    raw_status: int = -1
    #: branch-and-bound nodes explored / best dual (lower) bound at
    #: termination; NaN when HiGHS did not report them. These make a losing
    #: portfolio member explainable: few nodes + weak bound = starved,
    #: many nodes + tight bound = the region genuinely holds nothing
    #: better (`core/portfolio.py`).
    mip_node_count: float = math.nan
    mip_dual_bound: float = math.nan

    def __getitem__(self, var: Var) -> float:
        assert self.values is not None
        return float(self.values[var.idx])

    def binary(self, var: Var) -> bool:
        return self[var] > 0.5

    @property
    def ok(self) -> bool:
        """Trustworthy solve: OPTIMAL or a limit-stopped FEASIBLE incumbent.
        Deliberately excludes SUSPECT so consumers that use the assignment
        *without* independent re-validation (the scheduler/mesh placement
        MIPs, `mip_latency_of`) treat numerical-trouble solves as failures
        and take their fallback path."""
        return self.status in (Status.OPTIMAL, Status.FEASIBLE) and \
            self.values is not None

    @property
    def usable(self) -> bool:
        """``ok`` plus SUSPECT-with-assignment: for callers that re-validate
        and re-score the decoded result independently before trusting it
        (`formulation.optimize_layer`'s decode -> validate -> evaluate ->
        never-worse-than-incumbent path, which stays authoritative)."""
        return self.ok or (self.status is Status.SUSPECT
                           and self.values is not None)
