"""Batched analytical model: score N mappings of one (layer, arch) per
dispatch (DESIGN.md §Batched analytical model).

``latency.evaluate`` / ``energy.evaluate_edp`` are scalar Python called once
per candidate, which makes every optimization pass — stochastic search, DSE
screening, the MIP warm-start incumbents — evaluation-bound. This module
packs a whole candidate pool into fixed-shape arrays and replays the exact
same arithmetic vectorized over the batch:

  * the Table III recursion runs as a ``lax.scan`` over the (right-aligned,
    identity-padded) slot axis with the three per-operand rows unrolled in
    ``OPERANDS`` order,
  * one-time fills, energy traffic, the idealized perfect-overlap bound and
    the eq. (9) capacity feasibility are left-folds over padded hop/level
    axes in the scalar evaluation order.

The scalar model remains the oracle: packing reads the *shared* slot
analysis (`latency.operand_transfer_table` via ``analyze_slots`` /
``operand_fill_hops``, `energy.operand_energy_hops`,
`latency.idealized_terms`, `mapping.capacity_usage`), every float op is
replayed in the scalar order under float64 (``jax.experimental.enable_x64``),
and padding is provably inert (an identity slot — n=1, no transfers — maps
the P vector through unchanged; padded hops add ``+ 0.0``). Total cycles,
energy and EDP are therefore *bit-equal* to the scalar oracle, which the
differential sweep in ``tests/test_latency_batched.py`` enforces.

``feasible`` covers the eq. (9) capacity clause only — the one clause a
sampler-constructed candidate (`baselines.sample_mapping_raw`) can violate;
structural legality (factor products, spatial axis membership, monotone
level assignment, C^M) holds for such candidates by construction. For
arbitrary mappings run ``mapping.validate`` instead.

JAX is optional at runtime: without it (or with ``backend="numpy"``) a
NumPy reference loop evaluates the identical IEEE-754 operation sequence.
On CPU the two backends agree bitwise; the jitted path amortizes dispatch
over the batch (recompiles are bounded by bucketing the slot axis to
multiples of 4).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import workload as wl
from repro.core.arch import CimArch, OPERANDS
from repro.core.energy import operand_energy_hops
from repro.core.latency import (analyze_slots, idealized_terms,
                                operand_fill_hops, operand_transfer_table)
from repro.core.mapping import Mapping, capacity_usage, size_context

try:                                                    # pragma: no cover
    import jax
    from jax.experimental import enable_x64 as _enable_x64
    HAVE_JAX = True
except Exception:                                       # pragma: no cover
    jax = None
    HAVE_JAX = False

#: Auto-backend cutover: below this pool size the NumPy reference loop wins
#: (per-dispatch jit overhead dominates); above it the jitted scan wins.
#: Both backends are bit-identical, so this is purely a speed knob.
_JAX_MIN_BATCH = 256

#: Everything the packer can materialize; trim to skip host-side analysis
#: work the consumer does not need (e.g. the idealized-model heuristic pass
#: needs no latency/energy packing).
ALL_NEEDS = ("latency", "energy", "ideal", "feasible")


@dataclasses.dataclass
class PackedBatch:
    """N mappings of one (layer, arch) as fixed-shape float64 arrays.

    Slot arrays are right-aligned: real slots occupy the *trailing*
    positions so the reverse (innermost-first) recursion processes them
    first and the leading identity padding (n=1, t=0) afterwards — which
    leaves the P vector untouched. Hop/term axes pad with zeros at the end.
    """

    mappings: list[Mapping]
    layer: wl.Layer
    arch: CimArch
    need: tuple[str, ...]
    nf: np.ndarray          # (B,S) slot factors, pad 1.0
    t: np.ndarray           # (B,S,3) T_{i,λ} cycles, pad 0.0
    dbl: np.ndarray         # (B,S,3) psi^DL, pad False
    fill_c: np.ndarray      # (B,L,3) untriggered one-time fill cycles
    e_term: np.ndarray      # (B,L,3) per-hop traffic pJ (bytes x pJ/byte,
                            # multiplied at pack time: a fused multiply-add
                            # inside the jitted fold would round differently
                            # than the scalar oracle's separate mul-then-add)
    ideal_num: np.ndarray   # (B,3L) idealized iters*chunk, pad 0.0
    ideal_bw: np.ndarray    # (B,3L) idealized eff bandwidth, pad 1.0
    compute: np.ndarray     # (B,) temporal_iters * l_mvm
    sizes: np.ndarray       # (B,Lc,3) (1+psi^DM)*stored bytes, pad 0.0
    caps: np.ndarray        # (B,Lc) effective capacity bytes
    shared: np.ndarray      # (Lc,) level-shared flags (arch constant)
    gated: bool = False     # infeasible rows hold padding; scores -> inf

    @property
    def batch(self) -> int:
        return len(self.mappings)


@dataclasses.dataclass
class BatchScores:
    """Per-mapping scores; fields are ``None`` when not packed (``need``)."""

    cycles: np.ndarray | None       # latency.evaluate total_cycles
    energy_pj: np.ndarray | None    # energy.evaluate_energy total_pj
    edp: np.ndarray | None          # evaluate_edp edp
    idealized: np.ndarray | None    # latency.idealized_cycles
    feasible: np.ndarray | None     # eq. (9) capacity clause (bool)


def _slot_width(n: int) -> int:
    """Bucket the slot axis to multiples of 4 so the jitted evaluator sees
    a handful of shapes across a run instead of one per pool."""
    return max(4, -(-n // 4) * 4)


def _batch_width(b: int) -> int:
    """Bucket the batch axis to the next power of two (>= 16) so varying
    pool sizes reuse a handful of jit-compiled shapes; the evaluator pads
    by replicating row 0 and slices the results back to the real batch."""
    w = 16
    while w < b:
        w *= 2
    return w


def pack(mappings: Sequence[Mapping], layer: wl.Layer, arch: CimArch, *,
         need: Sequence[str] = ALL_NEEDS) -> PackedBatch:
    """Pack mappings into fixed-shape arrays via the shared slot analysis.

    When ``need`` includes "feasible", packing is *gated*: rows whose
    eq. (9) capacity check fails (the same comparison the evaluator
    replays) skip the latency/energy/idealized analysis entirely — the
    dominant cost on sampled pools, where most candidates are infeasible —
    and their scores come back as ``inf``. Feasible rows stay bit-equal to
    the scalar oracle. Omit "feasible" from ``need`` to force full packing
    of every row."""
    mappings = list(mappings)
    B, L = len(mappings), arch.n_levels
    S = _slot_width(max((mp.n_slots() for mp in mappings), default=1))
    K = 3 * L
    need = tuple(need)

    bounded = [m for m in range(L)
               if arch.level(m).capacity_bytes is not None]
    Lc = len(bounded)
    shared = np.array([arch.level(m).shared for m in bounded], dtype=bool)

    w_lat = "latency" in need
    w_en = "energy" in need
    w_id = "ideal" in need
    w_fe = "feasible" in need
    pad3 = [0.0, 0.0, 0.0]
    lam0, lam1, lam2 = OPERANDS
    shared_flag = [arch.level(m).shared for m in bounded]
    nf_l, t_l, dbl_l = [], [], []
    fill_l, e_l, num_l, bw_l, comp_l = [], [], [], [], []
    sz_l, cap_l = [], []
    packed_idx = []     # rows with analysis data (all rows when ungated)

    for b, mp in enumerate(mappings):
        # one memoized size table per mapping, shared by every analysis pass
        ctx = size_context(mp, layer, arch)
        row_ok = True
        if w_fe:
            usage = capacity_usage(mp, layer, arch, ctx)
            cap_row, sz_row = [], []
            for k, (_m, cap, sz) in enumerate(usage):
                s0 = sz.get(lam0, 0.0)
                s1 = sz.get(lam1, 0.0)
                s2 = sz.get(lam2, 0.0)
                cap_row.append(cap)
                sz_row.append([s0, s1, s2])
                # replay the evaluator's exact comparison (same floats,
                # same fold order) so gating can never disagree with the
                # `feasible` output
                if row_ok:
                    tol = cap + 1e-9
                    if shared_flag[k]:
                        row_ok = (s0 + s1) + s2 <= tol
                    else:
                        row_ok = s0 <= tol and s1 <= tol and s2 <= tol
            cap_l.append(cap_row)
            sz_l.append(sz_row)
            if not row_ok:
                # gated: the row keeps its identity/zero padding (supplied
                # by the preallocated arrays below) and scores inf on read
                continue
        packed_idx.append(b)
        if w_lat:
            tables = {lam: operand_transfer_table(mp, layer, arch, lam, ctx)
                      for lam in OPERANDS}
            slots = analyze_slots(mp, layer, arch, tables)
            off = S - len(slots)
            nf_l.append([1.0] * off + [float(s.n) for s in slots])
            t_l.append([pad3] * off
                       + [[s.transfer[lam] for lam in OPERANDS]
                          for s in slots])
            dbl_l.append([[False] * 3] * off
                         + [[s.double[lam] for lam in OPERANDS]
                            for s in slots])
            row = [[0.0] * 3 for _ in range(L)]
            for j, lam in enumerate(OPERANDS):
                h = 0
                for trig, cyc in operand_fill_hops(mp, layer, arch, lam,
                                                   tables[lam]):
                    if not trig:
                        row[h][j] = cyc
                        h += 1
            fill_l.append(row)
        if w_en:
            row = [[0.0] * 3 for _ in range(L)]
            for j, lam in enumerate(OPERANDS):
                for h, (tb, e) in enumerate(
                        operand_energy_hops(mp, layer, arch, lam, ctx)):
                    row[h][j] = tb * e
            e_l.append(row)
        if w_id:
            comp, terms = idealized_terms(mp, layer, arch, ctx)
            comp_l.append(float(comp))
            num_l.append([n for n, _ in terms] + [0.0] * (K - len(terms)))
            bw_l.append([w for _, w in terms] + [1.0] * (K - len(terms)))

    # preallocate identity padding; scatter the packed rows into place
    idx = np.array(packed_idx, dtype=np.intp)
    nf = np.ones((B, S))
    t = np.zeros((B, S, 3))
    dbl = np.zeros((B, S, 3), dtype=bool)
    fill_c = np.zeros((B, L, 3))
    e_term = np.zeros((B, L, 3))
    ideal_num = np.zeros((B, K))
    ideal_bw = np.ones((B, K))
    compute = np.zeros(B)
    sizes = np.zeros((B, Lc, 3))
    caps = np.full((B, Lc), np.inf)
    if len(idx):
        if w_lat:
            nf[idx] = nf_l
            t[idx] = t_l
            dbl[idx] = dbl_l
            fill_c[idx] = fill_l
        if w_en:
            e_term[idx] = e_l
        if w_id:
            ideal_num[idx] = num_l
            ideal_bw[idx] = bw_l
            compute[idx] = comp_l
    if w_fe and B:
        sizes[:] = np.array(sz_l).reshape(B, Lc, 3)
        caps[:] = np.array(cap_l).reshape(B, Lc)

    return PackedBatch(mappings=mappings, layer=layer, arch=arch, need=need,
                       nf=nf, t=t, dbl=dbl, fill_c=fill_c, e_term=e_term,
                       ideal_num=ideal_num, ideal_bw=ideal_bw,
                       compute=compute, sizes=sizes, caps=caps,
                       shared=shared, gated=bool(w_fe))


# ---------------------------------------------------------------------------
# Evaluation backends — identical IEEE-754 op sequences
# ---------------------------------------------------------------------------

#: Operand classes of the Table III rows, in OPERANDS order: I and W share
#: the single/double-buffered rows; O has its own pair.
_IS_IW = (True, True, False)


def _recursion_step(xp, carry, nf_i, t_i, dbl_i):
    """One slot of the Table III recursion, operands unrolled in scalar
    order. ``xp`` is ``numpy`` or ``jax.numpy``; shapes (B,) / (B,3)."""
    l_next, n_next, p_next = carry
    combined = xp.zeros_like(l_next)
    for j in range(3):
        tj, pj, dj = t_i[:, j], p_next[:, j], dbl_i[:, j]
        br = xp.where(tj == 0.0, pj,
                      xp.where(dj, xp.maximum(tj, pj), tj + pj))
        combined = xp.maximum(combined, br)
    l_i = xp.maximum(l_next * n_next, combined)
    ps = []
    for j, iw in enumerate(_IS_IW):
        tj, pj, dj = t_i[:, j], p_next[:, j], dbl_i[:, j]
        no_t = l_i * xp.maximum(nf_i - 1.0, 0.0) + pj
        if iw:
            single = l_i * xp.maximum(nf_i - 2.0, 0.0) + 2.0 * tj + pj
            double = xp.maximum(
                l_i * xp.maximum(nf_i - 3.0, 0.0) + 2.0 * tj
                + xp.maximum(tj, pj), tj * nf_i)
        else:
            single = l_i * xp.maximum(nf_i - 1.0, 0.0) + 2.0 * tj + pj
            double = l_i * xp.maximum(nf_i - 2.0, 0.0) + tj \
                + xp.maximum(tj, l_i) + xp.maximum(tj, pj)
        ps.append(xp.where(tj == 0.0, no_t, xp.where(dj, double, single)))
    return l_i, nf_i, xp.stack(ps, axis=1)


def _aggregate(xp, p_final, fill_c, e_term, ideal_num, ideal_bw,
               compute, sizes, caps, shared, mac_pj):
    """Post-recursion left-folds, all in the scalar evaluation order."""
    p_max = xp.maximum(xp.maximum(p_final[:, 0], p_final[:, 1]),
                       p_final[:, 2])
    one_time = xp.zeros_like(p_max)
    for j in range(3):
        s = xp.zeros_like(p_max)
        for h in range(fill_c.shape[1]):
            s = s + fill_c[:, h, j]
        one_time = one_time + s
    cycles = p_max + one_time

    traffic = xp.zeros_like(p_max)
    for j in range(3):
        s = xp.zeros_like(p_max)
        for h in range(e_term.shape[1]):
            s = s + e_term[:, h, j]
        traffic = traffic + s
    energy = traffic + mac_pj
    edp = energy * cycles

    ideal = compute
    for k in range(ideal_num.shape[1]):
        ideal = xp.maximum(ideal, ideal_num[:, k] / ideal_bw[:, k])

    tol = caps + 1e-9
    ssum = xp.zeros_like(caps)
    ok_each = xp.ones(caps.shape, dtype=bool)
    for j in range(3):
        ssum = ssum + sizes[:, :, j]
        ok_each = ok_each & (sizes[:, :, j] <= tol)
    ok = xp.where(shared[None, :], ssum <= tol, ok_each)
    feasible = xp.all(ok, axis=1)
    return cycles, energy, edp, ideal, feasible


def _eval_numpy(pb: PackedBatch) -> tuple:
    """Reference backend: the scalar op sequence, vectorized over B."""
    B = pb.batch
    l_mvm = float(pb.arch.l_mvm_cycles)
    carry = (np.full(B, l_mvm), np.ones(B), np.full((B, 3), l_mvm))
    for i in range(pb.nf.shape[1] - 1, -1, -1):
        carry = _recursion_step(np, carry, pb.nf[:, i], pb.t[:, i, :],
                                pb.dbl[:, i, :])
    mac_pj = pb.layer.macs * pb.arch.mac_energy_pj
    return _aggregate(np, carry[2], pb.fill_c, pb.e_term,
                      pb.ideal_num, pb.ideal_bw, pb.compute, pb.sizes,
                      pb.caps, pb.shared, mac_pj)


if HAVE_JAX:                                            # pragma: no branch
    import jax.numpy as jnp
    from jax import lax

    @jax.jit
    def _eval_jax_core(nf, t, dbl, fill_c, e_term, ideal_num,
                       ideal_bw, compute, sizes, caps, shared, l_mvm,
                       mac_pj):
        B = nf.shape[0]
        carry = (jnp.full((B,), l_mvm, dtype=jnp.float64),
                 jnp.ones((B,), dtype=jnp.float64),
                 jnp.full((B, 3), l_mvm, dtype=jnp.float64))

        def step(c, xs):
            nf_i, t_i, dbl_i = xs
            return _recursion_step(jnp, c, nf_i, t_i, dbl_i), None

        # innermost slot first: scan the slot axis in reverse
        xs = (jnp.swapaxes(nf, 0, 1), jnp.swapaxes(t, 0, 1),
              jnp.swapaxes(dbl, 0, 1))
        carry, _ = lax.scan(step, carry, xs, reverse=True)
        return _aggregate(jnp, carry[2], fill_c, e_term, ideal_num,
                          ideal_bw, compute, sizes, caps, shared, mac_pj)

    def _eval_jax(pb: PackedBatch) -> tuple:
        B = pb.batch
        Bp = _batch_width(B)

        def padb(a):
            if a.shape[0] == Bp:
                return a
            return np.concatenate(
                [a, np.repeat(a[:1], Bp - a.shape[0], axis=0)], axis=0)

        with _enable_x64():
            out = _eval_jax_core(
                padb(pb.nf), padb(pb.t), padb(pb.dbl), padb(pb.fill_c),
                padb(pb.e_term), padb(pb.ideal_num), padb(pb.ideal_bw),
                padb(pb.compute), padb(pb.sizes), padb(pb.caps),
                pb.shared, float(pb.arch.l_mvm_cycles),
                pb.layer.macs * pb.arch.mac_energy_pj)
        return tuple(np.asarray(x)[:B] for x in out)


def evaluate_batch(pb: PackedBatch, backend: str | None = None
                   ) -> BatchScores:
    """Evaluate a packed batch. ``backend``: "jax" | "numpy" | None (auto:
    jax when importable and the pool is large enough to amortize dispatch).
    Both backends execute the same float64 op sequence and return
    bit-identical arrays, so the choice never changes results."""
    if backend is None:
        backend = "jax" if HAVE_JAX and pb.batch >= _JAX_MIN_BATCH \
            else "numpy"
    if backend == "jax":
        if not HAVE_JAX:
            raise RuntimeError("jax backend requested but jax is missing")
        cyc, en, edp, ideal, feas = _eval_jax(pb)
    elif backend == "numpy":
        cyc, en, edp, ideal, feas = _eval_numpy(pb)
    else:
        raise ValueError(backend)
    if pb.gated:
        # gated packs hold identity padding in infeasible rows
        bad = ~np.asarray(feas)
        cyc, en, edp, ideal = (np.where(bad, np.inf, np.asarray(x))
                               for x in (cyc, en, edp, ideal))
    has = pb.need
    return BatchScores(
        cycles=cyc if "latency" in has else None,
        energy_pj=en if "energy" in has else None,
        edp=edp if ("latency" in has and "energy" in has) else None,
        idealized=ideal if "ideal" in has else None,
        feasible=np.asarray(feas) if "feasible" in has else None)


def score_mappings(mappings: Sequence[Mapping], layer: wl.Layer,
                   arch: CimArch, *, need: Sequence[str] = ALL_NEEDS,
                   backend: str | None = None) -> BatchScores:
    """Pack + evaluate in one call — the enumerate-then-score entry point
    used by `baselines.heuristic_search`, `dse.screen_arch` and the MIP
    warm-start incumbent pools."""
    if not mappings:
        z = np.zeros(0)
        return BatchScores(cycles=z, energy_pj=z, edp=z, idealized=z,
                           feasible=np.zeros(0, dtype=bool))
    return evaluate_batch(pack(mappings, layer, arch, need=need),
                          backend=backend)
