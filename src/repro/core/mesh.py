"""Multi-chip mesh optimization: N identical CIM chips on a parameterized
interconnect, per-layer tensor-parallel sharding, and the mesh-level
network pipeline (DESIGN.md §Mesh optimization, ROADMAP item 2).

The single-chip stack stops at `scheduler.py`'s residency packing: a model
whose weights exceed one chip's macros can never keep them resident. This
module extends the hierarchy one level up — a `MeshArch` of ``n_chips``
identical `CimArch` chips connected by a `MeshLink` (bandwidth bits/cycle,
per-hop latency, per-byte energy) in a 1D ring or 2D grid — following the
same layering discipline the chip abstraction uses (CIMFlow,
arXiv:2505.01107: the chip level is a clean layer, not a fork):

  * **Shard choices** per layer (`shard_choices`, driven by the axis rules
    in `sharding/rules.py`): ``replicate`` (the whole layer on one chip —
    always valid, the rules' fully-FSDP fallback analog), ``split_n``
    (tensor-parallel over output channels, canonical dim K: input
    broadcast + output gather) and ``split_k`` (over the reduction dim C:
    input scatter + a ring all-reduce of 32-bit partial sums).
  * **Inter-chip transfer terms** (`shard_eval`): eq. 9-style — sharded
    operand bytes and all-reduce volume over the link bandwidth, charged
    per hop count of the topology (`latency.link_transfer_cycles`,
    `latency.ring_allreduce_cycles`; the NoC dataflow constant,
    arXiv:2111.11744).
  * **Mesh network pipeline** (`optimize_mesh_network`): per unique layer,
    solve every valid shard's sub-layer through the existing single-chip
    pipeline (`network.optimize_network` on ``mesh.chip`` — dedup,
    MAC-weighted budgets, process fan-out and the chip-keyed record cache
    all apply), then pick the cheapest (chip + communication) choice and
    emit a *mesh record* (chip cycles + comm cycles, energy over active
    chips + link energy, the shard decomposition). Mesh records cache
    under `cache.solve_record_key` with the **mesh fingerprint** as the
    arch component (CACHE_VERSION 6): two meshes differing only in link
    bandwidth never share records.
  * **Mesh schedule** (`scheduler.schedule_mesh`): the segment MIP
    generalized to one-hot (chip, core) placement with per-chip residency
    capacity and a shared makespan epigraph; greedy water-filling
    fallback preserved so the MIP never loses by construction.

Invariant: a 1-chip mesh is the single chip — `network.optimize_network`
with ``mesh=MeshArch(chip, 1)`` takes today's single-chip path bit for bit
(`tests/test_mesh.py`).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import (CimArch, INPUT, MeshLink, OUTPUT, WEIGHT,
                             arch_fingerprint, default_arch)
from repro.core.latency import link_transfer_cycles, ring_allreduce_cycles

TOPOLOGIES = ("ring", "grid")

#: Shard choices, in deterministic preference order (ties in (cycles,
#: energy) resolve to the earlier choice — replicate, the no-comm option).
REPLICATE = "replicate"
SPLIT_N = "split_n"
SPLIT_K = "split_k"
SHARD_CHOICES = (REPLICATE, SPLIT_N, SPLIT_K)

#: Canonical loop dim each split divides. GEMM (M x K_red) @ (K_red x
#: N_out) enters the nest as N=M, K=N_out, C=K_red (`workload.gemm`), so
#: "split N_out" divides canonical K and "split K_red" divides canonical C.
SPLIT_DIM = {SPLIT_N: "K", SPLIT_K: "C"}

#: Operand byte widths at the mesh level: activations travel between chips
#: as 8-bit requantized values (`arch.operand_bits` outer-hierarchy
#: convention); split_k partial sums are exchanged pre-requantization at
#: 32 bits (the all-reduce operates on accumulator precision). Weight
#: gradients (the OUTPUT of a wGrad GEMM, `workload.OP_WGRAD`) leave the
#: chip unquantized too — they feed the fp32 optimizer state
#: (`train/optimizer.py`), not another MVM.
ACT_BYTES = 1
PSUM_BYTES = 4
GRAD_BYTES = 4


def out_bytes_per_elem(layer: wl.Layer) -> int:
    """Inter-chip byte width of one OUTPUT element of ``layer``: fp32 for
    weight-grad GEMMs, requantized INT8 activations otherwise."""
    return GRAD_BYTES if layer.op == wl.OP_WGRAD else ACT_BYTES


# ---------------------------------------------------------------------------
# MeshArch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshArch:
    """``n_chips`` identical chips on a ring or 2D-grid interconnect.

    ``chip`` is a full `CimArch`; the mesh adds only the chip count, the
    link and the topology — every intra-chip question still goes to the
    chip abstraction (the mesh is a layer, not a fork)."""

    chip: CimArch
    n_chips: int = 1
    link: MeshLink = MeshLink()
    topology: str = "ring"
    name: str = "mesh"

    def validate(self) -> None:
        self.chip.validate()
        self.link.validate()
        assert self.n_chips >= 1, self.n_chips
        assert self.topology in TOPOLOGIES, self.topology

    # ---- topology geometry ------------------------------------------------
    def grid_dims(self) -> tuple[int, int]:
        """Near-square (rows, cols) factorization for the 2D grid."""
        r = max(1, int(math.isqrt(self.n_chips)))
        while self.n_chips % r:
            r -= 1
        return r, self.n_chips // r

    def chip_distance(self, a: int, b: int) -> int:
        """Hop count between two chips under the topology."""
        if a == b or self.n_chips <= 1:
            return 0
        if self.topology == "ring":
            d = abs(a - b)
            return min(d, self.n_chips - d)
        _, cols = self.grid_dims()
        return abs(a // cols - b // cols) + abs(a % cols - b % cols)

    def bcast_hops(self) -> int:
        """Worst-case hop distance from any chip — the per-chunk hop count
        a broadcast/scatter/gather from one host chip is charged with."""
        if self.n_chips <= 1:
            return 0
        if self.topology == "ring":
            return self.n_chips // 2
        r, c = self.grid_dims()
        return (r - 1) + (c - 1)

    # ---- identity ---------------------------------------------------------
    def fingerprint(self) -> str:
        """Structural serialization for cache keys (`cache.arch_cache_key`
        duck-types on this). Embeds the chip fingerprint plus every
        solver-relevant mesh field — chip count, topology, link bandwidth /
        hop latency / link energy. Excludes ``name`` (same contract as
        `arch.arch_fingerprint`)."""
        lk = self.link
        return (f"mesh[{arch_fingerprint(self.chip)}]"
                f"|n{self.n_chips}|{self.topology}"
                f"|lb{lk.bandwidth_bits}|hl{lk.hop_latency_cycles}"
                f"|le{lk.energy_pj_per_byte!r}")


def make_mesh(chip: CimArch | None = None, n_chips: int = 1, *,
              link: MeshLink | None = None, topology: str = "ring",
              name: str | None = None) -> MeshArch:
    """Convenience constructor with Table-IV chip defaults."""
    chip = chip or default_arch()
    link = link or MeshLink()
    if name is None:
        name = f"mesh-{chip.name}-n{n_chips}-{topology}"
    mesh = MeshArch(chip=chip, n_chips=n_chips, link=link,
                    topology=topology, name=name)
    mesh.validate()
    return mesh


# ---------------------------------------------------------------------------
# Residency capacity (the feasibility question the mesh exists to answer)
# ---------------------------------------------------------------------------

def total_macro_bytes(mesh: MeshArch) -> int:
    """Weight-resident capacity of the whole mesh."""
    from repro.core.scheduler import chip_macro_bytes
    return mesh.n_chips * chip_macro_bytes(mesh.chip)


def residency_feasible(layers: Sequence[wl.Layer],
                       counts: Sequence[int] | None,
                       mesh: MeshArch) -> bool:
    """Can the whole network's distinct weight sets be macro-resident at
    once? Counts are distinct weight sets (depth repeats — the scheduler's
    convention). This is the benchmark's infeasible-on-one-chip /
    feasible-on-four criterion, not an execution gate: an infeasible
    network still *runs* (weights stream), it just can't stay resident."""
    counts = [1] * len(layers) if counts is None else list(counts)
    need = sum(int(c) * layer.operand_elems(WEIGHT)
               for layer, c in zip(layers, counts))
    return need <= total_macro_bytes(mesh)


# ---------------------------------------------------------------------------
# Shard choices + inter-chip transfer model
# ---------------------------------------------------------------------------

def shard_sub_layer(layer: wl.Layer, choice: str, n_chips: int) -> wl.Layer:
    """The per-chip sub-layer a shard choice executes: the split dim is
    divided by ``n_chips`` (validity checked by `shard_choices`);
    ``replicate`` is the layer itself. The name is display-only —
    structural identity (`cache.layer_cache_key`) covers bounds + stride."""
    if choice == REPLICATE or n_chips <= 1:
        return layer
    d = SPLIT_DIM[choice]
    assert layer.bound(d) % n_chips == 0, (layer.name, choice, n_chips)
    dims = {k: layer.bound(k) for k in wl.DIMS}
    dims[d] = dims[d] // n_chips
    return wl.Layer(f"{layer.name}~{choice}{n_chips}", dims,
                    stride=layer.stride, op=layer.op,
                    weight_written=layer.weight_written)


def shard_choices(layer: wl.Layer, mesh: MeshArch, *,
                  n_heads: int | None = None,
                  n_experts: int | None = None) -> tuple[str, ...]:
    """Valid shard choices for one layer on this mesh, in preference
    order. Divisibility discipline delegates to the sharding rules
    (`sharding.rules.mesh_tp_choices` — the same logical-axis fallbacks
    `make_plan` applies per tensor class), so attention heads that do not
    divide the mesh and MoE ``E % n != 0`` banks fall back to valid
    chip-replicated placements instead of raising. Always contains
    ``replicate``.

    Weight-grad GEMMs (`workload.OP_WGRAD`) resolve through the FSDP
    gradient rule instead (`sharding.rules.mesh_grad_choices`): split_n
    is the FSDP sharded-gradient layout (each chip owns a 1/n grad shard
    along the weight's output channels), split_k is data-parallel wGrad
    over the token reduction dim with a ring all-reduce of the fp32
    partial gradients (`shard_eval` already prices split_k's all-reduce
    at accumulator width — exactly the DP gradient sync)."""
    from repro.sharding.rules import mesh_grad_choices, mesh_tp_choices
    if layer.op == wl.OP_WGRAD:
        return mesh_grad_choices(mesh.n_chips,
                                 out_channels=layer.bound("K"),
                                 reduce_dim=layer.bound("C"))
    return mesh_tp_choices(mesh.n_chips,
                           out_channels=layer.bound("K"),
                           reduce_dim=layer.bound("C"),
                           n_heads=n_heads, n_experts=n_experts)


@dataclasses.dataclass(frozen=True)
class ShardEval:
    """One shard choice's communication bill for one layer execution."""

    choice: str
    sub_layer: wl.Layer
    n_active: int            # chips computing (chip-energy multiplier)
    comm_cycles: float       # per-execution inter-chip transfer cycles
    comm_energy_pj: float    # per-execution link energy


def shard_eval(layer: wl.Layer, choice: str, mesh: MeshArch) -> ShardEval:
    """Eq. 9-style transfer term of one shard choice: sharded operand
    bytes (and all-reduce volume) over the link bandwidth, charged per hop
    count of the topology.

      * replicate — no inter-chip traffic (the host chip holds everything).
      * split_n   — every chip needs the full input (broadcast from the
        host over ``bcast_hops``) and returns its 1/n output slice
        (gather: ``(n-1)/n`` of the output travels back, at
        `out_bytes_per_elem` width — fp32 for wGrad gradients).
      * split_k   — every chip needs its 1/n input slice (scatter:
        ``(n-1)/n`` of the input leaves the host) and the 32-bit partial
        outputs ring-all-reduce (2(n-1) steps of 1/n chunks).

    Link energy is per byte per hop (`MeshLink.energy_pj_per_byte`); the
    all-reduce moves ``2(n-1) * bytes/n`` over single-hop ring steps.
    Every term is monotone non-increasing in the link bandwidth, so the
    per-layer mesh record — the min over choices of (chip + comm) — is
    too (`tests/test_mesh.py` fuzzes this)."""
    n = mesh.n_chips
    sub = shard_sub_layer(layer, choice, n)
    if choice == REPLICATE or n <= 1:
        return ShardEval(REPLICATE, layer, 1, 0.0, 0.0)
    link, hops = mesh.link, mesh.bcast_hops()
    in_bytes = layer.operand_elems(INPUT) * ACT_BYTES
    out_bytes = layer.operand_elems(OUTPUT) * out_bytes_per_elem(layer)
    e = link.energy_pj_per_byte
    if choice == SPLIT_N:
        gather = out_bytes * (n - 1) / n
        cycles = (link_transfer_cycles(in_bytes, link, hops) +
                  link_transfer_cycles(gather, link, hops))
        energy = e * (in_bytes + gather) * hops
        return ShardEval(choice, sub, n, cycles, energy)
    assert choice == SPLIT_K, choice
    scatter = in_bytes * (n - 1) / n
    ar_bytes = layer.operand_elems(OUTPUT) * PSUM_BYTES
    cycles = (link_transfer_cycles(scatter, link, hops) +
              ring_allreduce_cycles(ar_bytes, link, n))
    energy = e * (scatter * hops + 2 * (n - 1) * (ar_bytes / n))
    return ShardEval(choice, sub, n, cycles, energy)


def best_shard(layer: wl.Layer, mesh: MeshArch, sub_records: dict, *,
               choices: Sequence[str] | None = None
               ) -> tuple[ShardEval, dict]:
    """Pick the cheapest shard choice given solved sub-layer records
    (``sub_records``: `layer_cache_key`(sub layer) -> chip record).
    Selection is argmin by (total cycles, total energy, choice order) —
    cycles first so the per-layer number stays monotone in the link
    bandwidth (a min of monotone per-choice curves)."""
    from repro.core.cache import layer_cache_key
    best = None
    for idx, choice in enumerate(choices or
                                 shard_choices(layer, mesh)):
        ev = shard_eval(layer, choice, mesh)
        rec = sub_records[layer_cache_key(ev.sub_layer)]
        cyc = rec["cycles"] + ev.comm_cycles
        pj = ev.n_active * rec["energy_pj"] + ev.comm_energy_pj
        if best is None or (cyc, pj, idx) < best[:3]:
            best = (cyc, pj, idx, ev, rec)
    assert best is not None
    _, _, _, ev, rec = best
    return ev, rec


def _mesh_record(layer: wl.Layer, ev: ShardEval, sub_rec: dict,
                 mode: str) -> dict:
    """Combine a chip record + a shard's comm bill into one mesh record.
    The record keeps the single-chip schema (cycles/energy_pj/edp/mapping
    — the mapping is the *sub-layer's*) and adds the mesh fields the
    scheduler and the reports read."""
    cycles = sub_rec["cycles"] + ev.comm_cycles
    energy = ev.n_active * sub_rec["energy_pj"] + ev.comm_energy_pj
    return {
        "mode": mode,
        "layer": layer.name,
        "mapping": sub_rec["mapping"],
        "cycles": cycles,
        "energy_pj": energy,
        "edp": cycles * energy,
        "spatial_util": sub_rec["spatial_util"],
        "temporal_util": sub_rec["temporal_util"],
        "solve_s": sub_rec.get("solve_s", 0.0),
        "status": sub_rec["status"],
        # mesh-only fields ---------------------------------------------------
        "chip_cycles": sub_rec["cycles"],
        "chip_energy_pj": sub_rec["energy_pj"],
        "comm_cycles": ev.comm_cycles,
        "comm_energy_pj": ev.comm_energy_pj,
        "shard": {
            "choice": ev.choice,
            "n_chips": ev.n_active if ev.choice != REPLICATE else 1,
            "n_active": ev.n_active,
            "sub_dims": {d: ev.sub_layer.bound(d) for d in wl.DIMS},
        },
    }


# ---------------------------------------------------------------------------
# Mesh network pipeline
# ---------------------------------------------------------------------------

def optimize_mesh_network(layers: Sequence[wl.Layer], mesh: MeshArch,
                          mode: str = "miredo", *,
                          counts: Sequence[int] | None = None,
                          cfg=None,
                          total_budget_s: float | None = None,
                          per_layer_cap_s: float = 60.0,
                          workers: int | None = None,
                          cache=None,
                          use_cache: bool = True,
                          schedule: bool = True,
                          schedule_boundaries: Sequence[int] | None = None,
                          warm_starts: dict[str, dict] | None = None,
                          portfolio=None,
                          verbose: bool = False):
    """Mesh counterpart of `network.optimize_network` (which dispatches
    here for ``mesh=`` with ``n_chips > 1``; a 1-chip mesh takes the
    single-chip path bit for bit and never reaches this function).

    Per unique layer, every valid shard's sub-layer is solved through ONE
    inner single-chip `optimize_network` call against ``mesh.chip``
    (``schedule=False``): structural dedup across layers AND shard
    choices, MAC-weighted budgets over the full sub-layer pool, process
    fan-out and chip-keyed record caching all come for free. The combined
    per-layer mesh records cache under the **mesh** fingerprint
    (CACHE_VERSION 6 arch key), so a rerun with every mesh record present
    skips the inner call entirely; any miss re-runs the inner call over
    the FULL sub-layer pool — budget allocation is over the same pool
    regardless of cache state, so budgets (and hence chip cache keys) are
    rerun-deterministic, mirroring the single-chip pipeline's discipline.

    Returns a `network.NetworkResult` with ``arch_name = mesh.name``;
    ``scheduled``/``schedule`` come from the mesh scheduler
    (`scheduler.schedule_mesh`: one-hot (chip, core) placement MIP with
    per-chip residency, greedy water-filling fallback)."""
    from repro.core.cache import (ResultCache, layer_cache_key,
                                  solve_record_key)
    from repro.core.formulation import FormulationConfig
    from repro.core.network import (DEFAULT_BUDGET_FRACTION, LayerResult,
                                    NetworkResult, _aggregate, dedup_layers,
                                    optimize_network)

    assert mesh.n_chips > 1, "1-chip meshes take the single-chip path"
    t0 = time.monotonic()
    layers = list(layers)
    counts = [1] * len(layers) if counts is None else list(counts)
    assert len(counts) == len(layers)
    base_cfg = cfg or FormulationConfig(time_limit_s=per_layer_cap_s)
    cache = cache if cache is not None else (
        ResultCache() if use_cache else None)

    unique, keys = dedup_layers(layers)

    # ---- candidate sub-layers per unique layer ----------------------------
    cands: dict[str, list[tuple[str, wl.Layer]]] = {}
    pool: list[wl.Layer] = []
    pool_seen: set[str] = set()
    for ul in unique:
        k = layer_cache_key(ul)
        cands[k] = [(c, shard_sub_layer(ul, c, mesh.n_chips))
                    for c in shard_choices(ul, mesh)]
        for _, sub in cands[k]:
            sk = layer_cache_key(sub)
            if sk not in pool_seen:
                pool_seen.add(sk)
                pool.append(sub)

    # Mesh-record cache probe. The cfg component of the mesh key carries
    # the *resolved global budget* (deterministic from the inputs) — the
    # per-sub-layer budgets the inner call derives are a pure function of
    # it and the pool, so the mesh key fully determines the solve.
    if total_budget_s is None:
        total_budget_s = (DEFAULT_BUDGET_FRACTION * per_layer_cap_s *
                          len(pool))
    mesh_cfg = dataclasses.replace(base_cfg, time_limit_s=total_budget_s)
    mesh_key = {k: solve_record_key(mode, ul, mesh, mesh_cfg,
                                    portfolio=portfolio)
                for ul, k in ((u, layer_cache_key(u)) for u in unique)}
    records: dict[str, dict] = {}
    if cache is not None:
        for ul in unique:
            k = layer_cache_key(ul)
            rec = cache.get(mesh_key[k])
            if rec is not None:
                records[k] = rec
    cache_hits = len(records)
    budgets: dict[str, float] = {}

    # ---- inner single-chip pass over the full pool on any miss ------------
    if len(records) < len(unique):
        inner = optimize_network(
            pool, mesh.chip, mode, cfg=base_cfg,
            total_budget_s=total_budget_s,
            per_layer_cap_s=per_layer_cap_s, workers=workers,
            cache=cache, use_cache=use_cache, schedule=False,
            warm_starts=warm_starts, portfolio=portfolio,
            verbose=verbose)
        sub_records = {lr.key: lr.record for lr in inner.layers}
        budgets = inner.budgets
        for ul in unique:
            k = layer_cache_key(ul)
            if k in records:
                continue
            ev, sub_rec = best_shard(
                ul, mesh, sub_records,
                choices=[c for c, _ in cands[k]])
            rec = _mesh_record(ul, ev, sub_rec, mode)
            records[k] = rec
            if cache is not None:
                cache.put(mesh_key[k], rec)
            if verbose:
                print(f"[mesh/{mode}] {ul.name}: {rec['shard']['choice']} "
                      f"-> {rec['cycles']:.3g} cyc "
                      f"({rec['comm_cycles']:.3g} comm)")

    # ---- per-instance results ---------------------------------------------
    out_layers: list[LayerResult] = []
    for layer, count, k in zip(layers, counts, keys):
        rec = dict(records[k])
        rec["layer"] = layer.name
        out_layers.append(LayerResult(layer=layer, count=int(count), key=k,
                                      record=rec))

    totals = _aggregate(out_layers)
    scheduled = sched = None
    if schedule:
        from repro.core.scheduler import schedule_mesh
        sched = schedule_mesh(out_layers, mesh,
                              boundaries=schedule_boundaries,
                              verbose=verbose)
        scheduled = sched.totals()
        scheduled["energy_pj"] = totals["energy_pj"] + sched.energy_delta_pj
        scheduled["edp"] = scheduled["energy_pj"] * sched.scheduled_cycles

    return NetworkResult(
        mode=mode, arch_name=mesh.name, layers=out_layers,
        n_unique=len(unique), n_solved=len(unique) - cache_hits,
        cache_hits=cache_hits, budgets=budgets,
        wall_s=round(time.monotonic() - t0, 2),
        totals=totals, scheduled=scheduled, schedule=sched)
