"""Energy / EDP model (paper §V reports EDP ratios; constants PCACTI-class).

Energy counts *physical* traffic (unlike the latency model's pipeline-edge
accounting): every hop of every operand contributes
    loads(hop) × chunk_bytes × (e_read(src) + e_write(dst)),
where loads = product of relevant temporal factors above the destination
block (reuse over irrelevant loops is free). Partial-sum write-backs pay a
read-modify-write factor while reduction dims remain un-accumulated above.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import workload as wl
from repro.core.arch import CimArch, OPERANDS, OUTPUT, WEIGHT
from repro.core.latency import LatencyReport, evaluate
from repro.core.mapping import Mapping, SizeContext

REDUCTION_DIMS = ("C", "FY", "FX")


@dataclasses.dataclass
class EnergyReport:
    total_pj: float
    traffic_pj: dict[str, float]
    mac_pj: float
    bytes_moved: dict[str, float]


def hop_loads(mapping: Mapping, operand: str, m_dst: int) -> int:
    """Number of distinct tile loads into level m_dst for the operand."""
    loads = 1
    for i, (dim, f) in enumerate(mapping.temporal):
        if mapping.level_of[operand][i] < m_dst and \
                wl.is_relevant(dim, operand):
            loads *= f
    return loads


def operand_energy_hops(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                        operand: str,
                        ctx: SizeContext | None = None
                        ) -> list[tuple[float, float]]:
    """Per hop of the operand's (DRAM-prepended) used-level chain, the
    ``(total_bytes, e_coef)`` pair whose product is the hop's traffic energy.
    ``total_bytes`` carries the psum read-modify-write doubling. Single
    source of truth for ``evaluate_energy`` and the batched packer
    (`latency_batched.py`)."""
    used = mapping.used_levels(operand)
    # Prepend DRAM as the universal source if not already present.
    if not used or used[0] != 0:
        used = [0] + used
    hops: list[tuple[float, float]] = []
    for m_src, m_dst in zip(used, used[1:]):
        loads = hop_loads(mapping, operand, m_dst)
        chunk = ctx.stored_bytes(operand, m_dst) if ctx is not None \
            else mapping.stored_bytes(layer, operand, arch, m_dst)
        total_bytes = loads * chunk
        e = arch.level(m_src).access_energy_pj_per_byte + \
            arch.level(m_dst).access_energy_pj_per_byte
        if operand == OUTPUT:
            # read-modify-write while reduction dims above m_dst exist
            rmw = any(
                wl.is_relevant(dim, operand) is False
                and dim in REDUCTION_DIMS
                and mapping.level_of[operand][i] < m_dst
                for i, (dim, _) in enumerate(mapping.temporal))
            if rmw:
                total_bytes *= 2
        hops.append((total_bytes, e))
    return hops


def evaluate_energy(mapping: Mapping, layer: wl.Layer,
                    arch: CimArch) -> EnergyReport:
    traffic = {lam: 0.0 for lam in OPERANDS}
    bytes_moved = {lam: 0.0 for lam in OPERANDS}
    for lam in OPERANDS:
        for total_bytes, e in operand_energy_hops(mapping, layer, arch, lam):
            traffic[lam] += total_bytes * e
            bytes_moved[lam] += total_bytes
    mac_pj = layer.macs * arch.mac_energy_pj
    total = sum(traffic.values()) + mac_pj
    return EnergyReport(total_pj=total, traffic_pj=traffic, mac_pj=mac_pj,
                        bytes_moved=bytes_moved)


@dataclasses.dataclass
class EdpReport:
    latency: LatencyReport
    energy: EnergyReport

    @property
    def cycles(self) -> float:
        return self.latency.total_cycles

    @property
    def edp(self) -> float:
        """pJ * s  (cycles converted at arch frequency)."""
        return self.energy.total_pj * self.latency.total_cycles


def evaluate_edp(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                 latency: LatencyReport | None = None) -> EdpReport:
    lat = latency if latency is not None else evaluate(mapping, layer, arch)
    return EdpReport(latency=lat, energy=evaluate_energy(mapping, layer, arch))
