"""Training-workload lowering: backward-pass GEMMs + the optimizer-step
traffic term (DESIGN.md §Training frontend, ROADMAP item 4).

The forward frontend (`core/frontend.py`) lowers every registry model to
weight-GEMMs ``Y = X . W`` — (M x K_red) @ (K_red x N_out), canonical
N=M, K=N_out, C=K_red (`workload.gemm`). A training step runs each of
them three times, and the two backward forms transpose the operands:

    forward   Y  = X . W            gemm(M,    N_out, K_red)
    dGrad     dX = dY . W^T         gemm(M,    K_red, N_out)  OP_DGRAD
    wGrad     dW = X^T . dY         gemm(K_red, N_out, M)     OP_WGRAD

All three have identical MACs (M * N_out * K_red), so a dense model's
backward exactly doubles its forward GEMM MACs — the embedding gather
contributes zero MACs on both sides, the same convention the forward
frontend uses. What changes is *which operand is stationary*:

  * dGrad's macro-resident operand is W^T — still a preloadable weight,
    so residency packing (`core/scheduler.py`) applies unchanged.
  * wGrad's macro-resident ("weight"-slot) operand is dY, an activation
    gradient *produced by this very step*. `Layer.weight_written` marks
    it: `scheduler.weight_residency` returns (False, 0.0) for written
    layers (nothing exists to preload, so the one-time program-in cannot
    amortize across pipelined items), `cache.layer_cache_key` keeps such
    layers from aliasing same-shaped forward layers, and the mesh rules
    route them through the FSDP gradient shards
    (`sharding.rules.mesh_grad_choices`).
  * activation-activation matmuls (the SSD duality forms, `OP_SSD`) have
    no weight at all: both backward GEMMs are activation grads — emitted
    tagged `OP_DGRAD` with ``weight_written=True`` on both sides (the
    stationary operand is always a forward activation or a gradient) and
    excluded from the optimizer update.

MoE routing: dGrad mirrors the forward multiplicities exactly (every
routed token-assignment backpropagates), but wGrad exists only for the
experts actually *hit* — with ``m * top_k`` token-assignments over ``E``
experts, at most ``min(E, m * top_k)`` experts received tokens, so the
routed ``.exp.*`` wGrad count scales by ``n_hit / E``.

The optimizer step itself is not a GEMM: per distinct weight set it reads
the fp32 gradient, reads+writes both Adam moments (`train/optimizer.py`:
fp32 m and v) and writes the requantized INT8 weight image back for the
macros. That traffic is priced once per step, never per tile, through the
same eq. 9/11-style machinery the per-layer model uses: bytes over the
DRAM bus width (`arch.level(0).bytes_per_cycle()`, the eq. 11 chunk
form) and per-byte (source + destination) access energy for the
DRAM<->GBuf hop, mirroring `energy.operand_energy_hops`' coefficient
convention. On a multi-chip mesh, data-parallel gradient sync adds one
ring all-reduce of the fp32 gradients (reduce-scatter + all-gather,
`latency.ring_allreduce_cycles` — the FSDP collective).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.configs.base import ModelConfig, ShapeSpec
from repro.core import workload as wl
from repro.core.arch import WEIGHT

#: Optimizer-state byte widths (`train/optimizer.py`: OPT_STATE_DTYPE is
#: fp32 for gradients and both Adam moments); the updated weight image is
#: written back requantized to the macros' INT8.
GRAD_BYTES = 4
MOMENT_BYTES = 4
ADAM_MOMENTS = 2
WEIGHT_IMAGE_BYTES = 1

#: Forward op kinds whose stationary operand is a true (trainable) weight.
_WEIGHTFUL_OPS = (wl.OP_GEMM, wl.OP_ATTENTION)
#: Name marker of top-k-routed MoE expert GEMMs (`lm_workloads.moe_gemms`
#: emits ``{prefix}.exp.ffn_up`` / ``.exp.ffn_down``; shared experts and
#: arctic's dense residual use other markers and train like dense FFNs).
_ROUTED_MARKER = ".exp."


def update_bytes_per_param() -> int:
    """DRAM bytes one parameter costs per optimizer step: gradient read +
    both Adam moments read and written + weight image write."""
    return GRAD_BYTES + 2 * ADAM_MOMENTS * MOMENT_BYTES + WEIGHT_IMAGE_BYTES


def routed_hit_experts(cfg: ModelConfig, m_tokens: int) -> int:
    """Experts that can receive >= 1 token under top-k routing of
    ``m_tokens`` tokens: ``min(E, m * top_k)``. 0 for non-MoE configs."""
    if not (cfg.n_experts and cfg.top_k):
        return 0
    return min(cfg.n_experts, m_tokens * cfg.top_k)


def backward_gemms(forward: Sequence[tuple[wl.Layer, int]],
                   cfg: ModelConfig, spec: ShapeSpec
                   ) -> list[tuple[wl.Layer, int]]:
    """Expand a forward (layer, count) stream into its backward stream.

    Emitted in *reversed* forward order (backprop executes the network
    back to front), one dGrad + one wGrad per forward GEMM, per the
    module-docstring transposition table. The ``.wgrad`` of an
    activation-activation matmul (`OP_SSD` forward) is itself an
    activation grad: tagged `OP_DGRAD` (no optimizer state behind it) but
    still ``weight_written`` — its stationary operand is produced too.
    """
    assert spec.kind == "train", spec.kind
    out: list[tuple[wl.Layer, int]] = []
    n_exp, n_hit = cfg.n_experts, routed_hit_experts(cfg, spec.m_tokens)
    for layer, count in reversed(list(forward)):
        assert layer.is_gemm and layer.op in wl.OP_KINDS[:3], \
            (layer.name, layer.op)
        m, n_out, k_red = (layer.bound("N"), layer.bound("K"),
                           layer.bound("C"))
        weightful = layer.op in _WEIGHTFUL_OPS
        out.append((wl.gemm(f"{layer.name}.dgrad", m, k_red, n_out,
                            op=wl.OP_DGRAD,
                            weight_written=not weightful), count))
        w_count = count
        if n_hit and _ROUTED_MARKER in layer.name:
            assert count % n_exp == 0, (layer.name, count, n_exp)
            w_count = (count // n_exp) * n_hit
        out.append((wl.gemm(f"{layer.name}.wgrad", k_red, n_out, m,
                            op=wl.OP_WGRAD if weightful else wl.OP_DGRAD,
                            weight_written=True), w_count))
    return out


# ---------------------------------------------------------------------------
# Optimizer-step traffic (once per step, never per tile)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UpdateCost:
    """The optimizer step's once-per-step DRAM + collective bill."""

    n_params: int            # distinct trainable params (weight sets)
    dram_bytes: int          # DRAM bytes touched per step
    cycles: float            # DRAM-bus cycles (eq. 11 chunk form)
    energy_pj: float         # DRAM<->GBuf access energy
    comm_cycles: float = 0.0     # mesh gradient ring all-reduce
    comm_energy_pj: float = 0.0  # link energy of that collective

    @property
    def total_cycles(self) -> float:
        return self.cycles + self.comm_cycles

    @property
    def total_energy_pj(self) -> float:
        return self.energy_pj + self.comm_energy_pj


def trainable_params(layers_counts: Sequence[tuple[wl.Layer, int]], *,
                     inst: int = 1) -> int:
    """Distinct trainable parameters of a lowered (layer, count) stream.

    Counts are depth x batch multiplicities; batch instances share
    weights, so each layer contributes ``count // inst`` distinct weight
    sets (`inst` = `ShapeSpec.instance_count` — the same depth-repeats
    convention the scheduler documents; conservative for parameter-shared
    blocks like zamba2's, which re-count the shared weights per apply).
    Backward layers, written-operand layers and activation-activation ops
    carry no trainable weight and contribute nothing.
    """
    n = 0
    for layer, count in layers_counts:
        if layer.op not in _WEIGHTFUL_OPS or layer.weight_written:
            continue
        assert count % inst == 0, (layer.name, count, inst)
        n += (count // inst) * layer.operand_elems(WEIGHT)
    return n


def optimizer_update_cost(layers_counts: Sequence[tuple[wl.Layer, int]],
                          arch, *, inst: int = 1) -> UpdateCost:
    """Price one optimizer step for a lowered workload on ``arch`` (a
    `CimArch`, or a `mesh.MeshArch` — then the FSDP gradient ring
    all-reduce is added and DRAM pricing uses the chip).

    Charged ONCE per training step: the update touches each parameter a
    fixed number of times regardless of how its GEMMs were tiled, so this
    term lives outside the per-layer records (which would re-bill it per
    tile or per instance)."""
    mesh = arch if getattr(arch, "n_chips", 1) > 1 else None
    chip = getattr(arch, "chip", arch)
    n_params = trainable_params(layers_counts, inst=inst)
    dram_bytes = n_params * update_bytes_per_param()
    # eq. 11 chunk form on the DRAM bus; (e_src + e_dst) per byte for the
    # DRAM<->GBuf hop, `energy.operand_energy_hops`' coefficient.
    cycles = float(math.ceil(dram_bytes / chip.level(0).bytes_per_cycle()))
    e_hop = (chip.level(0).access_energy_pj_per_byte +
             chip.level(1).access_energy_pj_per_byte)
    energy = dram_bytes * e_hop
    comm_cycles = comm_energy = 0.0
    if mesh is not None:
        from repro.core.latency import ring_allreduce_cycles
        grad_bytes = n_params * GRAD_BYTES
        comm_cycles = ring_allreduce_cycles(grad_bytes, mesh.link,
                                            mesh.n_chips)
        # 2(N-1) single-hop ring steps of 1/N chunks (reduce-scatter +
        # all-gather), priced like `mesh.shard_eval`'s all-reduce term
        comm_energy = (mesh.link.energy_pj_per_byte *
                       2 * (mesh.n_chips - 1) * (grad_bytes / mesh.n_chips))
    return UpdateCost(n_params=n_params, dram_bytes=dram_bytes,
                      cycles=cycles, energy_pj=energy,
                      comm_cycles=comm_cycles, comm_energy_pj=comm_energy)


# ---------------------------------------------------------------------------
# Phase splits + the backward-dataflow headline
# ---------------------------------------------------------------------------

def phase_of(layer: wl.Layer) -> str:
    """fwd | dgrad | wgrad bucket of one lowered layer (activation-
    activation backward ops land in dgrad — they carry that tag)."""
    if layer.op == wl.OP_WGRAD:
        return "wgrad"
    if layer.op == wl.OP_DGRAD:
        return "dgrad"
    return "fwd"


def cycle_splits(net) -> dict[str, float]:
    """Serial-sum cycles of a solved training network by phase."""
    out = {"fwd": 0.0, "dgrad": 0.0, "wgrad": 0.0}
    for lr in net.layers:
        out[phase_of(lr.layer)] += lr.count * lr.record["cycles"]
    return out


#: Canonical-dim -> GEMM-role maps. Raw loop dims of a backward layer
#: trivially differ from its forward's (the bounds are transposed), so
#: dataflow comparison happens in *role* space: M = tokens, N = the
#: forward weight's output channels, K = the forward reduction dim.
_FWD_ROLES = {"N": "M", "K": "N", "C": "K"}
_ROLES_BY_OP = {
    wl.OP_DGRAD: {"N": "M", "K": "K", "C": "N"},
    wl.OP_WGRAD: {"N": "K", "K": "N", "C": "M"},
}


def dataflow_signature(mapping_json: dict, op: str) -> tuple:
    """Structural dataflow signature of a solved mapping in GEMM-role
    space: which roles each spatial axis parallelizes and the temporal
    role order, factors dropped (trivial factor-1 entries excluded).
    Two layers share a signature iff the MIP chose the same *dataflow* —
    same stationarity/parallelization structure — for them, regardless of
    their (transposed) bounds."""
    roles = _ROLES_BY_OP.get(op, _FWD_ROLES)
    spatial = tuple(
        (ax, tuple(roles.get(d, d) for d, f in entries if f > 1))
        for ax, entries in sorted(mapping_json["spatial"].items()))
    temporal = tuple(roles.get(d, d) for d, f in mapping_json["temporal"]
                     if f > 1)
    return spatial, temporal


def backward_dataflow_diffs(net) -> list[dict]:
    """Per wGrad layer: does the MIP-optimal backward dataflow differ
    from the forward layer's? — the training benchmark's headline. Pairs
    each unique ``<name>.wgrad`` record with its forward ``<name>``
    record and compares role-space signatures."""
    fwd = {}
    for lr in net.layers:
        if phase_of(lr.layer) == "fwd":
            fwd.setdefault(lr.layer.name, lr)
    out, seen = [], set()
    for lr in net.layers:
        name = lr.layer.name
        if lr.layer.op != wl.OP_WGRAD or not name.endswith(".wgrad") \
                or name in seen:
            continue
        seen.add(name)
        flr = fwd.get(name[:-len(".wgrad")])
        if flr is None:
            continue
        fsig = dataflow_signature(flr.record["mapping"], flr.layer.op)
        wsig = dataflow_signature(lr.record["mapping"], wl.OP_WGRAD)
        out.append({"layer": flr.layer.name, "differs": fsig != wsig,
                    "fwd_signature": fsig, "wgrad_signature": wsig})
    return out


# ---------------------------------------------------------------------------
# End-to-end: one training step through the network pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TrainingResult:
    """One solved training step: the network result over
    fwd + dGrad + wGrad plus the once-per-step optimizer bill."""

    net: object              # network.NetworkResult
    update: UpdateCost
    splits: dict             # serial cycles by phase (cycle_splits)

    @property
    def step_cycles(self) -> float:
        """End-to-end cycles of one step: the scheduled network makespan
        (serial sum when scheduling was skipped) + the update."""
        s = self.net.scheduled
        base = s["cycles"] if s else self.net.totals["cycles"]
        return base + self.update.total_cycles

    @property
    def step_energy_pj(self) -> float:
        return self.net.totals["energy_pj"] + self.update.total_energy_pj


def optimize_training(cfg: ModelConfig, spec: ShapeSpec, arch=None,
                      mode: str = "miredo", *, mesh=None,
                      **net_kwargs) -> TrainingResult:
    """Lower ``cfg`` under a ``kind="train"`` spec (forward + backward via
    `frontend.extract_workload`), solve it through the network pipeline,
    and attach the optimizer-step bill. ``mesh=`` routes through the mesh
    pipeline and adds the gradient collective to the update."""
    from repro.core.frontend import extract_workload
    from repro.core.network import optimize_network

    assert spec.kind == "train", spec.kind
    work = extract_workload(cfg, spec)
    net = optimize_network(list(work.layers), arch, mode, mesh=mesh,
                           counts=list(work.counts), **net_kwargs)
    update = optimizer_update_cost(
        list(zip(work.layers, work.counts)),
        mesh if mesh is not None else arch,
        inst=spec.instance_count)
    return TrainingResult(net=net, update=update, splits=cycle_splits(net))
