"""Network-level multi-core scheduler: weight-resident layer-to-core
allocation + pipelined segment execution (DESIGN.md §Network scheduler).

The network pipeline (`core/network.py`) scores a model as a *serial sum*
of per-layer latencies: every layer owns all cores exclusively and pays its
full macro weight program-in (mode-switch stall, paper Fig. 2(a)) at every
layer boundary. System-level CIM efficiency is decided by *which weights
stay resident on which cores* and how layers pipeline across them (CIMFlow,
arXiv:2505.01107) — this module closes that gap on top of the per-layer
mappings `optimize_network` already produced:

  1. **Segment packing.** The ordered layer stream is partitioned into
     contiguous *segments* whose combined weight footprints fit the chip's
     macro capacity simultaneously (a dynamic program over all contiguous
     splits; per-segment cost below). A stage whose weights exceed the chip
     (count x weight bytes > all macros) — or whose mapping is not
     weight-stationary to begin with — executes serially, exactly as the
     per-layer record already models (intra-layer reloads included).
  2. **Layer-to-core allocation.** Within a segment, stages partition the
     core axis: stage i gets ``c_i`` cores, its (count_i) weight slices
     spread across those cores' macros (capacity floor
     ``count_i x w_bytes_i <= c_i x per-core macro bytes``), and computes
     at the core-scaled per-item latency ``t_i(c_i)``. The split is a small
     MIP over `core/mip/model.py` (one-hot core choice per stage, shared
     core budget, makespan epigraph) with a greedy water-filling fallback
     mirroring `network.allocate_budgets`; the better of the two is kept,
     so the MIP never loses to the fallback.
  3. **Pipelined segment schedule.** Weights load once per segment (one
     DRAM->macro program-in per weight slice, ONE mode-switch exposure
     instead of one per layer instance) and the stages stream activations
     GBuf->GBuf: item k of stage i feeds item min(k, count_{i-1}-1) of
     stage i+1. Segment latency:

         load    = sum_i ceil(count_i * w_bytes_i / BW_dram) + switch
         compute = exact makespan of the item stream at zero ready time
                   (`simulator.stream_finish_times` — the identical
                   recursion the event replay uses; the closed
                   fill+bottleneck form serves only as the allocators'
                   objective)

     ``load + compute`` upper-bounds the event replay: the replay starts
     stages as their own weights land (delaying every stage by at most
     the full load delays the finish by at most the full load). The
     simulator's network mode (`simulator.simulate_segment`) is the
     out-of-band cross-check (`cross_check`), the same discipline
     Fig. 4(a) applies to single layers.

Cost-model fidelity: per-item latency at full cores is the *record's* own
(MIP-fidelity) cycles minus its one-time weight fill — `weight_residency`
mirrors `latency.evaluate`'s one-time accounting exactly — and the core
sensitivity ``t_i(c)/t_i(n_cores)`` is probed with the same greedy
constructor that warm-starts the MIP (`baselines.greedy_mapping` on a
`arch.with_cores` variant), clamped monotone (more cores never hurt).
When the record's mapping streams weights (the solo-latency MIP has no
incentive to keep them resident), the scheduler may swap in the greedy
incumbent's weight-stationary mapping as the stage basis — residency is
exactly the network-level objective the per-layer solve cannot see.

Guarantees:
  * scheduled cycles <= serial cycles, always: every segment is charged
    ``min(pipelined, serial)`` and the DP may always fall back to
    serial singletons;
  * strictly better whenever a segment of record-resident stages keeps
    >=2 instances on chip (at minimum the saved mode-switch stalls);
    greedy-basis swaps only ever engage when they win too;
  * energy follows the mappings actually executed: record-basis segments
    leave it unchanged (every weight slice loads exactly once per
    instance in both schedules — the scheduler loads them *together*,
    the serial baseline one-by-one), and a pipelined greedy-basis swap
    charges its mapping's energy difference (`Schedule.energy_delta_pj`).

Counts are treated as *distinct* weight sets (depth repeats). For
batch-multiplicity counts the footprint is overcounted — a conservative
simplification (fewer packing opportunities, never an infeasible one).

Written-residency caveat (training workloads, `core/training.py`): a
layer with ``weight_written`` set carries a stationary operand that is
*produced* by the step that consumes it (a wGrad GEMM's resident operand
is the activation gradient of this very step), so there is nothing to
preload and keep resident across pipelined items — `weight_residency`
returns (False, 0.0) for such layers regardless of the mapping, the
greedy weight-stationary basis swap never engages, and the stage executes
serially exactly as its record prices it (intra-layer fills included).
Non-resident stages are serial singletons, so scheduled <= serial holds
unchanged.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import CimArch, WEIGHT, core_axis, n_macros, with_cores
from repro.core.cache import layer_cache_key, mapping_from_json
from repro.core.latency import evaluate, operand_fill_hops
from repro.core.mapping import Mapping

#: Wall-clock cap per segment-allocation MIP (they are tens of binaries;
#: the greedy fallback covers a cap hit).
ALLOC_MIP_CAP_S = 2.0


# ---------------------------------------------------------------------------
# Weight residency (mirrors latency.evaluate's one-time fill accounting)
# ---------------------------------------------------------------------------

def weight_residency(mapping: Mapping, layer: wl.Layer,
                     arch: CimArch) -> tuple[bool, float]:
    """(resident, fill_cycles) for the weight operand.

    ``resident`` iff no temporal slot ever retriggers a weight hop — the
    weights are fully stationary after their one-time program-in, so they
    *can* stay resident across executions. ``fill_cycles`` is exactly the
    weight share of `latency.evaluate`'s one-time fills (both read the
    same `latency.operand_fill_hops` chain), so
    ``record cycles - fill_cycles`` is the per-item resident latency at
    full cores. Non-resident mappings return (False, 0.0): their weight
    traffic lives inside the recursion and cannot be split out.

    A ``weight_written`` layer returns (False, 0.0) unconditionally: its
    stationary operand is produced by the step itself (wGrad GEMMs), so
    no mapping can make it preloadable — the residency record the packing
    would amortize does not exist before the items run."""
    if layer.weight_written:
        return False, 0.0
    hops = operand_fill_hops(mapping, layer, arch, WEIGHT)
    if any(triggered for triggered, _ in hops):
        return False, 0.0
    return True, sum(t for _, t in hops)


def weight_bytes(layer: wl.Layer) -> int:
    """One instance's weight footprint (INT8: one byte per element)."""
    return layer.operand_elems(WEIGHT)


def chip_macro_bytes(arch: CimArch) -> int:
    """Total weight-resident capacity: every physical macro's cell array."""
    cap = arch.level(arch.macro_level).capacity_bytes
    assert cap is not None
    return n_macros(arch) * cap


# ---------------------------------------------------------------------------
# Core-scaled per-item latency
# ---------------------------------------------------------------------------

class CoreScaling:
    """Greedy-probe core-sensitivity curves, memoized per (layer key, c).

    ``factor(layer, key, c)`` = greedy cycles on the c-core chip slice /
    greedy cycles on the full chip, clamped >= 1 and monotone non-increasing
    in c (a stage may always ignore surplus cores). The probes use the same
    incumbent constructor that warm-starts the MIP, so the curve is cheap
    (no solver) yet shape-aware; the absolute anchor stays the record's
    MIP-fidelity cycles."""

    def __init__(self, arch: CimArch):
        from repro.core.baselines import greedy_mapping
        self._greedy = greedy_mapping
        self.arch = arch
        ax = core_axis(arch)
        self.n_cores = ax.size if ax is not None else 1
        self._variant = {self.n_cores: arch}
        self._cycles: dict[tuple[str, int], float] = {}
        self._factor: dict[tuple[str, int], float] = {}

    def _greedy_cycles(self, layer: wl.Layer, key: str, c: int) -> float:
        k = (key, c)
        if k not in self._cycles:
            arch = self._variant.get(c)
            if arch is None:
                arch = self._variant[c] = with_cores(self.arch, c)
            mp = self._greedy(layer, arch)
            self._cycles[k] = evaluate(mp, layer, arch).total_cycles
        return self._cycles[k]

    def factor(self, layer: wl.Layer, key: str, c: int) -> float:
        c = max(1, min(c, self.n_cores))
        if c == self.n_cores:
            return 1.0
        k = (key, c)
        if k not in self._factor:
            base = self._greedy_cycles(layer, key, self.n_cores)
            raw = max(1.0, self._greedy_cycles(layer, key, c) / max(base, 1.0))
            # monotone: fewer cores are never faster than one more of them
            self._factor[k] = max(raw, self.factor(layer, key, c + 1))
        return self._factor[k]


# ---------------------------------------------------------------------------
# Plan dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StagePlan:
    """One layer instance-group inside a segment."""

    name: str
    key: str                    # structural dedup key (cache.layer_cache_key)
    count: int                  # executions (distinct weight sets, see above)
    weight_bytes: int           # per-instance weight footprint
    serial_cycles: float        # count x record cycles (the serial baseline)
    resident_cycles: float      # per-item latency at full cores, weights in
    resident: bool              # a weight-stationary mapping exists
    basis: str = "record"       # mapping behind resident_cycles:
                                # "record" | "greedy" (resident fallback)
    #: count x (basis-mapping energy - record energy); nonzero only for
    #: greedy-basis stages — charged iff the pipelined path is taken, so
    #: scheduled energy always reflects the mappings actually executed.
    energy_delta_pj: float = 0.0
    c_min: int = 1              # capacity floor on allocated cores
    cores: int = 0              # allocated cores (0 until planned)
    t_cycles: float = 0.0       # per-item latency at `cores`

    @property
    def load_bytes(self) -> int:
        return self.count * self.weight_bytes


@dataclasses.dataclass
class SegmentPlan:
    """A contiguous run of stages whose weights are co-resident."""

    stages: list[StagePlan]
    load_cycles: float = 0.0         # one-time weight program-in, whole seg
    compute_cycles: float = 0.0      # pipelined fill + bottleneck
    serial_cycles: float = 0.0       # sum of per-stage serial baselines
    #: "pipelined" iff the weight-resident schedule strictly beats the
    #: serial fallback for this run of stages; "serial" otherwise (either
    #: ineligible — non-resident / oversized — or pipelining simply loses,
    #: e.g. core partitioning costs more than the saved reloads).
    mode: str = "serial"
    allocator: str = "-"             # "mip" | "greedy" | "-"

    @property
    def pipelined_cycles(self) -> float:
        return self.load_cycles + self.compute_cycles

    @property
    def cycles(self) -> float:
        """What the schedule charges: never worse than serial."""
        if self.mode == "pipelined":
            return self.pipelined_cycles
        return self.serial_cycles

    @property
    def packed(self) -> bool:
        """True when the segment genuinely keeps >1 weight-resident
        instance on chip AND the pipelined schedule is the one taken —
        i.e. this segment strictly beats its serial baseline."""
        return self.mode == "pipelined" and \
            sum(st.count for st in self.stages) > 1

    @property
    def energy_delta_pj(self) -> float:
        """Energy adjustment vs the serial records: nonzero only when the
        pipelined path executes greedy-basis (swapped) mappings."""
        if self.mode != "pipelined":
            return 0.0
        return sum(st.energy_delta_pj for st in self.stages)


@dataclasses.dataclass
class Schedule:
    arch_name: str
    segments: list[SegmentPlan]
    serial_cycles: float
    scheduled_cycles: float

    @property
    def n_packed(self) -> int:
        return sum(seg.packed for seg in self.segments)

    @property
    def saved_cycles(self) -> float:
        return self.serial_cycles - self.scheduled_cycles

    @property
    def energy_delta_pj(self) -> float:
        return sum(seg.energy_delta_pj for seg in self.segments)

    def totals(self) -> dict[str, float]:
        return {
            "cycles": self.scheduled_cycles,
            "serial_cycles": self.serial_cycles,
            "saved_cycles": self.saved_cycles,
            "n_segments": float(len(self.segments)),
            "n_packed": float(self.n_packed),
            "energy_delta_pj": self.energy_delta_pj,
        }

    def stage_segment_ids(self) -> list[int]:
        """Segment index of each stage in stream order. Stages are the
        input layers of `schedule_network` in order and segments are
        contiguous, so this is the layer-index -> segment map the
        measured-execution backend (`core/executor.py`) uses to annotate
        its ops with the segment that will execute them."""
        out: list[int] = []
        for i, seg in enumerate(self.segments):
            out += [i] * len(seg.stages)
        return out


# ---------------------------------------------------------------------------
# Per-segment cost: core allocation (MIP + greedy water-filling fallback)
# ---------------------------------------------------------------------------

#: Multi-stage runs whose item streams exceed this are not pipelined
#: (packable segments are naturally small — every instance's weights must
#: fit the macros — so this is a guard, not a working limit).
ITEM_FLOW_CAP = 100_000


def _pipeline_compute(ts: Sequence[float], counts: Sequence[int]) -> float:
    """Fill + bottleneck closed form — the *allocation objective* only
    (linear in the MIP's one-hot terms; exact when stage counts are equal,
    optimistic when a downstream stage has more items than an upstream
    one). Segments are *charged* with the exact item recursion
    (`_exact_compute`), never with this."""
    return sum(ts) + max((n - 1) * t for n, t in zip(counts, ts))


def _exact_compute(ts: Sequence[float], counts: Sequence[int]) -> float:
    """Exact makespan of the index-matched item stream at zero ready time
    — the same recursion `simulator.simulate_segment` replays, so
    ``load + _exact_compute`` upper-bounds the replay (delaying every
    stage's start by at most the full load delays the finish by at most
    the full load)."""
    if len(ts) == 1:
        return counts[0] * ts[0]
    from repro.core.simulator import stream_finish_times
    return max(stream_finish_times(counts, ts, [0.0] * len(ts)))


def _allocate_greedy(stages: Sequence[StagePlan], n_cores: int,
                     t_of) -> list[int]:
    """Water-filling: start every stage at its capacity floor, then hand
    spare cores to whichever stage improves the pipelined makespan most
    (mirroring `network.allocate_budgets`' redistribution). Grants are
    multi-core jumps, not single increments: layer factorizations are
    mostly powers of two, so the latency curve is a staircase and +1 core
    frequently sits on a plateau that +2 escapes."""
    alloc = [st.c_min for st in stages]
    counts = [st.count for st in stages]
    spare = n_cores - sum(alloc)

    def obj(a: list[int]) -> float:
        return _pipeline_compute([t_of(i, c) for i, c in enumerate(a)],
                                 counts)

    cur = obj(alloc)
    while spare > 0:
        best = None                     # (obj, extra_cores, stage index)
        for i in range(len(stages)):
            for extra in range(1, spare + 1):
                trial = list(alloc)
                trial[i] += extra
                o = obj(trial)
                if o < cur - 1e-9 and \
                        (best is None or (o, extra) < best[:2]):
                    best = (o, extra, i)
        if best is None:
            break
        cur, extra, i = best
        alloc[i] += extra
        spare -= extra
    return alloc


def _allocate_mip(stages: Sequence[StagePlan], n_cores: int, t_of,
                  time_limit_s: float = ALLOC_MIP_CAP_S) -> list[int] | None:
    """Exact core split: one-hot core choice per stage, shared core budget,
    makespan epigraph variable. Returns None when the solver yields nothing
    usable (the caller keeps the greedy split)."""
    from repro.core.mip.model import LinExpr, MipModel

    m = MipModel("sched-alloc")
    zero = LinExpr({}, 0.0)
    xs: list[dict[int, object]] = []
    for i, st in enumerate(stages):
        vs = {c: m.add_binary(f"x[{i},{c}]")
              for c in range(st.c_min, n_cores + 1)}
        m.add_eq(sum(vs.values(), zero), 1.0)
        xs.append(vs)
    m.add_le(sum((c * v for vs in xs for c, v in vs.items()), zero),
             float(n_cores))
    z = m.add_var("makespan", 0.0)
    fill = zero
    for i, (st, vs) in enumerate(zip(stages, xs)):
        m.add_ge(z - sum(((st.count - 1) * t_of(i, c)) * v
                         for c, v in vs.items()), 0.0)
        fill = fill + sum((t_of(i, c) * v for c, v in vs.items()), zero)
    m.minimize(z + fill)
    try:
        sol = m.solve(time_limit_s=time_limit_s, mip_rel_gap=0.0)
    except Exception:
        return None
    if not sol.ok:
        return None
    alloc = []
    for vs in xs:
        c = max(vs, key=lambda c: sol[vs[c]])
        if sol[vs[c]] < 0.5:
            return None
        alloc.append(c)
    if sum(alloc) > n_cores:
        return None
    return alloc


def _plan_segment(stages: list[StagePlan], arch: CimArch, n_cores: int,
                  scaling: CoreScaling, *, use_mip: bool,
                  mip_time_limit_s: float,
                  layers_of: dict[str, wl.Layer]) -> SegmentPlan:
    seg = SegmentPlan(stages=stages,
                      serial_cycles=sum(st.serial_cycles for st in stages))
    if any(not st.resident or st.c_min > n_cores for st in stages):
        assert len(stages) == 1, "non-resident stages must be singletons"
        return seg                                  # serial, as recorded

    def t_of(i: int, c: int) -> float:
        st = stages[i]
        return st.resident_cycles * scaling.factor(layers_of[st.key],
                                                   st.key, c)

    counts = [st.count for st in stages]

    def exact_of(a: Sequence[int]) -> float:
        return _exact_compute([t_of(i, c) for i, c in enumerate(a)], counts)

    alloc = _allocate_greedy(stages, n_cores, t_of)
    allocator = "greedy"
    if use_mip and len(stages) > 1:
        mip = _allocate_mip(stages, n_cores, t_of,
                            time_limit_s=mip_time_limit_s)
        # both candidates are judged by the EXACT charge, so the MIP's
        # allocation never loses to the greedy fallback under the metric
        # the segment is actually billed with
        if mip is not None and exact_of(mip) <= exact_of(alloc) + 1e-9:
            alloc, allocator = mip, "mip"
    bw = arch.level(0).bytes_per_cycle()
    load = 0.0
    for i, (st, c) in enumerate(zip(stages, alloc)):
        st.cores = c
        st.t_cycles = t_of(i, c)
        load += math.ceil(st.load_bytes / bw)
    seg.load_cycles = load + arch.mode_switch_cycles
    seg.compute_cycles = exact_of(alloc)
    seg.allocator = allocator
    if seg.pipelined_cycles < seg.serial_cycles:
        seg.mode = "pipelined"
    return seg


# ---------------------------------------------------------------------------
# Schedule: DP over contiguous segmentations
# ---------------------------------------------------------------------------

def schedule_network(layers: Sequence, arch: CimArch, *,
                     boundaries: Sequence[int] | None = None,
                     use_mip: bool = True,
                     mip_time_limit_s: float = ALLOC_MIP_CAP_S,
                     verbose: bool = False) -> Schedule:
    """Schedule a network's solved layers onto the chip.

    ``layers`` is `NetworkResult.layers` (or any sequence of objects with
    ``.layer``, ``.count``, ``.key`` and ``.record``, the record carrying
    the solved ``mapping`` + ``cycles``). Stages keep input order (network
    order is execution order); segmentation is a DP over every contiguous
    split, each segment costed at ``min(pipelined, serial)``, so the total
    is optimal for the segment cost model and never worse than the serial
    sum. The final chosen multi-stage segments are re-allocated with the
    exact MIP (greedy fallback, never worse).

    ``boundaries`` marks indices where a new *independent* layer stream
    starts (e.g. the next (model, scenario) workload in a pooled
    benchmark call): no segment may span one — scheduling across
    unrelated networks would fabricate pipelining that can never
    execute."""
    ax = core_axis(arch)
    n_cores = ax.size if ax is not None else 1
    core_bytes = chip_macro_bytes(arch) // max(n_cores, 1)
    scaling = CoreScaling(arch)

    # ---- stage list (one per input layer record, input order) -------------
    # A stage is pipeline-eligible when a weight-stationary mapping exists
    # for it: the record's own mapping when it is resident, else the greedy
    # incumbent's (the per-layer MIP minimizes *solo* latency and may
    # happily stream weights — network-level residency is exactly the
    # objective it cannot see, so the scheduler may swap mappings; the
    # serial baseline always keeps the record's number and the min() guard
    # keeps the swap strictly-improving-or-ignored).
    from repro.core.baselines import greedy_mapping
    from repro.core.energy import evaluate_edp

    stages: list[StagePlan] = []
    layers_of: dict[str, wl.Layer] = {}
    # key -> (resident, resident_cycles, basis, per-instance energy delta)
    basis_of: dict[str, tuple[bool, float, str, float]] = {}
    for lr in layers:
        key = lr.key
        layers_of.setdefault(key, lr.layer)
        if key not in basis_of:
            if lr.layer.weight_written:
                # produced stationary operand: never resident, and the
                # greedy weight-stationary swap has nothing to offer
                basis_of[key] = (False, 0.0, "record", 0.0)
        if key not in basis_of:
            mp = mapping_from_json(lr.record["mapping"])
            resident, fill = weight_residency(mp, lr.layer, arch)
            if resident:
                basis_of[key] = (True, max(lr.record["cycles"] - fill, 1.0),
                                 "record", 0.0)
            else:
                gmp = greedy_mapping(lr.layer, arch)
                g_res, g_fill = weight_residency(gmp, lr.layer, arch)
                if g_res:
                    g = evaluate_edp(gmp, lr.layer, arch)
                    basis_of[key] = (
                        True, max(g.latency.total_cycles - g_fill, 1.0),
                        "greedy",
                        g.energy.total_pj - lr.record["energy_pj"])
                else:
                    basis_of[key] = (False, 0.0, "record", 0.0)
        resident, rc, basis, de = basis_of[key]
        w = weight_bytes(lr.layer)
        c_min = max(1, math.ceil(lr.count * w / max(core_bytes, 1)))
        stages.append(StagePlan(
            name=lr.layer.name, key=key, count=int(lr.count),
            weight_bytes=w,
            serial_cycles=lr.count * lr.record["cycles"],
            resident_cycles=rc, resident=resident, basis=basis,
            energy_delta_pj=lr.count * de, c_min=c_min))

    # ---- DP over contiguous splits ----------------------------------------
    # cost(i, j) = min(pipelined, serial) for stages[i:j]; a run is
    # pipeline-eligible iff every stage is resident and the capacity floors
    # fit the core budget together. Greedy allocation inside the DP (cheap,
    # memoized probes); the exact MIP refines the winning segmentation.
    n = len(stages)
    best = [0.0] + [math.inf] * n
    cut = [0] * (n + 1)
    cuts_inside = sorted(b for b in set(boundaries or ()) if 0 < b < n)

    def run_cost(i: int, j: int) -> float:
        if any(i < b < j for b in cuts_inside):
            return math.inf           # independent streams never co-pack
        sub = stages[i:j]
        if len(sub) > 1 and (
                any(not st.resident for st in sub) or
                sum(st.c_min for st in sub) > n_cores or
                sum(st.count for st in sub) > ITEM_FLOW_CAP):
            return math.inf
        seg = _plan_segment([dataclasses.replace(st) for st in sub],
                            arch, n_cores, scaling, use_mip=False,
                            mip_time_limit_s=mip_time_limit_s,
                            layers_of=layers_of)
        return seg.cycles

    for j in range(1, n + 1):
        for i in range(j - 1, -1, -1):
            if j - i > n_cores:        # each stage needs >= 1 core
                break
            c = run_cost(i, j)
            if best[i] + c < best[j]:
                best[j], cut[j] = best[i] + c, i
            if c == math.inf and j - i > 1:
                break                  # longer runs only get harder

    # ---- materialize the chosen segments (exact-MIP refinement) -----------
    bounds: list[tuple[int, int]] = []
    j = n
    while j > 0:
        bounds.append((cut[j], j))
        j = cut[j]
    bounds.reverse()
    segments = [
        _plan_segment(stages[i:j], arch, n_cores, scaling,
                      use_mip=use_mip, mip_time_limit_s=mip_time_limit_s,
                      layers_of=layers_of)
        for i, j in bounds]

    serial = sum(st.serial_cycles for st in stages)
    scheduled = sum(seg.cycles for seg in segments)
    if verbose:
        packed = sum(seg.packed for seg in segments)
        print(f"[scheduler/{arch.name}] {n} stages -> {len(segments)} "
              f"segments ({packed} packed): {serial:.4g} serial -> "
              f"{scheduled:.4g} scheduled cycles")
    return Schedule(arch_name=arch.name, segments=segments,
                    serial_cycles=serial, scheduled_cycles=scheduled)


# ---------------------------------------------------------------------------
# Event-simulator cross-check (the Fig. 4(a) discipline, network mode)
# ---------------------------------------------------------------------------

def cross_check(schedule: Schedule, arch: CimArch, *,
                max_items: int = 100_000) -> tuple[float, int]:
    """(mean accuracy, n segments checked) of the analytical segment model
    against `simulator.simulate_segment` over every pipelined segment small
    enough to replay. Accuracy per segment is 1 - |model - sim| / sim —
    the exact metric the Fig. 4(a) benchmark and `test_latency_model`'s
    simulator-agreement gate use for single layers."""
    from repro.core.simulator import simulate_segment

    accs = []
    for seg in schedule.segments:
        if seg.mode != "pipelined":
            continue
        if sum(st.count for st in seg.stages) > max_items:
            continue
        sim = simulate_segment(
            [(st.count, st.t_cycles, st.load_bytes) for st in seg.stages],
            arch)
        accs.append(1.0 - abs(seg.pipelined_cycles - sim.total_cycles) /
                    max(sim.total_cycles, 1.0))
    return (sum(accs) / len(accs) if accs else 1.0), len(accs)


# ---------------------------------------------------------------------------
# Mesh schedule: one-hot (chip, core) placement over a MeshArch
# ---------------------------------------------------------------------------
# The single-chip machinery above generalizes to `mesh.MeshArch` one level
# up (DESIGN.md §Mesh optimization): stages carry their *sub-layer* (the
# per-chip shard the mesh record solved), replicate stages place one-hot on
# a (chip, cores) pair, split stages occupy every chip symmetrically (the
# shard is an SPMD decomposition — c cores on each chip), and the segment
# cost adds two mesh terms the single-chip model has no concept of:
# per-item shard communication (`comm_cycles`, inside t_i — it recurs every
# item) and the inter-chip activation hop between adjacent stages hosted on
# different chips (`xfer_cycles`, threaded into the exact item recursion
# via `simulator.stream_finish_times`' xfer argument). Placement candidates
# are judged by that exact charge — xfer included — so the placement MIP
# never loses to the greedy water-filling fallback under the metric the
# segment is billed with, the same discipline `_plan_segment` applies.
# Weight program-ins of ALL chips serialize on one shared DRAM channel
# (the conservative single-host-memory assumption `simulate_segment`
# replays); per-chip residency capacity bounds what each chip holds.


@dataclasses.dataclass
class MeshStagePlan(StagePlan):
    """One layer instance-group of a mesh segment. `weight_bytes` is the
    PER-CHIP sub-layer footprint (the full layer's for replicate); `chip`
    is the host placement (-1 = split stage, resident on every chip)."""

    sub_key: str = ""           # structural key of the per-chip sub-layer
    choice: str = "replicate"   # mesh.SHARD_CHOICES member
    span_all: bool = False      # split stage: occupies all chips
    n_active: int = 1           # chips computing (DRAM-load multiplier)
    comm_cycles: float = 0.0    # per-item shard communication (in t_cycles)
    out_bytes: int = 0          # per-item activation output (xfer volume)
    chip: int = -1              # host chip (replicate) or -1 (split)
    xfer_cycles: float = 0.0    # per-item hop from the upstream stage

    @property
    def total_load_bytes(self) -> int:
        """DRAM bytes programmed across all chips holding this stage."""
        return self.count * self.weight_bytes * self.n_active


def _mesh_hosts(stages: Sequence[MeshStagePlan],
                chips: Sequence[int]) -> list[int]:
    """Activation host chip per stage: a split stage's traffic is anchored
    at chip 0 by convention (its input broadcast/scatter originates there
    and the gather/all-reduce result lands there — `mesh.shard_eval`)."""
    return [g if g >= 0 else 0 for g in chips]


def _mesh_exact(stages: Sequence[MeshStagePlan], chips: Sequence[int],
                cores: Sequence[int], mesh, t_of) -> float:
    """Exact makespan of the placed item stream at zero ready time — the
    recursion `simulate_segment` replays, with the per-item inter-chip
    activation hop between differently-hosted adjacent stages."""
    from repro.core.latency import link_transfer_cycles
    from repro.core.simulator import stream_finish_times

    ts = [t_of(i, c) for i, c in enumerate(cores)]
    counts = [st.count for st in stages]
    if len(stages) == 1:
        return counts[0] * ts[0]
    hosts = _mesh_hosts(stages, chips)
    xfer = [0.0] + [
        link_transfer_cycles(stages[i - 1].out_bytes, mesh.link,
                             mesh.chip_distance(hosts[i - 1], hosts[i]))
        for i in range(1, len(stages))]
    return max(stream_finish_times(counts, ts, [0.0] * len(ts), xfer))


def _mesh_place_greedy(stages: Sequence[MeshStagePlan], mesh, n_cores: int,
                       t_of) -> tuple[list[int], list[int]] | None:
    """Water-filling placement: reserve every split stage on all chips,
    place replicate stages (heaviest first) on the chip with the most free
    macro bytes, then hand spare cores to whichever stage improves the
    pipelined makespan most (`_allocate_greedy`'s multi-core jumps; a
    split stage's grant consumes cores on EVERY chip). None when the
    stages do not co-fit the mesh."""
    n_chips = mesh.n_chips
    cap = chip_macro_bytes(mesh.chip)
    free_b = [float(cap)] * n_chips
    free_c = [n_cores] * n_chips
    chips = [-1] * len(stages)
    for st in stages:
        if st.span_all:
            for g in range(n_chips):
                free_b[g] -= st.load_bytes
                free_c[g] -= st.c_min
    if any(b < 0 for b in free_b) or any(c < 0 for c in free_c):
        return None
    order = sorted((i for i, st in enumerate(stages) if not st.span_all),
                   key=lambda i: -stages[i].load_bytes)
    for i in order:
        st = stages[i]
        cand = [g for g in range(n_chips)
                if free_b[g] >= st.load_bytes and free_c[g] >= st.c_min]
        if not cand:
            return None
        g = max(cand, key=lambda g: (free_b[g], -g))
        chips[i] = g
        free_b[g] -= st.load_bytes
        free_c[g] -= st.c_min

    alloc = [st.c_min for st in stages]
    counts = [st.count for st in stages]

    def obj(a: list[int]) -> float:
        return _pipeline_compute([t_of(i, c) for i, c in enumerate(a)],
                                 counts)

    def spare_for(i: int) -> int:
        return min(free_c) if stages[i].span_all else free_c[chips[i]]

    cur = obj(alloc)
    while True:
        best = None                     # (obj, extra_cores, stage index)
        for i in range(len(stages)):
            for extra in range(1, spare_for(i) + 1):
                trial = list(alloc)
                trial[i] += extra
                o = obj(trial)
                if o < cur - 1e-9 and \
                        (best is None or (o, extra) < best[:2]):
                    best = (o, extra, i)
        if best is None:
            break
        cur, extra, i = best
        alloc[i] += extra
        if stages[i].span_all:
            for g in range(len(free_c)):
                free_c[g] -= extra
        else:
            free_c[chips[i]] -= extra
    return chips, alloc


def _mesh_place_mip(stages: Sequence[MeshStagePlan], mesh, n_cores: int,
                    t_of, time_limit_s: float = ALLOC_MIP_CAP_S
                    ) -> tuple[list[int], list[int]] | None:
    """Exact joint placement: the segment MIP generalized from one-hot
    core choice (`_allocate_mip`) to one-hot **(chip, cores)** choice per
    replicate stage — split stages keep a one-hot cores choice applied on
    every chip — under per-chip core budgets, per-chip residency byte
    capacity and the shared makespan epigraph. Returns None when the
    solver yields nothing usable (the caller keeps the greedy placement)."""
    from repro.core.mip.model import LinExpr, MipModel

    n_chips = mesh.n_chips
    cap = chip_macro_bytes(mesh.chip)
    cap_eff = float(cap) - sum(st.load_bytes for st in stages
                               if st.span_all)
    if cap_eff < 0:
        return None
    m = MipModel("mesh-alloc")
    zero = LinExpr({}, 0.0)
    sel: list[dict] = []                 # stage -> {option: Var}
    for i, st in enumerate(stages):
        crange = range(st.c_min, n_cores + 1)
        if st.span_all:
            opts = list(crange)
        else:
            opts = [(g, c) for g in range(n_chips) for c in crange]
        if not opts:
            return None
        sel.append(m.add_choice(f"x[{i}]", opts))
    for g in range(n_chips):
        cores_g = zero
        bytes_g = zero
        for st, vs in zip(stages, sel):
            if st.span_all:
                cores_g = cores_g + sum((c * v for c, v in vs.items()),
                                        zero)
            else:
                cores_g = cores_g + sum((c * v for (gg, c), v in vs.items()
                                         if gg == g), zero)
                bytes_g = bytes_g + sum(
                    (float(st.load_bytes) * v for (gg, _), v in vs.items()
                     if gg == g), zero)
        m.add_le(cores_g, float(n_cores))
        m.add_le(bytes_g, cap_eff)
    z = m.add_var("makespan", 0.0)
    fill = zero

    def cores_of(opt):
        return opt if isinstance(opt, int) else opt[1]

    for i, (st, vs) in enumerate(zip(stages, sel)):
        m.add_ge(z - sum((((st.count - 1) * t_of(i, cores_of(o))) * v
                          for o, v in vs.items()), zero), 0.0)
        fill = fill + sum((t_of(i, cores_of(o)) * v
                           for o, v in vs.items()), zero)
    m.minimize(z + fill)
    try:
        sol = m.solve(time_limit_s=time_limit_s, mip_rel_gap=0.0)
    except Exception:
        return None
    if not sol.ok:
        return None
    chips, alloc = [], []
    for st, vs in zip(stages, sel):
        o = max(vs, key=lambda o: sol[vs[o]])
        if sol[vs[o]] < 0.5:
            return None
        if st.span_all:
            chips.append(-1)
            alloc.append(o)
        else:
            chips.append(o[0])
            alloc.append(o[1])
    # re-verify the budgets the way _allocate_mip re-verifies its core sum
    for g in range(n_chips):
        used_c = sum(c for st, gg, c in zip(stages, chips, alloc)
                     if st.span_all or gg == g)
        used_b = sum(st.load_bytes for st, gg in zip(stages, chips)
                     if not st.span_all and gg == g)
        if used_c > n_cores or used_b > cap_eff + 1e-6:
            return None
    return chips, alloc


def _plan_mesh_segment(stages: list[MeshStagePlan], mesh,
                       scaling: CoreScaling, *, use_mip: bool,
                       mip_time_limit_s: float,
                       layers_of: dict[str, wl.Layer]) -> SegmentPlan:
    """Mesh counterpart of `_plan_segment`: same SegmentPlan contract
    (min(pipelined, serial) charging, exact-judged MIP-over-greedy), with
    placement instead of bare core allocation. A multi-stage run that does
    not co-fit the mesh simply stays serial (equivalent to the DP's
    singleton split, never wrong)."""
    chip = mesh.chip
    ax = core_axis(chip)
    n_cores = ax.size if ax is not None else 1
    seg = SegmentPlan(stages=stages,
                      serial_cycles=sum(st.serial_cycles for st in stages))
    if any(not st.resident or st.c_min > n_cores for st in stages):
        assert len(stages) == 1, "non-resident stages must be singletons"
        return seg

    def t_of(i: int, c: int) -> float:
        st = stages[i]
        return st.resident_cycles * scaling.factor(
            layers_of[st.sub_key], st.sub_key, c) + st.comm_cycles

    placed = _mesh_place_greedy(stages, mesh, n_cores, t_of)
    if placed is None:
        return seg                                  # does not co-fit: serial
    allocator = "greedy"

    def exact_of(p: tuple[list[int], list[int]]) -> float:
        return _mesh_exact(stages, p[0], p[1], mesh, t_of)

    if use_mip and len(stages) > 1:
        mip = _mesh_place_mip(stages, mesh, n_cores, t_of,
                              time_limit_s=mip_time_limit_s)
        if mip is not None and exact_of(mip) <= exact_of(placed) + 1e-9:
            placed, allocator = mip, "mip"
    chips, alloc = placed
    from repro.core.latency import link_transfer_cycles
    hosts = _mesh_hosts(stages, chips)
    bw = chip.level(0).bytes_per_cycle()
    load = 0.0
    for i, (st, g, c) in enumerate(zip(stages, chips, alloc)):
        st.chip = g
        st.cores = c
        st.t_cycles = t_of(i, c)
        st.xfer_cycles = 0.0 if i == 0 else link_transfer_cycles(
            stages[i - 1].out_bytes, mesh.link,
            mesh.chip_distance(hosts[i - 1], hosts[i]))
        load += math.ceil(st.total_load_bytes / bw)
    seg.load_cycles = load + chip.mode_switch_cycles
    seg.compute_cycles = exact_of(placed)
    seg.allocator = allocator
    if seg.pipelined_cycles < seg.serial_cycles:
        seg.mode = "pipelined"
    return seg


def schedule_mesh(layers: Sequence, mesh, *,
                  boundaries: Sequence[int] | None = None,
                  use_mip: bool = True,
                  mip_time_limit_s: float = ALLOC_MIP_CAP_S,
                  verbose: bool = False) -> Schedule:
    """Schedule a network's *mesh* records (`mesh.optimize_mesh_network`)
    onto a `mesh.MeshArch` — `schedule_network` one level up. A 1-chip
    mesh IS the chip: delegate, bit for bit.

    Stage basis mirrors `schedule_network` exactly, applied to each
    record's **sub-layer** (reconstructed from the record's shard
    decomposition): residency/fill from the sub-mapping on ``mesh.chip``,
    the greedy weight-stationary swap when the record's own mapping
    streams weights, core-sensitivity via the chip's `CoreScaling`. On
    top, each stage's per-item latency carries its shard communication
    (``+ comm_cycles``, not core-scaled — link time does not shrink with
    cores) and segments pay per-item activation hops between
    differently-hosted adjacent stages."""
    from repro.core.mesh import (REPLICATE, out_bytes_per_elem,
                                 shard_sub_layer)
    from repro.core.arch import OUTPUT

    if mesh.n_chips <= 1:
        return schedule_network(layers, mesh.chip, boundaries=boundaries,
                                use_mip=use_mip,
                                mip_time_limit_s=mip_time_limit_s,
                                verbose=verbose)
    chip = mesh.chip
    ax = core_axis(chip)
    n_cores = ax.size if ax is not None else 1
    core_bytes = chip_macro_bytes(chip) // max(n_cores, 1)
    scaling = CoreScaling(chip)

    from repro.core.baselines import greedy_mapping
    from repro.core.energy import evaluate_edp

    stages: list[MeshStagePlan] = []
    layers_of: dict[str, wl.Layer] = {}
    # full-layer key -> (resident, resident_cycles, basis, per-instance
    # energy delta) — the shard choice is a function of the full-layer key
    # within one mesh solve, so the memo stays keyed like schedule_network's
    basis_of: dict[str, tuple[bool, float, str, float]] = {}
    for lr in layers:
        rec = lr.record
        shard = rec.get("shard") or {}
        choice = shard.get("choice", REPLICATE)
        n_active = int(shard.get("n_active", 1))
        sub = shard_sub_layer(lr.layer, choice, mesh.n_chips)
        sub_key = layer_cache_key(sub)
        layers_of.setdefault(sub_key, sub)
        chip_cycles = float(rec.get("chip_cycles", rec["cycles"]))
        chip_energy = float(rec.get("chip_energy_pj", rec["energy_pj"]))
        comm = float(rec.get("comm_cycles", 0.0))
        if lr.key not in basis_of and sub.weight_written:
            # produced stationary operand (wGrad shard): never resident
            basis_of[lr.key] = (False, 0.0, "record", 0.0)
        if lr.key not in basis_of:
            mp = mapping_from_json(rec["mapping"])
            resident, fill = weight_residency(mp, sub, chip)
            if resident:
                basis_of[lr.key] = (True, max(chip_cycles - fill, 1.0),
                                    "record", 0.0)
            else:
                gmp = greedy_mapping(sub, chip)
                g_res, g_fill = weight_residency(gmp, sub, chip)
                if g_res:
                    g = evaluate_edp(gmp, sub, chip)
                    basis_of[lr.key] = (
                        True, max(g.latency.total_cycles - g_fill, 1.0),
                        "greedy",
                        n_active * (g.energy.total_pj - chip_energy))
                else:
                    basis_of[lr.key] = (False, 0.0, "record", 0.0)
        resident, rc, basis, de = basis_of[lr.key]
        w = weight_bytes(sub)
        c_min = max(1, math.ceil(lr.count * w / max(core_bytes, 1)))
        stages.append(MeshStagePlan(
            name=lr.layer.name, key=lr.key, count=int(lr.count),
            weight_bytes=w,
            serial_cycles=lr.count * rec["cycles"],
            resident_cycles=rc, resident=resident, basis=basis,
            energy_delta_pj=lr.count * de, c_min=c_min,
            sub_key=sub_key, choice=choice,
            span_all=choice != REPLICATE, n_active=n_active,
            comm_cycles=comm,
            out_bytes=lr.layer.operand_elems(OUTPUT) *
            out_bytes_per_elem(lr.layer)))

    # ---- DP over contiguous splits (schedule_network's, mesh budgets) -----
    n = len(stages)
    n_chips = mesh.n_chips
    best = [0.0] + [math.inf] * n
    cut = [0] * (n + 1)
    cuts_inside = sorted(b for b in set(boundaries or ()) if 0 < b < n)

    def run_cost(i: int, j: int) -> float:
        if any(i < b < j for b in cuts_inside):
            return math.inf           # independent streams never co-pack
        sub = stages[i:j]
        if len(sub) > 1 and (
                any(not st.resident for st in sub) or
                sum(st.c_min * (n_chips if st.span_all else 1)
                    for st in sub) > n_chips * n_cores or
                sum(st.count for st in sub) > ITEM_FLOW_CAP):
            return math.inf
        seg = _plan_mesh_segment([dataclasses.replace(st) for st in sub],
                                 mesh, scaling, use_mip=False,
                                 mip_time_limit_s=mip_time_limit_s,
                                 layers_of=layers_of)
        return seg.cycles

    for j in range(1, n + 1):
        for i in range(j - 1, -1, -1):
            if j - i > n_chips * n_cores:   # each stage needs >= 1 core
                break
            c = run_cost(i, j)
            if best[i] + c < best[j]:
                best[j], cut[j] = best[i] + c, i
            if c == math.inf and j - i > 1:
                break                  # longer runs only get harder

    bounds: list[tuple[int, int]] = []
    j = n
    while j > 0:
        bounds.append((cut[j], j))
        j = cut[j]
    bounds.reverse()
    segments = [
        _plan_mesh_segment(stages[i:j], mesh, scaling, use_mip=use_mip,
                           mip_time_limit_s=mip_time_limit_s,
                           layers_of=layers_of)
        for i, j in bounds]

    serial = sum(st.serial_cycles for st in stages)
    scheduled = sum(seg.cycles for seg in segments)
    if verbose:
        packed = sum(seg.packed for seg in segments)
        print(f"[scheduler/{mesh.name}] {n} stages -> {len(segments)} "
              f"segments ({packed} packed, {n_chips} chips): "
              f"{serial:.4g} serial -> {scheduled:.4g} scheduled cycles")
    return Schedule(arch_name=mesh.name, segments=segments,
                    serial_cycles=serial, scheduled_cycles=scheduled)


def cross_check_mesh(schedule: Schedule, mesh, *,
                     max_items: int = 100_000) -> tuple[float, int]:
    """`cross_check` for mesh schedules: replay every pipelined segment
    through `simulator.simulate_segment` in network mode — total DRAM
    load bytes across all chips holding each stage, per-item inter-chip
    activation hops as the 4th stage element — and report the same
    Fig. 4(a) mean-accuracy metric."""
    from repro.core.simulator import simulate_segment

    accs = []
    for seg in schedule.segments:
        if seg.mode != "pipelined":
            continue
        if sum(st.count for st in seg.stages) > max_items:
            continue
        sim = simulate_segment(
            [(st.count, st.t_cycles, st.total_load_bytes, st.xfer_cycles)
             for st in seg.stages], mesh.chip)
        accs.append(1.0 - abs(seg.pipelined_cycles - sim.total_cycles) /
                    max(sim.total_cycles, 1.0))
    return (sum(accs) / len(accs) if accs else 1.0), len(accs)
