"""Discrete-event pipeline simulator (the paper's "detailed hardware
simulation", §V-A) used to validate the analytical latency model (Fig 4a).

Simulates the mapped loop nest iteration-by-iteration with explicit:
  * per-link transfer channels (serialized on each source level's bus),
  * single/double buffer occupancy per (operand, destination level) —
    single: the next tile transfer must wait until the current tile's last
    consumer finishes (mutually-exclusive access, Fig. 2(b));
    double: one prefetch outstanding (half-capacity already enforced by the
    mapping validator),
  * operand synchronization — an MVM fires only when BOTH its input and
    weight chunks have arrived (Fig. 2(c)),
  * CIM mode-switch stalls — weight reloads into the macro array require
    compute to drain, pay ``mode_switch_cycles``, and never overlap MVMs
    (Fig. 2(a)),
  * output write-back — single-buffered output registers block the next MVM
    until the previous chunk drains (Fig. 2(c)).

This is an independent implementation sharing only the tile-geometry helpers
with latency.py, so agreement between the two is meaningful evidence.

Call path: the optimizers and the network pipeline score mappings with the
analytical model (`latency.evaluate` via `energy.evaluate_edp` — DESIGN.md
§Network pipeline); the simulator is the *out-of-band* cross-check, driven
by `benchmarks/fig4a_model_accuracy.py` (accuracy over sampled mappings)
and `examples/quickstart.py` (single-layer sanity check). It never sits on
the solve path. `simulate_segment` is the *network-mode* counterpart: it
replays one weight-resident segment of the multi-core scheduler
(`core/scheduler.py`) and cross-checks the analytical schedule model the
same way (`scheduler.cross_check`, `benchmarks/sched_lm.py`).
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import workload as wl
from repro.core.arch import CimArch, INPUT, OPERANDS, OUTPUT, WEIGHT
from repro.core.mapping import Mapping


@dataclasses.dataclass
class Hop:
    operand: str
    src: int                 # source level (owns the bus channel)
    dst: int                 # destination level
    chunk_cycles: int        # per-transfer cycles (incl. mode switch)
    watch: tuple[int, ...]   # temporal slot indices whose change retriggers
    double: bool
    is_macro_reload: bool


def _build_hops(mapping: Mapping, layer: wl.Layer, arch: CimArch) -> list[Hop]:
    hops: list[Hop] = []
    for lam in OPERANDS:
        used = mapping.used_levels(lam)
        if not used or used[0] != 0:
            used = [0] + used
        for src, dst in zip(used, used[1:]):
            # chunk = B^T at the source level (same as Mapping.transfer_bytes
            # and the MIP's TC: full multicast traffic, source precision).
            chunk = mapping.transfer_bytes(layer, lam, arch, src)
            bw = mapping.eff_bw_bytes(arch, src)
            cyc = math.ceil(chunk / bw)
            reload = lam == WEIGHT and dst == arch.macro_level
            if reload:
                cyc += arch.mode_switch_cycles
            watch = tuple(
                i for i, (dim, _) in enumerate(mapping.temporal)
                if mapping.level_of[lam][i] < dst and wl.is_relevant(dim, lam))
            dbl = mapping.is_double_buffered(lam, dst, arch) and not reload
            hops.append(Hop(lam, src, dst, cyc, watch, dbl, reload))
    return hops


@dataclasses.dataclass
class SimReport:
    total_cycles: float
    mvm_count: int
    stall_breakdown: dict[str, float]


def simulate(mapping: Mapping, layer: wl.Layer, arch: CimArch,
             max_iters: int = 2_000_000) -> SimReport:
    slots = mapping.temporal
    n_slots = len(slots)
    iters = math.prod(f for _, f in slots)
    if iters > max_iters:
        raise ValueError(f"temporal space {iters} > max_iters {max_iters}")

    hops = _build_hops(mapping, layer, arch)
    in_hops = [h for h in hops if h.operand in (INPUT, WEIGHT)]
    out_hops = [h for h in hops if h.operand == OUTPUT]
    l_mvm = arch.l_mvm_cycles

    # State -----------------------------------------------------------------
    chan_free = [0.0] * arch.n_levels          # per source-level bus
    compute_free = 0.0
    # per-hop: time current tile became ready; release times of buffer slots
    ready = [0.0] * len(hops)
    # buffer slot release times (len 1 = single, 2 = double)
    slots_free: list[list[float]] = [
        [0.0] * (2 if h.double else 1) for h in hops]
    last_consume = [0.0] * len(hops)
    stalls = {"transfer_wait": 0.0, "mode_switch": 0.0, "writeback": 0.0}

    # First fill: every inbound hop transfers its first chunk at t=0,
    # respecting hierarchy order (parent before child).
    order = sorted(range(len(hops)), key=lambda k: hops[k].dst)
    parent_ready: dict[tuple[str, int], float] = {}

    def do_transfer(k: int, now: float) -> float:
        h = hops[k]
        pr = parent_ready.get((h.operand, h.src), 0.0)
        sf = min(slots_free[k])
        start = max(now, chan_free[h.src], pr, sf)
        if h.is_macro_reload:
            start = max(start, compute_free)
        end = start + h.chunk_cycles
        chan_free[h.src] = end
        # occupy the freed slot; the true release time is set when the
        # tile is retired (on the next transfer for this hop).
        i = slots_free[k].index(sf)
        slots_free[k][i] = end
        parent_ready[(h.operand, h.dst)] = end
        return end

    counters = [0] * n_slots
    total_mvm = 0
    now = 0.0
    for k in order:
        if hops[k].operand != OUTPUT:
            ready[k] = do_transfer(k, 0.0)

    for it in range(iters):
        changed = set()
        if it > 0:
            # odometer increment, innermost first
            for pos in range(n_slots - 1, -1, -1):
                counters[pos] += 1
                changed.add(pos)
                if counters[pos] < slots[pos][1]:
                    break
                counters[pos] = 0
            else:
                pass
        # retrigger transfers whose watched loops changed
        if it > 0:
            for k in order:
                h = hops[k]
                if h.operand == OUTPUT:
                    continue
                if changed & set(h.watch):
                    # retire old tile: slot frees when last consumer done
                    j = slots_free[k].index(min(slots_free[k]))
                    slots_free[k][j] = last_consume[k]
                    ready[k] = do_transfer(k, now)
        # operand sync: innermost input+weight chunks must be present
        t_ready = now
        for k, h in enumerate(hops):
            if h.operand != OUTPUT and h.dst == mapping.deepest_used(h.operand):
                t_ready = max(t_ready, ready[k])
        stalls["transfer_wait"] += max(0.0, t_ready - max(now, compute_free))
        start = max(t_ready, compute_free)
        end = start + l_mvm
        compute_free = end
        for k, h in enumerate(hops):
            if h.operand != OUTPUT:
                last_consume[k] = end
        total_mvm += 1
        now = end
        # output write-back per hop when its watched loops will change next
        # (drain at tile boundary). Approximate: drain the innermost output
        # hop every iteration group where the output tile index changes.
        for k, h in enumerate(hops):
            if h.operand != OUTPUT:
                continue
            nxt_change = _will_change(counters, slots, h.watch)
            if nxt_change or it == iters - 1:
                sf = min(slots_free[k])
                start_t = max(now, chan_free[h.src], sf)
                end_t = start_t + h.chunk_cycles
                chan_free[h.src] = end_t
                j = slots_free[k].index(sf)
                slots_free[k][j] = end_t
                if not h.double:
                    stalls["writeback"] += max(0.0, end_t - now)
                    compute_free = max(compute_free, end_t)
                now = max(now, min(end_t, compute_free)) if h.double else now

    # drain channels
    final = max([compute_free] + chan_free)
    return SimReport(total_cycles=final, mvm_count=total_mvm,
                     stall_breakdown=stalls)


# ---------------------------------------------------------------------------
# Network mode: segment-level event simulation (DESIGN.md §Network scheduler)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SegmentSimReport:
    total_cycles: float
    load_cycles: float          # when the last weight program-in leaves the bus
    stage_finish: list[float]   # per stage, its last item's completion time


def stream_finish_times(counts, ts, ready, xfer=None) -> list[float]:
    """Last-item finish time per stage of the index-matched item stream:
    item k of stage i starts after item k-1 on the same stage AND item
    min(k, count_{i-1}-1) of the upstream stage, each item taking ``ts[i]``
    cycles, stage i's first item not before ``ready[i]``. This recursion IS
    the segment dependency structure — `simulate_segment` replays it with
    bus-serialized ready times, and the scheduler charges its segments
    with the zero-ready evaluation (`scheduler`), so the two can never
    encode different pipelines.

    ``xfer`` (mesh network mode) is a per-stage inter-chip activation
    transfer: when adjacent stages of a segment live on different chips,
    the upstream item must additionally cross ``xfer[i]`` cycles of links
    before stage i may consume it (`latency.link_transfer_cycles` over the
    host-chip distance — `scheduler.schedule_mesh`). ``None`` or all-zero
    is exactly the single-chip recursion."""
    finish_prev: list[float] | None = None
    out: list[float] = []
    xfer = [0.0] * len(counts) if xfer is None else list(xfer)
    for n, t, rdy, x in zip(counts, ts, ready, xfer):
        fin = [0.0] * n
        cur = float(rdy)
        for k in range(n):
            dep = 0.0
            if finish_prev is not None:
                dep = finish_prev[min(k, len(finish_prev) - 1)] + x
            fin[k] = max(cur, dep) + t
            cur = fin[k]
        finish_prev = fin
        out.append(fin[-1])
    return out


def simulate_segment(stages, arch: CimArch,
                     max_items: int = 1_000_000) -> SegmentSimReport:
    """Event-driven replay of one weight-resident segment — the network-mode
    counterpart of `simulate` that validates the scheduler's analytical
    segment model (`scheduler._pipeline_compute` + load term) the way
    Fig. 4(a) validates `latency.evaluate` for single layers.

    ``stages`` is an ordered sequence of ``(count, t_cycles, load_bytes)``
    triples (what `scheduler.SegmentPlan` stages carry): ``count`` items of
    ``t_cycles`` each, with ``load_bytes`` of weights programmed into the
    stage's macros before its first item. Mesh network mode appends a 4th
    element, ``xfer_cycles``: the per-item inter-chip activation hop from
    the upstream stage's host chip (`scheduler.schedule_mesh`), threaded
    into the item recursion via `stream_finish_times`' ``xfer``.
    Mechanics, reusing the single-layer machinery's conventions:

      * every weight program-in is a `Hop` (DRAM -> macro, macro-reload) and
        all of them serialize on the DRAM bus channel (``chan_free[0]``,
        exactly like `simulate`'s per-source-level channels); the stage's
        cores then pay ``mode_switch_cycles`` off-bus before computing;
      * items stream: item k of stage i starts after item k-1 on the same
        stage's cores AND item min(k, count_{i-1}-1) of the upstream stage
        (GBuf->GBuf activation streaming; index-matched, surplus downstream
        items follow the last upstream item — `stream_finish_times`, the
        same recursion the scheduler charges its segments with).

    Unlike the analytical model — which conservatively serializes the whole
    segment load before any compute — the replay lets early stages compute
    while later stages' weights still stream, so it never finishes later;
    agreement within the Fig. 4(a) tolerance is what
    `scheduler.cross_check` asserts."""
    stages = [(int(s[0]), float(s[1]), int(s[2]),
               float(s[3]) if len(s) > 3 else 0.0) for s in stages]
    if sum(n for n, _, _, _ in stages) > max_items:
        raise ValueError(f"segment items exceed max_items {max_items}")
    bw = arch.level(0).bytes_per_cycle()
    chan_free = [0.0] * arch.n_levels
    hops = [Hop(WEIGHT, 0, arch.macro_level, math.ceil(b / bw), (),
                False, True) for _, _, b, _ in stages]
    ready: list[float] = []
    for hop in hops:
        start = chan_free[hop.src]
        chan_free[hop.src] = start + hop.chunk_cycles
        ready.append(chan_free[hop.src] + arch.mode_switch_cycles)
    load_cycles = chan_free[0]

    stage_finish = stream_finish_times(
        [n for n, _, _, _ in stages], [t for _, t, _, _ in stages], ready,
        xfer=[x for _, _, _, x in stages])
    total = max(stage_finish + [load_cycles])
    return SegmentSimReport(total_cycles=total, load_cycles=load_cycles,
                            stage_finish=stage_finish)


def _will_change(counters: list[int], slots, watch: tuple[int, ...]) -> bool:
    """True if the next odometer increment flips any watched position."""
    if not watch:
        return False
    # next increment flips positions from the innermost up to the first
    # position that does not wrap
    n = len(slots)
    for pos in range(n - 1, -1, -1):
        if pos in watch:
            return True
        if counters[pos] + 1 < slots[pos][1]:
            return False
    return False
