"""Loop-nest workload representation (paper §IV: DNN operators as loop nests).

Every supported operator is expressed over the canonical 7-dim conv loop nest

    N  : batch
    K  : output channels
    C  : input channels (reduction)
    OY : output rows
    OX : output cols
    FY : filter rows
    FX : filter cols

GEMM  (M x K_red) @ (K_red x N_out)  is the special case
    N=M, K=N_out, C=K_red, OY=OX=FY=FX=1,
which is how every LM-architecture layer (attention projections, FFN mats,
MoE expert GEMMs, SSD block matmuls) enters MIREDO: the model frontend
(``core/frontend.py`` + ``core/lm_workloads.py``) lowers every registry
``ModelConfig`` under a ``ShapeSpec`` scenario to this form and feeds it
through the network pipeline. This module keeps only the canonical
representation and the conv-zoo tables.

Operand relevance (which dims index which tensor):
    I: N, C, IY(OY,FY), IX(OX,FX)       W: K, C, FY, FX       O: N, K, OY, OX
"""

from __future__ import annotations

import dataclasses
import math
from typing import Mapping as TMapping

from repro.core.arch import INPUT, OUTPUT, WEIGHT

DIMS = ("N", "K", "C", "OY", "OX", "FY", "FX")

# Dims that index each operand directly. Input rows/cols couple (OY,FY) and
# (OX,FX) through the sliding window — handled in `operand_tile_elems`.
RELEVANT = {
    INPUT: ("N", "C", "OY", "OX", "FY", "FX"),
    WEIGHT: ("K", "C", "FY", "FX"),
    OUTPUT: ("N", "K", "OY", "OX"),
}


def is_relevant(dim: str, operand: str) -> bool:
    return dim in RELEVANT[operand]


# Op kinds: which kernel primitive executes a layer on the measured-execution
# backend (`core/executor.py`). Every layer is still the same canonical loop
# nest for the MIP/latency stack; the kind only routes *execution*:
#   OP_GEMM      -> kernels/matmul_int8 (the CIM MVM primitive)
#   OP_ATTENTION -> attention projections; the executor additionally runs the
#                   score/AV stage on kernels/flash_attention per block
#   OP_SSD       -> SSD duality matmuls; the intra-chunk pair runs fused on
#                   kernels/ssd_scan, the state GEMMs on matmul_int8
#   OP_DGRAD     -> backward activation-grad GEMM (delta_X = delta_Y . W^T);
#                   plain matmul_int8 on the executor
#   OP_WGRAD     -> backward weight-grad GEMM (delta_W = X^T . delta_Y);
#                   plain matmul_int8, but its macro-resident operand is
#                   *produced* per step (``weight_written`` below)
OP_GEMM = "gemm"
OP_ATTENTION = "attention"
OP_SSD = "ssd"
OP_DGRAD = "dgrad"
OP_WGRAD = "wgrad"
OP_KINDS = (OP_GEMM, OP_ATTENTION, OP_SSD, OP_DGRAD, OP_WGRAD)


@dataclasses.dataclass(frozen=True)
class Layer:
    """One operator instance = loop bounds + stride + name (+ op kind).

    ``op`` tags the kernel family that executes this layer
    (`core/executor.py`); it is display/dispatch metadata like ``name``.
    ``weight_written`` marks a layer whose macro-resident ("weight"-slot)
    operand is *produced* by the step that uses it rather than preloaded
    from DRAM — wGrad GEMMs (the stationary operand is an activation
    gradient) and the backward of activation-activation matmuls. It IS
    structural: the scheduler's residency basis and the formulation's
    stationary-operand amortization are invalid for written operands, so
    `cache.layer_cache_key` (network dedup, record cache, scheduler basis
    memo) covers loop bounds + stride + weight_written."""

    name: str
    dims: TMapping[str, int]  # bound per canonical dim (>=1)
    stride: int = 1
    op: str = OP_GEMM
    weight_written: bool = False

    def __post_init__(self):
        assert self.op in OP_KINDS, (self.name, self.op)
        for d in DIMS:
            assert self.dims.get(d, 1) >= 1, (self.name, d)

    def bound(self, d: str) -> int:
        return int(self.dims.get(d, 1))

    @property
    def macs(self) -> int:
        return math.prod(self.bound(d) for d in DIMS)

    def operand_elems(self, operand: str) -> int:
        """Total element count of one operand tensor."""
        return operand_tile_elems(self, operand,
                                  {d: self.bound(d) for d in DIMS})

    @property
    def is_gemm(self) -> bool:
        return all(self.bound(d) == 1 for d in ("OY", "OX", "FY", "FX"))


def operand_tile_elems(layer: Layer, operand: str,
                       tile: TMapping[str, int]) -> int:
    """Element count of an operand tile given per-dim tile bounds.

    Input spatial extent uses the sliding-window relation
        IY = (oy - 1) * stride + fy   (and likewise IX),
    the standard Timeloop/ZigZag halo accounting.
    """
    t = lambda d: int(tile.get(d, 1))
    if operand == WEIGHT:
        return t("K") * t("C") * t("FY") * t("FX")
    if operand == OUTPUT:
        return t("N") * t("K") * t("OY") * t("OX")
    iy = (t("OY") - 1) * layer.stride + t("FY")
    ix = (t("OX") - 1) * layer.stride + t("FX")
    return t("N") * t("C") * iy * ix


def conv(name: str, n: int, k: int, c: int, oy: int, ox: int,
         fy: int, fx: int, stride: int = 1) -> Layer:
    return Layer(name, {"N": n, "K": k, "C": c, "OY": oy, "OX": ox,
                        "FY": fy, "FX": fx}, stride)


def gemm(name: str, m: int, n_out: int, k_red: int, op: str = OP_GEMM,
         weight_written: bool = False) -> Layer:
    """(m x k_red) @ (k_red x n_out)."""
    return Layer(name, {"N": m, "K": n_out, "C": k_red}, op=op,
                 weight_written=weight_written)


# ---------------------------------------------------------------------------
# Model workload tables.
# ---------------------------------------------------------------------------

def resnet18(batch: int = 1) -> list[Layer]:
    """ResNet-18 / ImageNet conv layers (the paper's baseline workload).

    Unique conv shapes with multiplicity folded into the name; INT8 W/A per
    the paper's setup. Downsample (1x1 stride-2) projections included.
    """
    ls: list[Layer] = [
        conv("conv1", batch, 64, 3, 112, 112, 7, 7, stride=2),
        conv("conv2_x", batch, 64, 64, 56, 56, 3, 3),        # x4
        conv("conv3_1", batch, 128, 64, 28, 28, 3, 3, stride=2),
        conv("conv3_ds", batch, 128, 64, 28, 28, 1, 1, stride=2),
        conv("conv3_x", batch, 128, 128, 28, 28, 3, 3),      # x3
        conv("conv4_1", batch, 256, 128, 14, 14, 3, 3, stride=2),
        conv("conv4_ds", batch, 256, 128, 14, 14, 1, 1, stride=2),
        conv("conv4_x", batch, 256, 256, 14, 14, 3, 3),      # x3
        conv("conv5_1", batch, 512, 256, 7, 7, 3, 3, stride=2),
        conv("conv5_ds", batch, 512, 256, 7, 7, 1, 1, stride=2),
        conv("conv5_x", batch, 512, 512, 7, 7, 3, 3),        # x3
        gemm("fc", batch, 1000, 512),
    ]
    return ls


RESNET18_MULTIPLICITY = {
    "conv2_x": 4, "conv3_x": 3, "conv4_x": 3, "conv5_x": 3,
}


def resnet50(batch: int = 1) -> list[Layer]:
    ls = [conv("conv1", batch, 64, 3, 112, 112, 7, 7, stride=2)]
    spec = [(64, 256, 56, 3), (128, 512, 28, 4), (256, 1024, 14, 6),
            (512, 2048, 7, 3)]
    cin = 64
    for i, (mid, out, hw, _reps) in enumerate(spec):
        s = 1 if i == 0 else 2
        ls += [
            conv(f"b{i}_red", batch, mid, cin, hw, hw, 1, 1, stride=s),
            conv(f"b{i}_3x3", batch, mid, mid, hw, hw, 3, 3),
            conv(f"b{i}_exp", batch, out, mid, hw, hw, 1, 1),
            conv(f"b{i}_ds", batch, out, cin, hw, hw, 1, 1, stride=s),
        ]
        cin = out
    ls.append(gemm("fc", batch, 1000, 2048))
    return ls


def mobilenet_v2_slice(batch: int = 1) -> list[Layer]:
    """Representative MobileNetV2 pointwise/expansion convs (depthwise convs
    are not MVM-shaped for a CIM macro and are executed on the SIMD unit —
    standard practice; see DESIGN.md)."""
    return [
        conv("pw1", batch, 96, 16, 112, 112, 1, 1),
        conv("pw2", batch, 144, 24, 56, 56, 1, 1),
        conv("pw3", batch, 192, 32, 28, 28, 1, 1),
        conv("pw4", batch, 384, 64, 14, 14, 1, 1),
        conv("pw5", batch, 960, 160, 7, 7, 1, 1),
        gemm("fc", batch, 1000, 1280),
    ]


def vgg16_slice(batch: int = 1) -> list[Layer]:
    return [
        conv("c1", batch, 64, 3, 224, 224, 3, 3),
        conv("c3", batch, 128, 128, 112, 112, 3, 3),
        conv("c6", batch, 256, 256, 56, 56, 3, 3),
        conv("c9", batch, 512, 512, 28, 28, 3, 3),
        conv("c13", batch, 512, 512, 14, 14, 3, 3),
        gemm("fc1", batch, 4096, 25088),
    ]


def bert_base_layer(seq: int = 128) -> list[Layer]:
    d, ff = 768, 3072
    return [
        gemm("qkv", seq, 3 * d, d),
        gemm("attn_out", seq, d, d),
        gemm("ffn_up", seq, ff, d),
        gemm("ffn_down", seq, d, ff),
    ]


def lm_block_gemms(name: str, d_model: int, n_heads: int, kv_heads: int,
                   d_ff: int, seq: int, *, gated: bool = True,
                   n_experts: int = 0, top_k: int = 0) -> list[Layer]:
    """GEMM workloads of one hand-parameterized LM transformer block.

    Kept for the fig5a block-level comparison; whole-model extraction from
    a registry ``ModelConfig`` (GQA KV sizing, shared experts, SSD blocks,
    scenarios) lives in ``core/frontend.py``."""
    head_dim = d_model // n_heads
    ls = [
        gemm(f"{name}.wq", seq, n_heads * head_dim, d_model),
        gemm(f"{name}.wk", seq, kv_heads * head_dim, d_model),
        gemm(f"{name}.wv", seq, kv_heads * head_dim, d_model),
        gemm(f"{name}.wo", seq, d_model, n_heads * head_dim),
    ]
    if n_experts:
        tok_per_exp = max(1, seq * top_k // n_experts)
        ls += [
            gemm(f"{name}.exp_up", tok_per_exp, d_ff * (2 if gated else 1),
                 d_model),
            gemm(f"{name}.exp_down", tok_per_exp, d_model, d_ff),
        ]
    elif d_ff:
        ls += [
            gemm(f"{name}.ffn_up", seq, d_ff * (2 if gated else 1), d_model),
            gemm(f"{name}.ffn_down", seq, d_model, d_ff),
        ]
    return ls


MODEL_ZOO = {
    "resnet18": resnet18,
    "resnet50": resnet50,
    "mobilenetv2": mobilenet_v2_slice,
    "vgg16": vgg16_slice,
    "bert-base": bert_base_layer,
}
