"""Measured-execution backend: run an optimized plan on the Pallas kernels
and validate predicted vs measured (DESIGN.md §Executor).

Everything upstream of this module *predicts*: the MIP, the analytical
latency model and the event simulator agree with each other, but none of
them executes a kernel. This module closes that loop — the CIMFlow-style
execution+evaluation backend the ROADMAP's "runs as fast as the hardware
allows" demands:

  1. **Lowering.** A solved ``NetworkResult`` (plus its scheduler
     ``Schedule``) for one (model, scenario) pair is lowered to an
     ``ExecPlan``: every frontend layer, tagged with its op kind in
     `core/lm_workloads.py` (``workload.OP_GEMM`` / ``OP_ATTENTION`` /
     ``OP_SSD``), becomes an ``ExecOp`` dispatched to the kernel family
     that executes it —

       * weight GEMMs (projections, FFN/MoE mats, SSD state GEMMs, the LM
         head) -> `kernels/matmul_int8`, block shapes derived from the
         layer's *optimized mapping* by the TPU bridge
         (`tpu_bridge.select_blocks_from_mapping`);
       * one score/AV stage per attention block -> `kernels/
         flash_attention` (`tpu_bridge.select_flash_blocks`); decode runs
         the step against a synthetic KV cache, prefill the full causal
         square. Score matmuls are deliberately *not* workload layers (they
         run on the attention unit, not the CIM macro — DESIGN.md §Model
         frontend), so these ops carry no predicted cycles and are excluded
         from the rank statistic, but are still timed and numerics-checked;
       * the SSD intra-chunk pair (scores + y_intra) -> fused
         `kernels/ssd_scan` invocation.

     Plan order is stream order, i.e. schedule order — each op is annotated
     with the segment that will execute it (`Schedule.stage_segment_ids`).
  2. **Execution.** Each structurally unique op runs once with warm-up plus
     timed repeats (operand *values* are synthetic; shapes, dtypes and
     block shapes are exactly the plan's). ``interpret=True`` executes the
     Pallas kernels in Python on CPU so CI exercises the whole path; on
     real hardware pass ``interpret=False``.
  3. **Validation.** Every kernel invocation is checked against its
     package's ``ref.py`` oracle (`quantized_matmul_and_ref`,
     `attention_ref`, `ssd_intra_chunk_and_ref`), and measured wall-clock
     is *ranked* against predicted cycles (`spearman`) — the Fig. 4(a)
     discipline, now model-vs-execution instead of model-vs-simulator.
     Absolute agreement is not expected (interpret-mode CPU seconds are not
     CIM cycles); monotonicity is: a layer the model calls heavier must
     measure heavier.

Entry points: ``execute_model`` (extract -> optimize -> lower -> execute),
``lower_plan`` / ``execute_plan`` for pre-solved results. Surfaced as the
``exec`` benchmark job (`benchmarks/exec_lm.py`) and wired into
`examples/serve_lm.py`'s served decode step. JAX/kernel imports stay
inside functions so MIP solves can still fan out across processes before
any kernel runs (fork-after-JAX deadlocks; see `examples/serve_lm.py`).
"""

from __future__ import annotations

import dataclasses
import math
import time
import zlib
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import CimArch
from repro.core.cache import mapping_from_json
from repro.core.tpu_bridge import select_blocks_from_mapping, \
    select_flash_blocks

#: Decode attention replays the step against a synthetic KV cache of the
#: scenario's sequence length, capped so interpret-mode CI stays fast (a
#: 32k-entry cache is a prediction-side scenario, not an execution target).
DECODE_KV_CAP = 512

#: Frobenius relative-error floor per kernel family vs its ref.py oracle.
#: matmul shares the oracle's int32 accumulation exactly (only the final
#: f32 scale multiply can round differently); attention/SSD re-associate
#: f32 reductions blockwise.
NUMERICS_TOL = {"matmul_int8": 1e-4, "flash_attention": 2e-3,
                "ssd_scan": 2e-3}

#: Block-size cap for executed matmuls: per-grid-step wall-clock is the
#: measurement granularity, so each op should span several steps — one
#: mapping-sized mega-block would collapse every GEMM into a single opaque
#: step and flatten the measured ranking the backend exists to test.
EXEC_BLOCK_CAP = 128


# ---------------------------------------------------------------------------
# Plan dataclasses
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ExecOp:
    """One kernel invocation of the plan (one or more workload layers)."""

    name: str
    kernel: str                    # matmul_int8 | flash_attention | ssd_scan
    spec: dict                     # kernel-family shape/block parameters
    count: int                     # network multiplicity (instances)
    layer_indices: tuple[int, ...]  # workload layers this op covers
    segment: int | None = None     # schedule segment executing this op
    #: Per-instance predicted cycles (sum of covered layers' records);
    #: ``None`` for ops with no workload layer (attention score stage).
    predicted_cycles: float | None = None
    measured_s: float | None = None        # per-invocation wall-clock
    rel_err: float | None = None           # vs the kernel's ref.py oracle
    numerics_ok: bool | None = None

    @property
    def key(self) -> tuple:
        """Structural execution identity: equal keys run identical kernels
        on identical shapes/blocks, so measurement and numerics memoize."""
        return (self.kernel,) + tuple(sorted(self.spec.items()))


@dataclasses.dataclass
class ExecPlan:
    model: str
    scenario: str
    arch_name: str
    ops: list[ExecOp]
    predicted_serial_cycles: float
    predicted_scheduled_cycles: float | None
    n_segments: int

    @property
    def n_unique(self) -> int:
        return len({op.key for op in self.ops})


@dataclasses.dataclass
class ExecReport:
    plan: ExecPlan
    #: Count-weighted measured wall-clock — the executed analogue of the
    #: serial-sum predicted cycles (unique ops run once; instances scale).
    measured_total_s: float
    #: Spearman rank correlation of per-op predicted cycles vs measured
    #: seconds over the plan's unique predicted ops (None under 3 points).
    rank_corr: float | None
    numerics_ok: bool
    max_rel_err: float
    n_ops: int
    n_unique: int
    n_checked: int

    def rank_points(self) -> list[tuple[float, float]]:
        """(predicted cycles, measured seconds) per unique predicted op —
        poolable across reports for a fleet-level rank statistic."""
        seen, pts = set(), []
        for op in self.plan.ops:
            if op.predicted_cycles is None or op.measured_s is None or \
                    op.key in seen:
                continue
            seen.add(op.key)
            pts.append((op.predicted_cycles, op.measured_s))
        return pts


# ---------------------------------------------------------------------------
# Rank statistic
# ---------------------------------------------------------------------------

def spearman(xs: Sequence[float], ys: Sequence[float]) -> float | None:
    """Spearman rank correlation (scipy, average ranks for ties); ``None``
    when fewer than 3 points or either side is constant."""
    from scipy.stats import spearmanr
    assert len(xs) == len(ys)
    if len(xs) < 3 or len(set(xs)) == 1 or len(set(ys)) == 1:
        return None
    rho = float(spearmanr(xs, ys)[0])
    return None if math.isnan(rho) else rho


# ---------------------------------------------------------------------------
# Lowering: NetworkResult -> ExecPlan
# ---------------------------------------------------------------------------

def _gemm_mkn(layer: wl.Layer) -> tuple[int, int, int]:
    """GEMM-speak (M x K) @ (K x N) from the canonical loop nest."""
    assert layer.is_gemm, layer.name
    return layer.bound("N"), layer.bound("C"), layer.bound("K")


def _matmul_op(idx: int, lr, arch: CimArch) -> ExecOp:
    m, k, n = _gemm_mkn(lr.layer)
    mapping = mapping_from_json(lr.record["mapping"])
    c = select_blocks_from_mapping(mapping, lr.layer, arch,
                                   cap=EXEC_BLOCK_CAP)
    return ExecOp(
        name=lr.layer.name, kernel="matmul_int8",
        spec={"m": m, "k": k, "n": n, "bm": c.bm, "bk": c.bk, "bn": c.bn},
        count=lr.count, layer_indices=(idx,),
        predicted_cycles=lr.record["cycles"])


def _flash_op(prefix: str, group: dict, cfg, spec) -> ExecOp | None:
    """The score/AV stage of one attention block (no workload layer — no
    predicted cycles; see module docstring)."""
    if "wq" not in group:
        return None
    qi, qlr = group["wq"]
    lq = qlr.layer.bound("N")
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    idxs = tuple(i for i, _ in group.values())
    if spec.is_decode:
        # one decode step against the (synthetic) KV cache: every cached
        # position is visible, sequences batch on the leading dim. The
        # cache is the decoder's own stream for self-attention; cached
        # cross-attention (kv_m=0 — no wk/wv at decode) attends the
        # encoder memory instead.
        cache = (cfg.frontend_seq or spec.seq_len) \
            if prefix.endswith(".xattn") else spec.seq_len
        lk = min(int(cache), DECODE_KV_CAP)
        b, lq, causal = lq, 1, False
    elif "wk" not in group:
        return None                 # defensive: prefill group without K/V
    else:
        lk = group["wk"][1].layer.bound("N")
        # cross-attention and the encoder's bidirectional self-attention
        # (the frontend's `.xattn` / `.enc` groups) see every position;
        # decoder/self streams are causal
        bidi = prefix.endswith(".xattn") or prefix.endswith(".enc")
        b, causal = 1, not bidi
    bq, bk = select_flash_blocks(lq, lk, hd)
    return ExecOp(
        name=f"{prefix}.attention", kernel="flash_attention",
        spec={"b": b, "lq": lq, "lk": lk, "h": h, "hd": hd,
              "causal": causal, "bq": bq, "bk": bk},
        count=qlr.count, layer_indices=idxs)


def lower_plan(cfg, spec, net, arch: CimArch) -> ExecPlan:
    """Lower a solved ``NetworkResult`` for ``(cfg, spec)`` into an
    executable plan. ``net.layers`` must be the workload extracted by
    `frontend.extract_workload(cfg, spec)` in order (op-kind tags intact).
    """
    layers = net.layers
    seg_ids = net.schedule.stage_segment_ids() if net.schedule else None
    ops: list[ExecOp] = []
    i = 0
    while i < len(layers):
        lr = layers[i]
        kind = lr.layer.op
        prefix, _, leaf = lr.layer.name.rpartition(".")
        if kind == wl.OP_ATTENTION:
            # contiguous projection run of one block: wq/wo[/wk/wv]
            group: dict[str, tuple[int, object]] = {}
            j = i
            while j < len(layers) and layers[j].layer.op == wl.OP_ATTENTION \
                    and layers[j].layer.name.rpartition(".")[0] == prefix:
                group[layers[j].layer.name.rpartition(".")[2]] = \
                    (j, layers[j])
                ops.append(_matmul_op(j, layers[j], arch))
                j += 1
            fo = _flash_op(prefix, group, cfg, spec)
            if fo is not None:
                ops.append(fo)
            i = j
            continue
        if kind == wl.OP_SSD and leaf == "ssd_scores" and \
                i + 1 < len(layers) and \
                layers[i + 1].layer.name == f"{prefix}.ssd_y_intra":
            # fused intra-chunk pair: scores (C B^T) + y_intra (scores X)
            sc, yi = lr, layers[i + 1]
            assert sc.count == yi.count, (sc.count, yi.count)
            q = sc.layer.bound("N")
            ops.append(ExecOp(
                name=f"{prefix}.ssd_intra", kernel="ssd_scan",
                spec={"q": q, "n": sc.layer.bound("C"),
                      "p": yi.layer.bound("K")},
                count=sc.count, layer_indices=(i, i + 1),
                predicted_cycles=sc.record["cycles"] + yi.record["cycles"]))
            i += 2
            continue
        # plain weight GEMM (FFN/MoE/LM head/projections) or SSD state GEMM
        ops.append(_matmul_op(i, lr, arch))
        i += 1
    if seg_ids is not None:
        for op in ops:
            op.segment = seg_ids[op.layer_indices[0]]
    sched = net.scheduled
    return ExecPlan(
        model=cfg.name, scenario=spec.name, arch_name=net.arch_name,
        ops=ops, predicted_serial_cycles=net.totals["cycles"],
        predicted_scheduled_cycles=sched["cycles"] if sched else None,
        n_segments=len(net.schedule.segments) if net.schedule else 0)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def _rel_err(out, ref) -> float:
    import numpy as np
    a = np.asarray(out, np.float32)
    b = np.asarray(ref, np.float32)
    return float(np.linalg.norm(a - b) / max(np.linalg.norm(b), 1e-12))


def _time_call(fn, warmup: int, repeats: int) -> float:
    """min-of-repeats wall-clock of ``fn()`` after ``warmup`` extra calls.
    Callers count their numerics invocation as the first warm-up (it
    already paid jit tracing), so they pass ``warmup - 1``."""
    import jax
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    best = math.inf
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _run_matmul(op: ExecOp, rng, interpret: bool, warmup: int,
                repeats: int) -> tuple[float, float]:
    import jax.numpy as jnp
    from repro.kernels.matmul_int8.ops import (quantized_matmul,
                                               quantized_matmul_and_ref)
    s = op.spec
    x = jnp.asarray(rng.standard_normal((s["m"], s["k"])), jnp.float32)
    w = jnp.asarray(rng.standard_normal((s["k"], s["n"])) * 0.1,
                    jnp.float32)
    blocks = (s["bm"], s["bk"], s["bn"])
    out, ref = quantized_matmul_and_ref(x, w, block_shapes=blocks,
                                        interpret=interpret)
    t = _time_call(
        lambda: quantized_matmul(x, w, block_shapes=blocks,
                                 interpret=interpret,
                                 out_dtype=jnp.float32),
        warmup - 1, repeats)
    return t, _rel_err(out, ref)


def _run_flash(op: ExecOp, rng, interpret: bool, warmup: int,
               repeats: int) -> tuple[float, float]:
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    s = op.spec
    mk = lambda l: jnp.asarray(
        rng.standard_normal((s["b"], l, s["h"], s["hd"])), jnp.float32)
    q, k, v = mk(s["lq"]), mk(s["lk"]), mk(s["lk"])
    call = lambda: flash_attention(q, k, v, causal=s["causal"],
                                   block_q=s["bq"], block_k=s["bk"],
                                   interpret=interpret)
    out = call()
    ref = attention_ref(q, k, v, causal=s["causal"])
    return _time_call(call, warmup - 1, repeats), _rel_err(out, ref)


def _run_ssd(op: ExecOp, rng, interpret: bool, warmup: int,
             repeats: int) -> tuple[float, float]:
    import jax.numpy as jnp
    from repro.kernels.ssd_scan.ops import (ssd_intra_chunk,
                                            ssd_intra_chunk_and_ref)
    s = op.spec
    q, n, p = s["q"], s["n"], s["p"]
    c = jnp.asarray(rng.standard_normal((1, 1, q, 1, n)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((1, 1, q, 1, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (1, 1, q, 1)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, (1,)), jnp.float32)
    ss = jnp.cumsum(dt * a, axis=2)
    x = jnp.asarray(rng.standard_normal((1, 1, q, 1, p)), jnp.float32)
    out, ref = ssd_intra_chunk_and_ref(c, b, ss, dt, x, interpret=interpret)
    t = _time_call(
        lambda: ssd_intra_chunk(c, b, ss, dt, x, interpret=interpret),
        warmup - 1, repeats)
    return t, _rel_err(out, ref)


_RUNNERS = {"matmul_int8": _run_matmul, "flash_attention": _run_flash,
            "ssd_scan": _run_ssd}


def execute_plan(plan: ExecPlan, *, interpret: bool = True, warmup: int = 1,
                 repeats: int = 2, seed: int = 0, verbose: bool = False,
                 memo: dict | None = None) -> ExecReport:
    """Execute every structurally unique op of ``plan`` (memoized by
    ``ExecOp.key``) with warm-up + timed repeats, numerics-check each kernel
    against its ``ref.py`` oracle, and fill the per-op measurement fields
    in place. Deterministic for a fixed ``seed``.

    ``memo`` can be shared across plans executed with identical
    (interpret, warmup, repeats, seed) settings — reduced configs
    deliberately share shapes across models, and a structurally identical
    op measures once (`benchmarks/exec_lm.py`)."""
    import numpy as np

    memo = {} if memo is None else memo
    for op in plan.ops:
        if op.key not in memo:
            # crc32 over the structural key: stable across processes
            # (tuple hash() is salted), so reruns rebuild identical operands
            rng = np.random.default_rng(
                [seed, zlib.crc32(repr(op.key).encode())])
            memo[op.key] = _RUNNERS[op.kernel](op, rng, interpret, warmup,
                                               repeats)
            if verbose:
                t, e = memo[op.key]
                print(f"[exec] {op.kernel:>16} {op.name}: {t * 1e3:.2f} ms "
                      f"rel_err {e:.2e}")
        op.measured_s, op.rel_err = memo[op.key]
        op.numerics_ok = op.rel_err <= NUMERICS_TOL[op.kernel]
    report = ExecReport(
        plan=plan,
        measured_total_s=sum(op.count * op.measured_s for op in plan.ops),
        rank_corr=None, numerics_ok=all(op.numerics_ok for op in plan.ops),
        max_rel_err=max(op.rel_err for op in plan.ops),
        n_ops=len(plan.ops), n_unique=plan.n_unique,
        n_checked=len({op.key for op in plan.ops}))
    pts = report.rank_points()
    report.rank_corr = spearman([p for p, _ in pts], [m for _, m in pts])
    return report


def execute_model(cfg, spec, arch: CimArch | None = None, *,
                  mode: str = "miredo", per_layer_cap_s: float = 2.0,
                  total_budget_s: float | None = None,
                  workers: int | None = 1, net=None,
                  interpret: bool = True, warmup: int = 1, repeats: int = 2,
                  seed: int = 0, verbose: bool = False) -> ExecReport:
    """Extract -> optimize -> lower -> execute for one (model, scenario).

    ``net`` short-circuits the solve with a pre-computed ``NetworkResult``
    for exactly this workload (e.g. `examples/serve_lm.py`, which already
    optimized the served decode step). ``workers`` defaults to 1: kernels
    import JAX, and forking a solver pool afterwards risks deadlock."""
    from repro.core.arch import default_arch
    from repro.core.frontend import extract_workload
    from repro.core.network import optimize_network

    arch = arch or default_arch()
    if net is None:
        work = extract_workload(cfg, spec)
        net = optimize_network(list(work.layers), arch, mode,
                               counts=list(work.counts),
                               per_layer_cap_s=per_layer_cap_s,
                               total_budget_s=total_budget_s,
                               workers=workers, verbose=verbose)
    plan = lower_plan(cfg, spec, net, arch)
    return execute_plan(plan, interpret=interpret, warmup=warmup,
                        repeats=repeats, seed=seed, verbose=verbose)
