"""Per-family GEMM emitters for the LM model frontend (DESIGN.md §Model
frontend).

Each helper lowers one sub-block of an LM architecture into ``(Layer,
count)`` pairs — the weight-bearing matmuls that a CIM macro executes as
MVMs. ``count`` is the multiplicity of the GEMM in the whole network
(depth x batch x chunks x heads, as applicable); the network pipeline
(`core/network.py`) dedups structurally identical entries to one solve and
scales aggregates by ``count``.

Conventions (see `frontend.extract_workload` for scenario plumbing):

* ``m`` is the token dimension of one GEMM *instance* — the scenario's
  contribution. Prefill/train pass the sequence length (batch goes into
  ``count``); decode passes the serving batch (one token per sequence,
  batched into a single MVM).
* Attention *score* matmuls (QK^T, AV) are activation-activation products
  with no resident weight operand — they run on the dedicated attention /
  SIMD unit, not the CIM macro, and are not extracted (the standard CIM
  split; DESIGN.md §Model frontend). SSD intra-chunk matmuls *are*
  extracted: the SSM archs have no attention unit and the state-space
  duality form is exactly the blocked matmul stack `models/ssm.py`
  implements.
* Embedding lookup is a gather (no MACs) and is skipped; the LM head is a
  full GEMM over the padded vocab.
"""

from __future__ import annotations

import math

from repro.core import workload as wl

Emitted = list[tuple[wl.Layer, int]]


def attn_gemms(prefix: str, d_model: int, n_heads: int, n_kv_heads: int,
               head_dim: int, m: int, *, kv_m: int | None = None,
               count: int = 1) -> Emitted:
    """QKV/O projections with GQA head counts.

    Q/O are sized by ``n_heads``; K/V by ``n_kv_heads`` (grouped-query
    attention shrinks the KV projections, e.g. glm4's kv=2 of 32 heads).
    ``kv_m`` overrides the K/V token dim (enc-dec cross-attention projects
    the encoder memory instead of the decoder stream); ``kv_m=0`` skips
    K/V entirely (decode-time cross-attention reuses cached memory K/V).
    """
    kv_m = m if kv_m is None else kv_m
    att = wl.OP_ATTENTION    # executor: projections on matmul_int8, plus
    out: Emitted = [         # one flash_attention score/AV op per block
        (wl.gemm(f"{prefix}.wq", m, n_heads * head_dim, d_model, op=att),
         count),
        (wl.gemm(f"{prefix}.wo", m, d_model, n_heads * head_dim, op=att),
         count),
    ]
    if kv_m:
        out += [
            (wl.gemm(f"{prefix}.wk", kv_m, n_kv_heads * head_dim, d_model,
                     op=att), count),
            (wl.gemm(f"{prefix}.wv", kv_m, n_kv_heads * head_dim, d_model,
                     op=att), count),
        ]
    return out


def ffn_gemms(prefix: str, d_model: int, d_ff: int, m: int, *,
              gated: bool = True, count: int = 1) -> Emitted:
    """Dense MLP: fused up(+gate) projection and down projection."""
    if not d_ff:
        return []
    up = d_ff * (2 if gated else 1)
    return [
        (wl.gemm(f"{prefix}.ffn_up", m, up, d_model), count),
        (wl.gemm(f"{prefix}.ffn_down", m, d_model, d_ff), count),
    ]


def moe_gemms(prefix: str, d_model: int, moe_d_ff: int, n_experts: int,
              n_shared_experts: int, top_k: int, m: int, *,
              gated: bool = True, count: int = 1) -> Emitted:
    """Routed + shared expert GEMMs.

    Top-k routing sends ``m * top_k`` token-assignments to ``n_experts``
    experts; under the balanced-load assumption each expert sees
    ``ceil(m * top_k / n_experts)`` tokens (floored at 1 — an expert GEMM
    with zero rows is no GEMM at all). Total routed MACs therefore scale
    with ``top_k``, not with ``n_experts``: that is the MoE efficiency the
    dataflow has to serve. Shared experts process every token.
    """
    out: Emitted = []
    if n_experts and top_k:
        m_exp = max(1, math.ceil(m * top_k / n_experts))
        out += ffn_gemms(f"{prefix}.exp", d_model, moe_d_ff, m_exp,
                         gated=gated, count=count * n_experts)
    if n_shared_experts:
        out += ffn_gemms(f"{prefix}.shared", d_model, moe_d_ff, m,
                         gated=gated, count=count * n_shared_experts)
    return out


def ssd_gemms(prefix: str, d_model: int, *, expand: int, head_dim: int,
              groups: int, state: int, m: int, decode: bool,
              chunk: int = 256, count: int = 1) -> Emitted:
    """Mamba2 / SSD block matmuls (`models/ssm.py` semantics).

    Projections (weight GEMMs) plus the SSD state matmuls. Prefill/train
    uses the chunked duality form — per chunk and per head:

      scores  = C B^T            (Q x Q x N)
      y_intra = scores X         (Q x P x Q)
      s_chunk = B^T (w*X)        (N x P x Q)   chunk state summary
      y_inter = (C*decay) h      (Q x P x N)

    Decode is the O(1) recurrent update per token and head: a rank-1
    state write (N x P x 1) and a state readout (1 x P x N). Depthwise
    causal conv is SIMD work (not MVM-shaped) and is skipped, like
    depthwise convs in the conv zoo (DESIGN.md §Decisions).
    """
    d_inner = expand * d_model
    nh = d_inner // head_dim
    gn = groups * state
    d_proj = 2 * d_inner + 2 * gn + nh
    out: Emitted = [
        (wl.gemm(f"{prefix}.in_proj", m, d_proj, d_model), count),
        (wl.gemm(f"{prefix}.out_proj", m, d_model, d_inner), count),
    ]
    ssd = wl.OP_SSD          # executor: scores+y_intra fused on ssd_scan,
    if decode:               # state GEMMs on matmul_int8
        # m = batch of single-token sequences; state ops are per seq x head
        c = count * m * nh
        out += [
            (wl.gemm(f"{prefix}.ssd_state_upd", state, head_dim, 1, op=ssd),
             c),
            (wl.gemm(f"{prefix}.ssd_readout", 1, head_dim, state, op=ssd),
             c),
        ]
    else:
        q = min(chunk, m)
        nc = math.ceil(m / q)
        c = count * nc * nh
        out += [
            (wl.gemm(f"{prefix}.ssd_scores", q, q, state, op=ssd), c),
            (wl.gemm(f"{prefix}.ssd_y_intra", q, head_dim, q, op=ssd), c),
            (wl.gemm(f"{prefix}.ssd_s_chunk", state, head_dim, q, op=ssd),
             c),
            (wl.gemm(f"{prefix}.ssd_y_inter", q, head_dim, state, op=ssd),
             c),
        ]
    return out


def lm_head_gemm(prefix: str, d_model: int, padded_vocab: int, m: int, *,
                 count: int = 1) -> Emitted:
    """Final unembedding projection over the padded vocabulary."""
    return [(wl.gemm(f"{prefix}.lm_head", m, padded_vocab, d_model), count)]
