"""Per-layer racing solver portfolio (ROADMAP open item 3, DESIGN.md
§Solver portfolio).

The paper caps Gurobi at 5 min/layer; our HiGHS-via-scipy port keeps the
cap but on many layers the time-capped solve still returns the warm-start
incumbent unimproved — the branch-and-bound never gets past the root, or
stops at the default 2% relative gap with the heuristic incumbent still in
hand. A *portfolio* races K deterministic parameterizations of the same
layer MIP inside the layer's **existing** allocated budget:

  * each `PortfolioMember` is a distinct (factorization-ladder rung,
    HiGHS ``presolve``/``node_limit``/``mip_rel_gap`` parameterization,
    incumbent-seed subset) combination — diversity, not redundancy;
  * members run **time-sliced** inside the layer's single process (the
    `network.optimize_network` workers are already saturated fanning out
    *layers*; racing sequentially keeps the winner independent of the
    worker count): member *i* receives a ``share``-weighted split of the
    budget left on the shared deadline (``remaining * share_i / sum(share_j,
    j >= i)``), so early finishers roll their slack forward to later
    members;
  * the best-known upper bound is **shared**: every member's prune row
    (``PMAX <= UB * 1.001``) is tightened from the running incumbent —
    improvements found by member *i* cut member *i+1*'s search region;
  * the returned result is best-of-portfolio by ``(eval_latency,
    member_index)``, so ties resolve to the earliest member and the
    outcome is a pure function of the member results — bit-deterministic
    and cache-stable. Full end-to-end bit-determinism additionally
    requires members to terminate on a deterministic criterion
    (optimality / ``node_limit``) rather than the wall clock; the default
    grid node-limits every non-baseline member for exactly this reason.

The portfolio can never return a worse ``eval_latency`` than its incumbent
pool (each member inherits `formulation.solve_ladder`'s never-worse
fallback), so seeding it with another solver's result — e.g. the single
baseline solve in ``benchmarks/opt_speed.py --portfolio`` — makes
"never worse than that solver" hold *by construction*.

Threaded through `formulation.optimize_layer(portfolio=)`,
`cache.solve_layer` / `solve_record_key` (the portfolio digest joins the
key; CACHE_VERSION=8) and `network.optimize_network(portfolio=)`.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time

from repro.core import workload as wl
from repro.core.arch import CimArch
from repro.core.formulation import (FormulationConfig, MiredoResult,
                                    native_incumbents, solve_ladder)
from repro.core.latency import evaluate
from repro.core.mapping import Mapping, validate

#: Below this many seconds of remaining budget, a non-baseline member is
#: skipped instead of launched (building a formulation alone costs more).
MIN_MEMBER_SLICE_S = 0.05

#: Incumbent-seed subsets a member may start from (its own pool; the
#: running shared best is always added on top).
SEED_SUBSETS = ("all", "search", "greedy")


@dataclasses.dataclass(frozen=True)
class PortfolioMember:
    """One deterministic solver parameterization.

    ``rung`` picks the starting Flexible-Factorization ladder rung
    (`formulation.ladder_rungs`); ``node_limit``/``presolve``/
    ``mip_rel_gap`` map straight onto HiGHS options
    (`mip.model.MipModel.solve`); ``seed`` selects which native incumbents
    form the member's own pool (``all`` | ``search`` | ``greedy``) — a
    weaker seed changes the big-M scale and the fallback preference, i.e.
    a genuinely different search, while the *prune row* still tightens
    from the running shared UB; ``share`` weights the member's time slice
    (see `race`) — these solves are root-dominated, so wall clock, not
    node count, decides whether a member lands its first integer point."""
    name: str
    rung: int = 0
    node_limit: int | None = None
    presolve: bool | None = None
    mip_rel_gap: float | None = None
    seed: str = "all"
    share: float = 1.0

    def __post_init__(self):
        assert self.seed in SEED_SUBSETS, self.seed
        assert self.share > 0, self.share


@dataclasses.dataclass(frozen=True)
class Portfolio:
    """An ordered member grid. Order matters twice: earlier members see a
    looser shared UB (they *produce* it) and win eval-latency ties."""
    members: tuple[PortfolioMember, ...]

    def __post_init__(self):
        assert self.members, "a portfolio needs at least one member"

    def digest(self) -> str:
        """Cache-key component: digests every result-affecting member
        field, order-sensitively (`cache.solve_record_key`)."""
        blob = json.dumps([dataclasses.asdict(m) for m in self.members],
                          sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


def default_portfolio() -> Portfolio:
    """The shipped K=3 grid, tuned on the reduced LM zoo
    (`benchmarks/opt_speed.py --portfolio`).

    * ``coarse`` — one rung coarser, triple slice share: a much smaller
      MIP. These solves are root-dominated (HiGHS spends the budget on
      presolve + root heuristics, rarely past node 2-3), so on layers
      where the fine model cannot land a single integer point in-budget
      the coarse model both lands one *and* often lands a better one
      (e.g. the reduced minicpm FFN-up GEMM: coarse finds 7114 cycles in
      ~1.5 s where the fine model needs >3 s to reach 8448). Runs first
      so its UB prunes the fine members.
    * ``base`` — the single-parameterization solve, unchanged knobs:
      keeps the portfolio's floor at the historical solver's quality on
      layers where the fine model wins in-slice.
    * ``gap0`` — near-zero relative gap, node-limited: keeps branching
      after the point where ``base`` would declare the (possibly
      still-heuristic) incumbent close enough; benefits most from the
      shared UB since it starts from the tightest prune row.
    """
    return Portfolio(members=(
        PortfolioMember(name="coarse", rung=1, share=3.0),
        PortfolioMember(name="base"),
        PortfolioMember(name="gap0", mip_rel_gap=1e-6, presolve=True,
                        node_limit=20000),
    ))


@dataclasses.dataclass
class MemberOutcome:
    """Per-member diagnostics: why did this member win / lose?"""
    index: int
    name: str
    status: str                   # Status name, or SKIPPED / OVERFLOW
    eval_latency: float           # inf when the member produced nothing
    solve_seconds: float
    mip_gap: float = math.nan
    mip_node_count: float = math.nan
    mip_dual_bound: float = math.nan
    improved: bool = False        # beat the native incumbent pool?

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PortfolioOutcome:
    result: MiredoResult          # winner's result; solve_seconds = total
    winner: int                   # index into ``members``
    members: list[MemberOutcome]

    def to_json(self) -> dict:
        return {"winner": self.winner,
                "members": [m.to_json() for m in self.members]}


def _seed_pool(incumbents, seed: str):
    if seed == "all":
        return list(incumbents)
    # native_incumbents order: [search, greedy]
    return [incumbents[0 if seed == "search" else 1]]


def race(layer: wl.Layer, arch: CimArch, cfg: FormulationConfig,
         pf: Portfolio, warm_start: Mapping | None = None
         ) -> PortfolioOutcome:
    """Race ``pf``'s members on one layer inside ``cfg.time_limit_s``.

    Budget contract: the native incumbent pool, every member's builds and
    solves, and all fallback evaluation share ONE deadline anchored before
    the incumbent search — total wall clock stays within the layer's
    allocated budget + scheduling epsilon, same as the single solve after
    the ladder fix (`formulation.solve_ladder`).

    Returns the best member by ``(eval_latency, member_index)``; the
    winning `MiredoResult`'s ``solve_seconds`` is the portfolio's total
    elapsed time (that is what `network.allocate_budgets` charged).
    """
    t0 = time.monotonic()
    deadline = t0 + cfg.time_limit_s
    base = native_incumbents(layer, arch, cfg)
    native_ub = min(l for l, _ in base)
    shared: list[tuple[float, Mapping]] = []   # warm start + member results
    if warm_start is not None and not validate(warm_start, layer, arch):
        shared.append(
            (evaluate(warm_start, layer, arch).total_cycles, warm_start))

    best: tuple[float, int, MiredoResult] | None = None
    outcomes: list[MemberOutcome] = []
    last_exc: Exception | None = None
    for idx, mem in enumerate(pf.members):
        remaining = deadline - time.monotonic()
        if idx > 0 and remaining <= MIN_MEMBER_SLICE_S:
            outcomes.append(MemberOutcome(
                index=idx, name=mem.name, status="SKIPPED",
                eval_latency=math.inf, solve_seconds=0.0))
            continue
        # deterministic slice policy: a share-weighted split of what is
        # left, so early finishers fund later members
        w = sum(m.share for m in pf.members[idx:])
        slice_s = max(0.0, remaining) * mem.share / w
        mem_deadline = min(deadline, time.monotonic() + slice_s)
        # member pool = its seed subset + the shared running incumbents;
        # the prune row (min of the pool) is thereby tightened from the
        # best known UB across members
        pool = _seed_pool(base, mem.seed) + list(shared)
        mem_t0 = time.monotonic()
        try:
            res = solve_ladder(
                layer, arch, cfg, pool, t0=mem_t0, deadline=mem_deadline,
                incumbent_latency=native_ub, rung=mem.rung,
                node_limit=mem.node_limit, presolve=mem.presolve,
                mip_rel_gap=mem.mip_rel_gap)
        except Exception as e:          # all rungs overflowed for this member
            last_exc = e
            outcomes.append(MemberOutcome(
                index=idx, name=mem.name, status="OVERFLOW",
                eval_latency=math.inf,
                solve_seconds=time.monotonic() - mem_t0))
            continue
        outcomes.append(MemberOutcome(
            index=idx, name=mem.name, status=res.status.name,
            eval_latency=res.eval_latency, solve_seconds=res.solve_seconds,
            mip_gap=res.mip_gap, mip_node_count=res.mip_node_count,
            mip_dual_bound=res.mip_dual_bound,
            improved=res.eval_latency < native_ub))
        # share the member's result as an incumbent for later members
        if res.mapping is not None:
            shared.append((res.eval_latency, res.mapping))
        # winner ordering: (eval_latency, member_index) — strict < keeps
        # the earliest member on ties
        if best is None or res.eval_latency < best[0]:
            best = (res.eval_latency, idx, res)
    if best is None:
        raise last_exc or RuntimeError("every portfolio member failed")
    result = dataclasses.replace(
        best[2], solve_seconds=time.monotonic() - t0,
        incumbent_latency=native_ub)
    return PortfolioOutcome(result=result, winner=best[1],
                            members=outcomes)
