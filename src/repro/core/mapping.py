"""Dataflow mapping representation + tile/size computation.

A ``Mapping`` fixes every decision the MIREDO MIP optimizes (paper §IV-C):

  * spatial unrolling: per spatial axis, the (dim, factor) list unrolled on it
    (variables X^U),
  * the temporal loop nest: ordered (dim, factor) slots, outermost first
    (variables X^L / psi^L),
  * per-operand memory-level assignment of every temporal slot (variables
    X^M / X^Z — "uneven mapping": each operand owns its own partition of the
    nest into per-level loop blocks),
  * per-(operand, level) buffering mode (psi^DM) and implied bypass
    (psi^U = level has no slots for the operand).

Size conventions (paper eqs. 6–10, aggregate-granularity — see DESIGN.md):
  * stored tile  B^S(m, λ): product over λ-relevant dims of all temporal
    factors assigned to levels >= m, times spatial extents of axes with
    C_u >= m (union across lanes; multicast-replicated copies counted once).
  * transfer chunk B^T(m, λ): same but temporal factors at levels >= m+1
    only — the chunk streamed per iteration of level-m loops.
  * capacities and bandwidths are aggregated over *used* lanes of axes that
    replicate the level (axes with C_u <= m).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import (CimArch, INPUT, OPERANDS, OUTPUT, WEIGHT,
                             operand_bits)


@dataclasses.dataclass(frozen=True)
class Mapping:
    spatial: dict[str, tuple[tuple[str, int], ...]]  # axis -> ((dim, f), ...)
    temporal: tuple[tuple[str, int], ...]            # outer..inner (dim, f)
    level_of: dict[str, tuple[int, ...]]             # operand -> level/slot
    double_buf: frozenset[tuple[str, int]]           # (operand, level) w/ DB

    # ---- structural queries ---------------------------------------------
    def n_slots(self) -> int:
        return len(self.temporal)

    def spatial_extent(self, axis: str, dim: str | None = None) -> int:
        fs = self.spatial.get(axis, ())
        return math.prod(f for d, f in fs if dim is None or d == dim)

    def spatial_dim_extent(self, dim: str, arch: CimArch,
                           min_cu: int | None = None) -> int:
        """Product of factors of `dim` unrolled on axes with C_u >= min_cu."""
        out = 1
        for ax in arch.spatial:
            if min_cu is not None and ax.at_level < min_cu:
                continue
            out *= self.spatial_extent(ax.name, dim)
        return out

    def used_levels(self, operand: str) -> list[int]:
        # Memoized: every analysis pass asks repeatedly, and mappings are
        # frozen. Callers must not mutate the returned list (none do).
        cache = self.__dict__.get("_used_lv")
        if cache is None:
            cache = {}
            object.__setattr__(self, "_used_lv", cache)
        v = cache.get(operand)
        if v is None:
            v = sorted(set(self.level_of[operand]))
            cache[operand] = v
        return v

    def deepest_used(self, operand: str) -> int:
        return max(self.level_of[operand], default=0)

    def next_used_below(self, operand: str, m: int) -> int | None:
        for x in self.used_levels(operand):
            if x > m:
                return x
        return None

    def is_double_buffered(self, operand: str, level: int,
                           arch: CimArch) -> bool:
        if not arch.level(level).double_bufferable:
            return False
        if level == arch.macro_level:
            return False  # shared peripherals: never overlap (Fig. 2a)
        return (operand, level) in self.double_buf

    # ---- tile sizes -------------------------------------------------------
    def _tile_bounds(self, layer: wl.Layer, operand: str, arch: CimArch,
                     min_level: int, spatial_min_cu: int) -> dict[str, int]:
        t = {d: 1 for d in wl.DIMS}
        levels = self.level_of[operand]
        for (d, f), m in zip(self.temporal, levels):
            if m >= min_level:
                t[d] *= f
        for ax in arch.spatial:
            if ax.at_level >= spatial_min_cu:
                for d, f in self.spatial.get(ax.name, ()):
                    t[d] *= f
        return t

    def stored_elems(self, layer: wl.Layer, operand: str, arch: CimArch,
                     m: int) -> int:
        """B^S (eq. 6): union tile stored at level m."""
        t = self._tile_bounds(layer, operand, arch, m, m)
        return wl.operand_tile_elems(layer, operand, t)

    def transfer_elems(self, layer: wl.Layer, operand: str, arch: CimArch,
                       m: int) -> int:
        """B^T (eq. 10): chunk streamed per iteration of level-m loops."""
        t = self._tile_bounds(layer, operand, arch, m + 1, m)
        return wl.operand_tile_elems(layer, operand, t)

    def stored_bytes(self, layer: wl.Layer, operand: str, arch: CimArch,
                     m: int) -> float:
        return self.stored_elems(layer, operand, arch, m) * \
            operand_bits(arch, m, operand) / 8.0

    def transfer_bytes(self, layer: wl.Layer, operand: str, arch: CimArch,
                       m: int) -> float:
        # Source-level precision: psum write-backs leave the core at 32-bit
        # (SIMD requantizes at the GBuf boundary); inbound I/W are 8-bit
        # throughout. Keeps the MIP transfer-size linearization exact.
        bits = operand_bits(arch, m, operand)
        return self.transfer_elems(layer, operand, arch, m) * bits / 8.0

    # ---- aggregated hardware quantities -----------------------------------
    def used_lanes(self, arch: CimArch, m: int) -> int:
        """Used lane count of axes whose per-lane hardware includes level m
        (capacity/bandwidth aggregation — see SpatialAxis.replicates_from)."""
        out = 1
        for ax in arch.spatial:
            if ax.replicates_from is not None and ax.replicates_from <= m:
                out *= self.spatial_extent(ax.name)
        return out

    def eff_bw_bytes(self, arch: CimArch, m: int) -> float:
        return arch.level(m).bytes_per_cycle() * self.used_lanes(arch, m)

    def eff_capacity(self, arch: CimArch, m: int) -> float | None:
        cap = arch.level(m).capacity_bytes
        if cap is None:
            return None
        return cap * self.used_lanes(arch, m)


@dataclasses.dataclass
class SizeContext:
    """Memoized per-mapping size/bandwidth/capacity tables.

    ``Mapping.stored_bytes``/``transfer_bytes`` recompute their tile-bound
    scan per call; the analysis helpers (`latency.operand_transfer_table`,
    `energy.operand_energy_hops`, `latency.idealized_terms`,
    `mapping.capacity_usage`) query the same handful of (operand, level)
    sizes repeatedly, which dominates batched packing
    (`latency_batched.pack`). This context computes every needed entry in
    one monotone suffix-product pass per operand — identical integer
    products, so byte-identical bytes — and answers lookups from dicts.
    Entries exist for each operand's used levels plus DRAM (level 0);
    anything else falls back to the mapping's own methods."""

    mapping: Mapping
    layer: wl.Layer
    arch: CimArch
    stored: dict[str, dict[int, float]]
    transfer: dict[str, dict[int, float]]
    bw: dict[int, float]
    cap: dict[int, float | None]

    def stored_bytes(self, operand: str, m: int) -> float:
        v = self.stored[operand].get(m)
        if v is None:
            return self.mapping.stored_bytes(self.layer, operand,
                                             self.arch, m)
        return v

    def transfer_bytes(self, operand: str, m: int) -> float:
        v = self.transfer[operand].get(m)
        if v is None:
            return self.mapping.transfer_bytes(self.layer, operand,
                                               self.arch, m)
        return v

    def eff_bw_bytes(self, m: int) -> float:
        return self.bw[m]

    def eff_capacity(self, m: int) -> float | None:
        return self.cap[m]


#: dim-name -> index into `wl.DIMS`-ordered tile vectors (hot-path helper)
_DI = {d: i for i, d in enumerate(wl.DIMS)}


def size_context(mapping: Mapping, layer: wl.Layer,
                 arch: CimArch) -> SizeContext:
    """Build the memoized size tables for one mapping (see `SizeContext`).

    Per operand the temporal part of every level's tile is a *suffix*
    product of the slot factors (level assignment is monotone), so one
    innermost-to-outermost walk yields the stored tile (slots at levels
    >= m) and the transfer chunk (slots at levels >= m+1) for every used
    level, plus the DRAM-source chunk at level 0. Tiles are 7-int vectors
    in `wl.DIMS` order; all products are exact integer arithmetic, so the
    resulting bytes are bit-identical to the per-call mapping methods."""
    # spatial per-axis per-dim factor products, and used lanes per axis
    ax_dims: list[tuple[int, list[tuple[int, int]]]] = []
    ax_lanes: list[tuple[int | None, int]] = []
    for ax in arch.spatial:
        d: dict[int, int] = {}
        for dim, f in mapping.spatial.get(ax.name, ()):
            k = _DI[dim]
            d[k] = d.get(k, 1) * f
        ax_dims.append((ax.at_level, list(d.items())))
        ax_lanes.append((ax.replicates_from, math.prod(d.values())))

    bw, cap = {}, {}
    for m in range(arch.n_levels):
        lanes = 1
        for rep, ext in ax_lanes:
            if rep is not None and rep <= m:
                lanes *= ext
        bw[m] = arch.level(m).bytes_per_cycle() * lanes
        c = arch.level(m).capacity_bytes
        cap[m] = None if c is None else c * lanes

    ones = [1] * 7
    sp_cache: dict[int, list[int]] = {}

    def spatial_tile(min_cu: int) -> list[int]:
        sp = sp_cache.get(min_cu)
        if sp is None:
            sp = list(ones)
            for at, items in ax_dims:
                if at >= min_cu:
                    for k, f in items:
                        sp[k] *= f
            sp_cache[min_cu] = sp
        return sp

    stride = layer.stride
    tmp_idx = [(_DI[d], f) for d, f in mapping.temporal]

    def elems(lam: str, td: list[int], sp: list[int]) -> int:
        # inlined wl.operand_tile_elems on the (temporal x spatial) tile —
        # same integer products, index order N K C OY OX FY FX
        if lam == WEIGHT:
            return (td[1] * sp[1]) * (td[2] * sp[2]) \
                * (td[5] * sp[5]) * (td[6] * sp[6])
        if lam == OUTPUT:
            return (td[0] * sp[0]) * (td[1] * sp[1]) \
                * (td[3] * sp[3]) * (td[4] * sp[4])
        iy = (td[3] * sp[3] - 1) * stride + td[5] * sp[5]
        ix = (td[4] * sp[4] - 1) * stride + td[6] * sp[6]
        return (td[0] * sp[0]) * (td[2] * sp[2]) * iy * ix

    stored: dict[str, dict[int, float]] = {}
    transfer: dict[str, dict[int, float]] = {}
    for lam in OPERANDS:
        lv = mapping.level_of[lam]
        n = len(lv)
        ms = sorted(set(lv) | {0}, reverse=True)
        td = list(ones)
        st_l, tr_l = {}, {}
        i = n
        for m in ms:
            # td holds the suffix of slots at levels > m (== >= m+1, since
            # consecutive ms are consecutive used levels)
            sp = spatial_tile(m)
            tr_elems = elems(lam, td, sp)
            while i > 0 and lv[i - 1] >= m:
                i -= 1
                k, f = tmp_idx[i]
                td[k] *= f
            st_elems = elems(lam, td, sp)
            bits = operand_bits(arch, m, lam)
            tr_l[m] = tr_elems * bits / 8.0
            st_l[m] = st_elems * bits / 8.0
        stored[lam] = st_l
        transfer[lam] = tr_l
    return SizeContext(mapping=mapping, layer=layer, arch=arch,
                       stored=stored, transfer=transfer, bw=bw, cap=cap)


def capacity_usage(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                   ctx: SizeContext | None = None
                   ) -> list[tuple[int, float, dict[str, float]]]:
    """Eq. (9) raw terms, one entry per capacity-bounded level:
    ``(m, eff_capacity, {operand: (1 + psi^DM) * stored_bytes})`` over the
    operands that hold slots at (and are served by) level m. Single source
    of truth for ``validate``'s capacity clause and the batched feasibility
    check (`latency_batched.py`). ``ctx`` routes size lookups through a
    prebuilt `SizeContext` (identical values, memoized)."""
    out: list[tuple[int, float, dict[str, float]]] = []
    used = {lam: set(mapping.used_levels(lam)) for lam in OPERANDS}
    for m in range(arch.n_levels):
        cap = ctx.eff_capacity(m) if ctx is not None else \
            mapping.eff_capacity(arch, m)
        if cap is None:
            continue
        sizes: dict[str, float] = {}
        for lam in OPERANDS:
            if m not in used[lam]:
                continue
            if not arch.serves(m, lam):
                continue
            mult = 2 if mapping.is_double_buffered(lam, m, arch) else 1
            sizes[lam] = mult * (ctx.stored_bytes(lam, m) if ctx is not None
                                 else mapping.stored_bytes(layer, lam,
                                                           arch, m))
        out.append((m, cap, sizes))
    return out


def validate(mapping: Mapping, layer: wl.Layer, arch: CimArch) -> list[str]:
    """Return a list of constraint violations (empty = feasible)."""
    errs: list[str] = []
    # (2) each dim's factors multiply back to the bound.
    for d in wl.DIMS:
        prod = math.prod(f for dd, f in mapping.temporal if dd == d)
        for ax in arch.spatial:
            prod *= mapping.spatial_extent(ax.name, d)
        if prod != layer.bound(d):
            errs.append(f"dim {d}: factor product {prod} != {layer.bound(d)}")
    # C^X: spatial axis dim legality + axis size.
    for ax in arch.spatial:
        for d, f in mapping.spatial.get(ax.name, ()):
            if d not in ax.dims:
                errs.append(f"axis {ax.name} cannot unroll dim {d}")
        if mapping.spatial_extent(ax.name) > ax.size:
            errs.append(f"axis {ax.name} over-unrolled")
    for lam in OPERANDS:
        lv = mapping.level_of[lam]
        if len(lv) != mapping.n_slots():
            errs.append(f"{lam}: level_of length mismatch")
            continue
        # Loop blocks: outer loops at outer (smaller-m) levels.
        for a, b in zip(lv, lv[1:]):
            if a > b:
                errs.append(f"{lam}: level assignment not monotonic {lv}")
                break
        # C^M legality.
        for m in set(lv):
            if not arch.serves(m, lam):
                errs.append(f"level {arch.level(m).name} cannot hold {lam}")
    # Weights must terminate in the macro array (in-situ computation).
    if mapping.deepest_used(WEIGHT) != arch.macro_level and \
            mapping.n_slots() > 0:
        # allowed only if all weight factors are spatial (tiny layer)
        pass
    # (9) capacity with double-buffering multiplier.
    for m, cap, sizes in capacity_usage(mapping, layer, arch):
        level = arch.level(m)
        if level.shared:
            if sum(sizes.values()) > cap + 1e-9:
                errs.append(
                    f"{level.name}: {sum(sizes.values()):.0f}B > {cap:.0f}B")
        else:
            for lam, s in sizes.items():
                if s > cap + 1e-9:
                    errs.append(f"{level.name}[{lam}]: {s:.0f}B > {cap:.0f}B")
    # Macro geometry: wordline/bitline extents within array.
    for ax in arch.spatial:
        if mapping.spatial_extent(ax.name) > ax.size:
            errs.append(f"{ax.name} exceeds physical size")
    return errs
