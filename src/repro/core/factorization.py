"""Flexible Factorization (paper Alg. 1) + FlexScore.

Shrinks the prime-factor pool of each loop bound by greedily merging factor
pairs while the relative loss of mapping flexibility stays below ``alpha``,
stopping at ``k_min`` factors. FlexScore counts the unique ways the factor
multiset can be partitioned into k ∈ {1,2,3} disjoint non-empty subsets
(identified by their sorted product tuples), weighted by decreasing
``mu_p``.
"""

from __future__ import annotations

import functools
import math
from collections import Counter

DEFAULT_MU_P = (1.0, 0.5, 0.25)
DEFAULT_ALPHA = 0.15
DEFAULT_KMIN = 3


def prime_factors(n: int) -> list[int]:
    out, d = [], 2
    while d * d <= n:
        while n % d == 0:
            out.append(d)
            n //= d
        d += 1 if d == 2 else 2
    if n > 1:
        out.append(n)
    return out


def _splits_2(ms: tuple[int, ...]) -> set[tuple[int, int]]:
    """Unique (a, b) with a<=b, a*b=prod(ms), both from non-empty disjoint
    sub-multisets. Enumerates achievable sub-multiset products."""
    total = math.prod(ms)
    prods = {1: Counter()}  # achievable product -> one witness sub-multiset
    achievable = {1}
    for f in ms:
        achievable |= {p * f for p in achievable}
    out = set()
    for a in achievable:
        if a == 1 or a == total:
            continue
        b = total // a
        if a * b == total:
            out.add((min(a, b), max(a, b)))
    # NOTE: for a multiset, every achievable product's complement is also
    # achievable (complement sub-multiset), so the check above is exact.
    return out


@functools.lru_cache(maxsize=65536)
def _sub_products(ms: tuple[int, ...]) -> frozenset[int]:
    """All products of (possibly empty) sub-multisets of ms."""
    acc = {1}
    for f in ms:
        acc |= {p * f for p in acc}
    return frozenset(acc)


@functools.lru_cache(maxsize=65536)
def _splits_3(ms: tuple[int, ...]) -> frozenset[tuple[int, int, int]]:
    """Unique sorted triples (a,b,c), a*b*c = prod(ms), from a partition of
    ms into three non-empty disjoint sub-multisets."""
    if len(ms) < 3:
        return frozenset()
    out = set()

    def rec(remaining: tuple[int, ...], chosen_prod: int, start_allowed: bool):
        pass

    # Enumerate first subset by distinct sub-multisets (via counts), then
    # 2-way split the remainder. Dedupe on product triples keeps this small.
    counts = Counter(ms)
    keys = sorted(counts)

    def gen_subsets(idx: int, cur: list[tuple[int, int]]):
        if idx == len(keys):
            take = Counter({k: c for k, c in cur if c})
            if sum(take.values()) == 0 or sum(take.values()) == len(ms):
                return
            a = math.prod(k ** c for k, c in take.items())
            rem = counts - take
            rem_tuple = tuple(sorted(rem.elements()))
            for b, c in _splits_2(rem_tuple):
                out.add(tuple(sorted((a, b, c))))
            return
        k = keys[idx]
        for c in range(counts[k] + 1):
            gen_subsets(idx + 1, cur + [(k, c)])

    gen_subsets(0, [])
    return frozenset(out)


@functools.lru_cache(maxsize=65536)
def flex_score(ms: tuple[int, ...],
               mu_p: tuple[float, float, float] = DEFAULT_MU_P) -> float:
    """Paper Alg. 1 FlexScore: weighted count of unique k-partitions."""
    ms = tuple(sorted(ms))
    p1 = 1 if ms else 0
    p2 = len(_splits_2(ms)) if len(ms) >= 2 else 0
    p3 = len(_splits_3(ms)) if len(ms) >= 3 else 0
    return mu_p[0] * p1 + mu_p[1] * p2 + mu_p[2] * p3


def flexible_factorization(
    n: int,
    alpha: float = DEFAULT_ALPHA,
    k_min: int = DEFAULT_KMIN,
    mu_p: tuple[float, float, float] = DEFAULT_MU_P,
) -> list[int]:
    """Paper Alg. 1, verbatim control flow.

    Returns a factor list F with prod(F) == n, len(F) >= 1 (empty for n=1).
    """
    if n <= 1:
        return []
    f = sorted(prime_factors(n))
    if len(f) <= k_min:
        return f
    score_full = flex_score(tuple(f), mu_p)
    while len(f) > k_min:
        score_base = flex_score(tuple(f), mu_p)
        best_delta, best_f = math.inf, None
        seen_pairs = set()
        for i in range(len(f)):
            for j in range(i + 1, len(f)):
                pair = (f[i], f[j])
                if pair in seen_pairs:
                    continue
                seen_pairs.add(pair)
                merged = sorted(f[:i] + f[i + 1:j] + f[j + 1:] + [f[i] * f[j]])
                score_m = flex_score(tuple(merged), mu_p)
                delta = (score_base - score_m) / max(score_full, 1e-12)
                if delta < best_delta:
                    best_delta, best_f = delta, merged
        if best_delta > alpha:
            break
        f = best_f
    return f


def factorize_layer_dims(bounds: dict[str, int], alpha: float = DEFAULT_ALPHA,
                         k_min: int = DEFAULT_KMIN) -> dict[str, list[int]]:
    """Factor pools per canonical dim; dims with bound 1 get no factors."""
    return {d: flexible_factorization(b, alpha, k_min)
            for d, b in bounds.items() if b > 1}


def sub_multiset_products(factors: list[int]) -> list[int]:
    """Sorted achievable tile bounds for a dim (used by size enumeration)."""
    return sorted(_sub_products(tuple(sorted(factors))))
