"""Baseline dataflow generators (paper §V-A).

* ``greedy_mapping``      — deterministic feasible constructor (also supplies
                            the MIP's big-M latency bound).
* ``ws_baseline``         — conventional Weight-Stationary dataflow: the
                            paper derives it "by imposing additional
                            constraints within our own MIP formulation";
                            we do exactly that (FormulationConfig
                            .weight_stationary=True).
* ``heuristic_search``    — ZigZag-style stochastic mapper: samples uneven
                            mappings and ranks them with the *idealized*
                            perfect-overlap cost model (the oversimplified
                            model the paper criticizes, limitation ❶); the
                            winner is then re-scored with the accurate
                            analytical model, exposing the modeling gap.
* ``random_search``       — uniform sampling, accurate model (ablation).
"""

from __future__ import annotations

import dataclasses
import math
import random

from repro.core import workload as wl
from repro.core.arch import CimArch, INPUT, OPERANDS, OUTPUT, WEIGHT
from repro.core.factorization import factorize_layer_dims
from repro.core.latency import evaluate, idealized_cycles
from repro.core.mapping import Mapping, validate


# ---------------------------------------------------------------------------
# Greedy constructor
# ---------------------------------------------------------------------------

def _assign_levels(temporal: list[tuple[str, int]], layer: wl.Layer,
                   arch: CimArch, spatial: dict,
                   double_buf: frozenset) -> Mapping | None:
    """Assign per-operand levels innermost-out, deepest level that fits."""
    n = len(temporal)
    level_of = {}
    for lam in OPERANDS:
        legal = [m for m in range(arch.n_levels) if arch.serves(m, lam)]
        lv = [0] * n
        cur = max(legal)
        for i in range(n - 1, -1, -1):
            # try to keep current level; move outward (smaller m) while the
            # cumulative tile no longer fits.
            while True:
                lv[i] = cur
                probe = Mapping(spatial=spatial,
                                temporal=tuple(temporal),
                                level_of={**{o: tuple([0] * n)
                                             for o in OPERANDS
                                             if o != lam},
                                          lam: tuple(lv)},
                                double_buf=double_buf)
                cap = probe.eff_capacity(arch, cur)
                size = probe.stored_bytes(layer, lam, arch, cur)
                mult = 2 if probe.is_double_buffered(lam, cur, arch) else 1
                lvl = arch.level(cur)
                # Shared levels budget a fair share per served operand (the
                # sweep places one operand at a time, so the full capacity
                # would over-commit a level that must later hold all three);
                # dedicated levels grant their full per-operand capacity.
                budget = None if cap is None else \
                    (cap / len(lvl.serves) if lvl.shared else cap)
                if budget is None or mult * size <= budget:
                    break
                outer = [mm for mm in legal if mm < cur]
                if not outer:
                    break
                cur = max(outer)
            cur = lv[i]
        level_of[lam] = tuple(lv)
    mp = Mapping(spatial=spatial, temporal=tuple(temporal),
                 level_of=level_of, double_buf=double_buf)
    return mp if not validate(mp, layer, arch) else None


def greedy_mapping(layer: wl.Layer, arch: CimArch,
                   k_min: int = 3, alpha: float = 0.15) -> Mapping:
    """Deterministic, always-feasible mapping: fill macro spatial axes with
    the largest legal factors, order temporals weight-dims-outermost, place
    levels by capacity sweep, single-buffered everywhere."""
    factors = factorize_layer_dims({d: layer.bound(d) for d in wl.DIMS},
                                   alpha=alpha, k_min=k_min)
    pool: list[tuple[str, int]] = []
    for d, fs in sorted(factors.items()):
        pool += [(d, f) for f in fs]
    spatial: dict[str, list[tuple[str, int]]] = {}
    used = set()
    for ax in arch.spatial:
        room = ax.size
        chosen = []
        for idx, (d, f) in sorted(enumerate(pool),
                                  key=lambda kv: -kv[1][1]):
            if idx in used or d not in ax.dims or f > room:
                continue
            chosen.append((d, f))
            used.add(idx)
            room //= f
        spatial[ax.name] = chosen
    remaining = [pool[i] for i in range(len(pool)) if i not in used]
    w_dims = [p for p in remaining if wl.is_relevant(p[0], WEIGHT)]
    o_dims = [p for p in remaining if not wl.is_relevant(p[0], WEIGHT)]
    temporal = w_dims + o_dims
    mp = _assign_levels(temporal, layer, arch,
                        {k: tuple(v) for k, v in spatial.items()},
                        frozenset())
    if mp is None:
        # ultra-conservative fallback: everything streamed from DRAM
        level_of = {lam: tuple([0] * len(temporal)) for lam in OPERANDS}
        if temporal:
            level_of[WEIGHT] = tuple(
                [0] * (len(temporal) - 1) + [arch.macro_level])
        mp = Mapping(spatial={k: tuple(v) for k, v in spatial.items()},
                     temporal=tuple(temporal), level_of=level_of,
                     double_buf=frozenset())
        errs = validate(mp, layer, arch)
        if errs:
            raise AssertionError(f"greedy fallback infeasible: {errs}")
    return mp


# ---------------------------------------------------------------------------
# Stochastic mappers
# ---------------------------------------------------------------------------

def sample_mapping_raw(layer: wl.Layer, arch: CimArch, rng: random.Random,
                       factors: dict[str, list[int]]) -> Mapping:
    """One random uneven mapping, *not* validated. By construction the
    candidate satisfies every structural constraint (complete factor
    products, spatial axis membership and lane budgets, monotone per-operand
    level assignment, C^M legality, weights terminating in the macro) — the
    only clause it can still violate is the eq. (9) buffer capacity, which
    the batched scorer checks for the whole pool in one dispatch
    (`latency_batched.score_mappings(...).feasible`)."""
    pool: list[tuple[str, int]] = []
    for d, fs in sorted(factors.items()):
        pool += [(d, f) for f in fs]
    rng.shuffle(pool)
    spatial: dict[str, list[tuple[str, int]]] = {ax.name: []
                                                 for ax in arch.spatial}
    room = {ax.name: ax.size for ax in arch.spatial}
    temporal: list[tuple[str, int]] = []
    for d, f in pool:
        axes = [ax.name for ax in arch.spatial
                if d in ax.dims and f <= room[ax.name]]
        choice = rng.randrange(len(axes) + 2) if axes else 0
        if axes and choice < len(axes):
            ax = axes[choice]
            spatial[ax].append((d, f))
            room[ax] //= f
        else:
            temporal.append((d, f))
    n = len(temporal)
    level_of = {}
    for lam in OPERANDS:
        legal = sorted(m for m in range(arch.n_levels)
                       if arch.serves(m, lam))
        # random monotone assignment
        cur = legal[0]
        lv = []
        for i in range(n):
            ups = [mm for mm in legal if mm >= cur]
            cur = rng.choice(ups)
            lv.append(cur)
        if lam == WEIGHT and lv:
            # weights physically terminate in the macro array: relabel the
            # innermost loop block to the macro level.
            tail = lv[-1]
            for i in range(n - 1, -1, -1):
                if lv[i] != tail:
                    break
                lv[i] = arch.macro_level
        level_of[lam] = tuple(lv)
    dbuf = set()
    for lam in OPERANDS:
        for mm in set(level_of[lam]):
            if arch.level(mm).double_bufferable and mm != arch.macro_level \
                    and rng.random() < 0.5:
                dbuf.add((lam, mm))
    return Mapping(spatial={k: tuple(v) for k, v in spatial.items()},
                   temporal=tuple(temporal), level_of=level_of,
                   double_buf=frozenset(dbuf))


def _sample_mapping(layer: wl.Layer, arch: CimArch, rng: random.Random,
                    factors: dict[str, list[int]]) -> Mapping | None:
    """Validated variant of `sample_mapping_raw` (None = infeasible)."""
    mp = sample_mapping_raw(layer, arch, rng, factors)
    return mp if not validate(mp, layer, arch) else None


@dataclasses.dataclass
class SearchResult:
    mapping: Mapping
    chosen_by_cost: float      # the cost model used for selection
    eval_latency: float        # accurate analytical model
    n_feasible: int
    n_sampled: int


def heuristic_search(layer: wl.Layer, arch: CimArch, budget: int = 2000,
                     seed: int = 0, accurate: bool = False,
                     k_min: int = 3, alpha: float = 0.15,
                     backend: str | None = None) -> SearchResult:
    """ZigZag-style mapper. ``accurate=False`` ranks candidates with the
    idealized perfect-overlap model (the strawman the paper criticizes);
    ``accurate=True`` ranks with the full analytical model (ablation).

    Enumerate-then-score: the whole candidate pool is sampled up front and
    ranked in one batched dispatch (`latency_batched.score_mappings` —
    bit-equal to the scalar oracle, so the winner, its cost and the
    feasible count are identical to the historical per-candidate loop).
    ``backend`` forwards to the batched scorer ("jax"/"numpy"/auto)."""
    import numpy as np

    from repro.core import latency_batched as lb

    rng = random.Random(seed)
    factors = factorize_layer_dims({d: layer.bound(d) for d in wl.DIMS},
                                   alpha=alpha, k_min=k_min)
    cands = [sample_mapping_raw(layer, arch, rng, factors)
             for _ in range(budget)]
    need = ("feasible", "latency") if accurate else ("feasible", "ideal")
    sc = lb.score_mappings(cands, layer, arch, need=need, backend=backend)
    best, best_cost = None, math.inf
    feas = int(sc.feasible.sum()) if budget else 0
    if feas:
        cost = np.where(sc.feasible,
                        sc.cycles if accurate else sc.idealized, math.inf)
        idx = int(np.argmin(cost))   # first minimum = first strict improver
        best, best_cost = cands[idx], float(cost[idx])
    if best is None:
        best = greedy_mapping(layer, arch)
        best_cost = idealized_cycles(best, layer, arch)
    return SearchResult(
        mapping=best, chosen_by_cost=best_cost,
        eval_latency=evaluate(best, layer, arch).total_cycles,
        n_feasible=feas, n_sampled=budget)


def random_search(layer: wl.Layer, arch: CimArch, budget: int = 2000,
                  seed: int = 0) -> SearchResult:
    return heuristic_search(layer, arch, budget, seed, accurate=True)


def ws_baseline(layer: wl.Layer, arch: CimArch, **kw):
    """Weight-stationary dataflow via the constrained MIP (paper §V-A)."""
    from repro.core.formulation import FormulationConfig, optimize_layer
    cfg = kw.pop("cfg", None) or FormulationConfig(weight_stationary=True,
                                                   **kw)
    cfg.weight_stationary = True
    return optimize_layer(layer, arch, cfg)
