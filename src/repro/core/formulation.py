"""MIREDO MIP formulation (paper §IV, eqs. 2–14 + Table III).

Maps the dataflow-optimization problem onto the MIP modeling layer:

  X^L[d,f,i]   factor -> temporal slot            (eq. 2)
  X^U[d,f,u]   factor -> spatial axis             (eq. 2, C^X legality eq. 3)
  X^M[d,f,λ,m] factor -> memory level per operand (uneven mapping, eq. 3)
  X^Z[i,λ,m]   slot i in operand λ's level-m loop block (eq. 3/4)
  ψ^L, ψ^U     active-slot / level-used indicators (eq. 4)
  X^N[λ,m,m']  transfer path between consecutive used levels (eq. 5)
  B^S / B^T    log-domain per-dim loop bounds (eqs. 6, 10)
  V^S / V^T    one-hot data-size selections over pre-enumerated combos
               (eqs. 7, 8; combos from Flexible-Factorization value sets)
  ψ^DM, ψ^DL   double-buffer mode / per-slot overlap indicators (eqs. 9, 12)
  T, P, L      transfer / processing / critical-path latencies
               (eq. 11, Table III rows, eq. 13)
  objective    μ1·max_λ P_0,λ − μ2·Σ m·Size_{m,λ}  (eq. 14)

All products of decision variables are linearized exactly: one factor per
temporal slot makes loop counts N_i selectable per-factor with big-M rows;
data sizes select pre-enumerated per-dim bound combos (the paper's H/Y/V
machinery); variable effective bandwidth (core-lane parallelism) is handled
by a one-hot over achievable core extents. Big-M constants derive from a
greedy feasible mapping's evaluated latency — the MIP search region
provably contains the optimum (see DESIGN.md §Decisions).
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import (CimArch, INPUT, OPERANDS, OUTPUT, WEIGHT,
                             operand_bits)
from repro.core.factorization import (factorize_layer_dims,
                                      sub_multiset_products)
from repro.core.latency import evaluate
from repro.core.mapping import Mapping, validate
from repro.core.mip.model import LinExpr, MipModel, Status

LOG2_M = 64.0  # big-M for log-domain equalities (log2 of any bound << 64)

#: Floor multiplier for the latency big-M: ``M_L = max(latency_slack,
#: BIG_M_FLOOR) * UB``. Recursion rows sum up to four latency terms (e.g.
#: ``P >= cd*L + 2T + MX``), so 4x the incumbent latency is the smallest
#: region that provably never clips a candidate the prune row
#: (``PMAX <= UB*1.001``) would keep. ``latency_slack`` values at or below
#: the floor are therefore equivalent by construction — the cache key
#: canonicalizes them (`cache.config_cache_key`) so they share records.
BIG_M_FLOOR = 4.0


class ComboOverflow(RuntimeError):
    """Size-combo enumeration exceeded the cap; retry with coarser factors."""


@dataclasses.dataclass
class FormulationConfig:
    alpha: float = 0.15
    k_min: int = 3
    mu1: float = 1.0
    mu2_frac: float = 0.02        # locality reward as fraction of latency UB
    time_limit_s: float = 60.0
    mip_rel_gap: float = 0.02
    combo_cap: int = 4096
    #: Latency big-M multiplier: ``M_L = max(latency_slack, BIG_M_FLOOR) *
    #: incumbent latency``. Values above the floor loosen the LP relaxation
    #: (see DESIGN.md §Decisions); values at/below it are floored and share
    #: cache records (`cache.config_cache_key` canonicalizes).
    latency_slack: float = 8.0
    weight_stationary: bool = False   # WS baseline (§V-A) extra constraints
    verbose: bool = False


@dataclasses.dataclass
class MiredoResult:
    mapping: Mapping | None
    status: Status
    objective: float
    mip_latency: float            # P_max inside the MIP
    eval_latency: float           # re-scored by the analytical evaluator
    solve_seconds: float
    n_vars: int
    n_rows: int
    mip_gap: float
    #: Best *native* incumbent latency (greedy/heuristic pool, excluding
    #: any injected neighbor warm start): the baseline of the
    #: incumbent-unimproved metric (`benchmarks/opt_speed.py --portfolio`).
    incumbent_latency: float = math.nan
    #: Solver diagnostics at termination (NaN when not reported): nodes
    #: explored and best dual bound — what makes a losing portfolio member
    #: explainable (starved vs exhausted region).
    mip_node_count: float = math.nan
    mip_dual_bound: float = math.nan

    @property
    def improved(self) -> bool:
        """Did the MIP beat the native warm-start incumbent?"""
        return (math.isfinite(self.incumbent_latency)
                and self.eval_latency < self.incumbent_latency)


class MiredoFormulation:
    def __init__(self, layer: wl.Layer, arch: CimArch,
                 cfg: FormulationConfig | None = None):
        self.layer = layer
        self.arch = arch
        self.cfg = cfg or FormulationConfig()
        self.factors = factorize_layer_dims(
            {d: layer.bound(d) for d in wl.DIMS},
            alpha=self.cfg.alpha, k_min=self.cfg.k_min)
        # flat factor list
        self.ff: list[tuple[str, int, int]] = []   # (dim, local idx, value)
        for d, fs in sorted(self.factors.items()):
            for j, f in enumerate(fs):
                self.ff.append((d, j, f))
        self.n_slots = len(self.ff)
        self.levels = list(range(arch.n_levels))
        self.m = MipModel(f"miredo[{layer.name}]")

    # ------------------------------------------------------------------
    def build(self, big_m_latency: float, big_m_transfer: float) -> None:
        m, arch, layer, cfg = self.m, self.arch, self.layer, self.cfg
        ff, n_slots = self.ff, self.n_slots
        nL = arch.n_levels
        log2 = math.log2

        # ---------------- mapping variables ----------------
        self.XL = {}
        self.XU = {}
        for k, (d, j, f) in enumerate(ff):
            for i in range(n_slots):
                self.XL[k, i] = m.add_binary(f"XL[{d}{j}={f},s{i}]")
            for ax in arch.spatial:
                if d in ax.dims:
                    self.XU[k, ax.name] = m.add_binary(f"XU[{d}{j},{ax.name}]")
        # symmetry breaking: identical (dim, value) factors get a canonical
        # assignment order (huge XL permutation symmetry otherwise).
        for k in range(len(ff) - 1):
            d, j, f = ff[k]
            d2, j2, f2 = ff[k + 1]
            if d == d2 and f == f2:
                rank_k = LinExpr({})
                rank_k2 = LinExpr({})
                for i in range(n_slots):
                    rank_k = rank_k + float(i + 1) * self.XL[k, i]
                    rank_k2 = rank_k2 + float(i + 1) * self.XL[k + 1, i]
                for a_i, ax in enumerate(arch.spatial):
                    if (k, ax.name) in self.XU:
                        rank_k = rank_k + float(n_slots + 1 + a_i) * \
                            self.XU[k, ax.name]
                        rank_k2 = rank_k2 + float(n_slots + 1 + a_i) * \
                            self.XU[k + 1, ax.name]
                m.add_le(rank_k - rank_k2, 0.0)
        # (2) uniqueness
        for k in range(len(ff)):
            terms = [self.XL[k, i] for i in range(n_slots)]
            terms += [self.XU[k, ax.name] for ax in arch.spatial
                      if (k, ax.name) in self.XU]
            m.add_eq(sum(terms, LinExpr({})), 1.0)
        # one factor per slot; psi^L prefix-active
        self.psiL = []
        for i in range(n_slots):
            occ = sum((self.XL[k, i] for k in range(len(ff))), LinExpr({}))
            p = m.add_binary(f"psiL[{i}]")
            m.add_eq(p - occ, 0.0)
            self.psiL.append(p)
        for i in range(n_slots - 1):
            m.add_ge(self.psiL[i] - self.psiL[i + 1], 0.0)
        # axis size limits (log domain)
        for ax in arch.spatial:
            e = LinExpr({})
            for k, (d, j, f) in enumerate(ff):
                if (k, ax.name) in self.XU:
                    e = e + log2(f) * self.XU[k, ax.name]
            m.add_le(e, log2(ax.size))

        # X^M per operand (uneven mapping); only levels serving the operand
        self.XM = {}
        for k, (d, j, f) in enumerate(ff):
            for lam in OPERANDS:
                legal = [mm for mm in self.levels if arch.serves(mm, lam)]
                for mm in legal:
                    self.XM[k, lam, mm] = m.add_binary(
                        f"XM[{d}{j},{lam},m{mm}]")
                is_temporal = sum((self.XL[k, i] for i in range(n_slots)),
                                  LinExpr({}))
                m.add_eq(sum((self.XM[k, lam, mm] for mm in legal),
                             LinExpr({})) - is_temporal, 0.0)

        # X^Z: slot-level block membership per operand (eq. 3) — exact via
        # lower bounds + per-slot one-hot against psi^L.
        self.XZ = {}
        for i in range(n_slots):
            for lam in OPERANDS:
                legal = [mm for mm in self.levels if arch.serves(mm, lam)]
                for mm in legal:
                    z = m.add_binary(f"XZ[s{i},{lam},m{mm}]")
                    self.XZ[i, lam, mm] = z
                m.add_eq(sum((self.XZ[i, lam, mm] for mm in legal),
                             LinExpr({})) - self.psiL[i], 0.0)
        for k in range(len(ff)):
            for i in range(n_slots):
                for lam in OPERANDS:
                    for mm in self.levels:
                        if (k, lam, mm) in self.XM and (i, lam, mm) in self.XZ:
                            m.add_ge(self.XZ[i, lam, mm] - self.XL[k, i]
                                     - self.XM[k, lam, mm], -1.0)
        # loop-block ordering: levels non-decreasing outer -> inner
        for lam in OPERANDS:
            for i in range(n_slots - 1):
                lhs = LinExpr({})
                for mm in self.levels:
                    if (i, lam, mm) in self.XZ:
                        lhs = lhs + mm * self.XZ[i, lam, mm]
                    if (i + 1, lam, mm) in self.XZ:
                        lhs = lhs - mm * self.XZ[i + 1, lam, mm]
                m.add_le(lhs - nL * (1 - self.psiL[i + 1] * 1.0), 0.0)

        # psi^U (eq. 4). Level 0 (DRAM) is the home of every tensor and is
        # always on the transfer path, independent of loop placement.
        self.psiU = {}
        for lam in OPERANDS:
            for mm in self.levels:
                if mm == 0:
                    one = m.add_binary(f"psiU[{lam},m0]")
                    m.add_eq(LinExpr({one.idx: 1.0}), 1.0)
                    self.psiU[lam, mm] = one
                    continue
                xs = [self.XM[k, lam, mm] for k in range(len(ff))
                      if (k, lam, mm) in self.XM]
                if xs:
                    self.psiU[lam, mm] = m.add_or(f"psiU[{lam},m{mm}]", xs)

        # NotDeepest / HasOut / X^N (eq. 5)
        self.notdeep = {}
        self.hasout = {}
        self.XN = {}
        for lam in OPERANDS:
            for mm in self.levels:
                if (lam, mm) not in self.psiU:
                    continue
                below = [self.psiU[lam, m2] for m2 in self.levels
                         if m2 > mm and (lam, m2) in self.psiU]
                if below:
                    nd = m.add_or(f"ND[{lam},m{mm}]", below)
                else:
                    nd = m.add_binary(f"ND[{lam},m{mm}]")
                    m.add_eq(LinExpr({nd.idx: 1.0}), 0.0)
                self.notdeep[lam, mm] = nd
                ho = m.add_and(f"HO[{lam},m{mm}]", [self.psiU[lam, mm], nd])
                self.hasout[lam, mm] = ho
            for mm in self.levels:
                if (lam, mm) not in self.psiU:
                    continue
                outs = []
                for m2 in self.levels:
                    if m2 <= mm or (lam, m2) not in self.psiU:
                        continue
                    xn = m.add_binary(f"XN[{lam},m{mm}->m{m2}]")
                    self.XN[lam, mm, m2] = xn
                    m.add_le(xn - self.psiU[lam, m2], 0.0)
                    # no hop across an intermediate used level
                    for m3 in self.levels:
                        if mm < m3 < m2 and (lam, m3) in self.psiU:
                            m.add_le(xn + self.psiU[lam, m3], 1.0)
                    outs.append(xn)
                if outs:
                    m.add_eq(sum(outs, LinExpr({}))
                             - self.hasout[lam, mm], 0.0)

        # weights must terminate in the macro array
        mac = arch.macro_level
        if (WEIGHT, mac) in self.psiU:
            m.add_ge(LinExpr({self.psiU[WEIGHT, mac].idx: 1.0}), 1.0)

        # psi^DM (eq. 9 buffering mode)
        self.psiDM = {}
        for lam in OPERANDS:
            for mm in self.levels:
                lvl = arch.level(mm)
                if (lam, mm) in self.psiU and lvl.double_bufferable \
                        and mm != mac:
                    dm = m.add_binary(f"psiDM[{lam},m{mm}]")
                    m.add_le(dm - self.psiU[lam, mm], 0.0)
                    self.psiDM[lam, mm] = dm

        # ---------------- core-extent one-hot (variable bandwidth) --------
        core_vals = self._core_extent_values()
        self.VE = self.m.add_one_hot("VE", len(core_vals))
        e_log = LinExpr({})
        for k, (d, j, f) in enumerate(ff):
            if (k, "core") in self.XU:
                e_log = e_log + log2(f) * self.XU[k, "core"]
        sel = LinExpr({})
        for v, var in zip(core_vals, self.VE):
            sel = sel + log2(v) * var
        m.add_eq(sel - e_log, 0.0)
        self.core_vals = core_vals

        # ---------------- size/transfer enumeration (eqs. 6-10) ----------
        self._build_sizes()

        # ---------------- capacity (eq. 9) --------------------------------
        self._build_capacity()

        # ---------------- latency (eq. 11-13, Table III) -------------------
        self._build_latency(big_m_latency, big_m_transfer)

        # ---------------- objective (eq. 14) -------------------------------
        size_term = LinExpr({})
        for (mm, lam), s in self.Size.items():
            size_term = size_term + float(mm) * s
        max_size = sum(
            mm * self._max_bytes(mm, lam)
            for (mm, lam) in self.Size.keys()) or 1.0
        mu2 = cfg.mu2_frac * big_m_latency / max_size
        m.minimize(cfg.mu1 * self.PMAX - mu2 * size_term)

        if cfg.weight_stationary:
            self._add_ws_constraints()

    # ------------------------------------------------------------------
    def _core_extent_values(self) -> list[int]:
        ax = self.arch.axis("core")
        pool = [f for (d, j, f) in self.ff if d in ax.dims]
        vals = [v for v in sub_multiset_products(pool) if v <= ax.size]
        return vals or [1]

    def _dim_values(self, d: str) -> list[int]:
        return sub_multiset_products(self.factors.get(d, []))

    def _max_bytes(self, mm: int, lam: str) -> float:
        return self.layer.operand_elems(lam) * \
            operand_bits(self.arch, mm, lam) / 8.0

    def _combos(self, mm: int, lam: str) -> list[dict[str, int]]:
        """Enumerate per-dim bound combos for (m, λ), capacity-filtered."""
        rel = [d for d in wl.RELEVANT[lam] if d in self.factors]
        value_sets = [self._dim_values(d) for d in rel]
        cap = self.arch.level(mm).capacity_bytes
        max_lanes = max(self.core_vals)
        out = []
        for combo in itertools.product(*value_sets):
            t = dict(zip(rel, combo))
            elems = wl.operand_tile_elems(self.layer, lam, t)
            b = elems * operand_bits(self.arch, mm, lam) / 8.0
            if cap is not None and b > cap * max_lanes * 2:
                continue
            out.append(t)
        if len(out) > self.cfg.combo_cap:
            raise ComboOverflow(
                f"{len(out)} combos for (m={mm}, {lam}); coarsen the "
                f"factorization (alpha/k_min)")
        return out

    def _combo_bytes(self, mm: int, lam: str, t: dict[str, int]) -> float:
        elems = wl.operand_tile_elems(self.layer, lam, t)
        return elems * operand_bits(self.arch, mm, lam) / 8.0

    def _bound_expr(self, d: str, lam: str, min_level: int,
                    spatial_min_cu: int) -> LinExpr:
        """Σ_f log2(F)·(Σ_{m'>=min_level} X^M + Σ_{u: C_u>=cu} X^U)."""
        e = LinExpr({})
        for k, (dd, j, f) in enumerate(self.ff):
            if dd != d:
                continue
            for mm in self.levels:
                if mm >= min_level and (k, lam, mm) in self.XM:
                    e = e + math.log2(f) * self.XM[k, lam, mm]
            for ax in self.arch.spatial:
                if ax.at_level >= spatial_min_cu and (k, ax.name) in self.XU:
                    e = e + math.log2(f) * self.XU[k, ax.name]
        return e

    def _build_sizes(self) -> None:
        m, arch, cfg = self.m, self.arch, self.cfg
        self.VS = {}
        self.VT = {}
        self.Size = {}
        self.TC = {}
        self.combos = {}
        for lam in OPERANDS:
            for mm in self.levels:
                if (lam, mm) not in self.psiU:
                    continue
                combos = self._combos(mm, lam)
                self.combos[mm, lam] = combos
                rel = [d for d in wl.RELEVANT[lam] if d in self.factors]
                # ---- V^S: stored size (skip DRAM: unbounded, no objective
                # term at m=0 anyway)
                if mm >= 1:
                    vs = m.add_binaries(f"VS[m{mm},{lam}]", len(combos))
                    m.add_eq(sum(vs, LinExpr({}))
                             - self.psiU[lam, mm], 0.0)
                    self.VS[mm, lam] = vs
                    for d in rel:
                        selected = LinExpr({})
                        for t, var in zip(combos, vs):
                            selected = selected + math.log2(t[d]) * var
                        bexpr = self._bound_expr(d, lam, mm, mm)
                        diff = selected - bexpr
                        # enforce only when psi^U = 1 (eq. 8)
                        gate = LOG2_M * (1 - self.psiU[lam, mm] * 1.0)
                        m.add_le(diff - gate, 0.0)
                        m.add_ge(diff + gate, 0.0)
                    size = m.add_var(f"Size[m{mm},{lam}]", 0.0,
                                     self._max_bytes(mm, lam))
                    sel_b = LinExpr({})
                    for t, var in zip(combos, vs):
                        sel_b = sel_b + self._combo_bytes(mm, lam, t) * var
                    m.add_eq(size - sel_b, 0.0)
                    self.Size[mm, lam] = size
                # ---- V^T: transfer chunk out of level mm (eq. 10/11)
                if (lam, mm) in self.hasout:
                    vt = m.add_binaries(f"VT[m{mm},{lam}]", len(combos))
                    m.add_eq(sum(vt, LinExpr({}))
                             - self.hasout[lam, mm], 0.0)
                    self.VT[mm, lam] = vt
                    for d in rel:
                        selected = LinExpr({})
                        for t, var in zip(combos, vt):
                            selected = selected + math.log2(t[d]) * var
                        bexpr = self._bound_expr(d, lam, mm + 1, mm)
                        diff = selected - bexpr
                        gate = LOG2_M * (1 - self.hasout[lam, mm] * 1.0)
                        m.add_le(diff - gate, 0.0)
                        m.add_ge(diff + gate, 0.0)

    def _transfer_cycles_const(self, mm: int, lam: str, t: dict[str, int],
                               lanes: int) -> float:
        bw = self.arch.level(mm).bytes_per_cycle() * lanes
        return math.ceil(self._combo_bytes(mm, lam, t) / bw)

    def _build_capacity(self) -> None:
        m, arch = self.m, self.arch
        self.DBX = {}
        cap_lanes = {}
        for mm in self.levels:
            lvl = arch.level(mm)
            if lvl.capacity_bytes is None:
                continue
            # effective capacity = cap * core extent when level replicated
            replicated = any(
                ax.replicates_from is not None and ax.replicates_from <= mm
                for ax in arch.spatial)
            cap_rhs = LinExpr({})
            if replicated:
                for v, var in zip(self.core_vals, self.VE):
                    cap_rhs = cap_rhs + (lvl.capacity_bytes * v) * var
            else:
                cap_rhs = LinExpr({}, float(lvl.capacity_bytes))
            served = [lam for lam in OPERANDS if (mm, lam) in self.Size]
            terms = LinExpr({})
            for lam in served:
                size = self.Size[mm, lam]
                dbx = m.add_var(f"DBX[m{mm},{lam}]", 0.0,
                                self._max_bytes(mm, lam))
                self.DBX[mm, lam] = dbx
                if (lam, mm) in self.psiDM:
                    big = self._max_bytes(mm, lam)
                    m.add_ge(dbx - size + big * (1 - self.psiDM[lam, mm]
                                                 * 1.0), 0.0)
                if lvl.shared:
                    terms = terms + size + dbx
                else:
                    m.add_le(size + dbx - cap_rhs, 0.0)
            if lvl.shared and served:
                m.add_le(terms - cap_rhs, 0.0)

    def _build_latency(self, m_lat: float, m_tr: float) -> None:
        m, arch, layer = self.m, self.arch, self.layer
        ff, n_slots = self.ff, self.n_slots
        l_mvm = float(arch.l_mvm_cycles)
        mac = arch.macro_level

        # DBdest[λ,m]: hop out of m lands in a double-buffered level (eq. 12,
        # destination-mode reading — see DESIGN.md).
        dbdest = {}
        for lam in OPERANDS:
            for mm in self.levels:
                if (lam, mm) not in self.hasout:
                    continue
                terms = []
                for m2 in self.levels:
                    if (lam, mm, m2) in self.XN and (lam, m2) in self.psiDM:
                        terms.append(m.add_and(
                            f"XNDM[{lam},{mm},{m2}]",
                            [self.XN[lam, mm, m2], self.psiDM[lam, m2]]))
                if terms:
                    dbdest[lam, mm] = m.add_or(f"DBd[{lam},m{mm}]", terms)

        # TC[m,λ]: cycles per transfer out of level m (eq. 11), with
        # lane-scaled bandwidth for replicated levels and the Memory-mode
        # switch penalty for weight reloads into the macro.
        for lam in OPERANDS:
            for mm in self.levels:
                if (mm, lam) not in self.VT:
                    continue
                tc = m.add_var(f"TC[m{mm},{lam}]", 0.0, m_tr)
                self.TC[mm, lam] = tc
                # pin to zero when the hop does not exist
                m.add_le(tc - m_tr * self.hasout[lam, mm], 0.0)
                combos = self.combos[mm, lam]
                vt = self.VT[mm, lam]
                ms_term = LinExpr({})
                if lam == WEIGHT and (lam, mm, mac) in self.XN:
                    ms_term = arch.mode_switch_cycles * self.XN[lam, mm, mac]
                lane_scaled = any(
                    ax.replicates_from is not None and ax.replicates_from <= mm
                    for ax in arch.spatial)
                if not lane_scaled:
                    sel = LinExpr({})
                    for t, var in zip(combos, vt):
                        sel = sel + self._transfer_cycles_const(
                            mm, lam, t, 1) * var
                    m.add_ge(tc - sel - ms_term, 0.0)
                else:
                    for t, var in zip(combos, vt):
                        for v, evar in zip(self.core_vals, self.VE):
                            cyc = self._transfer_cycles_const(mm, lam, t, v)
                            rhs = cyc * 1.0
                            e = tc - ms_term + m_tr * (1 - var * 1.0) \
                                + m_tr * (1 - evar * 1.0)
                            m.add_ge(e, rhs)

        # per-slot machinery
        self.T = {}
        self.P = {}
        self.L = []
        self.R = {}
        hasT = {}
        act_single = {}
        act_double = {}
        for i in range(n_slots):
            self.L.append(m.add_var(f"L[{i}]", l_mvm, m_lat))
            for lam in OPERANDS:
                self.P[i, lam] = m.add_var(f"P[{i},{lam}]", l_mvm, m_lat)
                self.T[i, lam] = m.add_var(f"T[{i},{lam}]", 0.0, m_tr)
        # boundary pseudo-slot
        p_bound = {lam: LinExpr({}, l_mvm) for lam in OPERANDS}

        for i in range(n_slots):
            for lam in OPERANDS:
                # R[i,λ]: slot's dim relevant to λ
                rel_expr = LinExpr({})
                for k, (d, j, f) in enumerate(self.ff):
                    if wl.is_relevant(d, lam):
                        rel_expr = rel_expr + self.XL[k, i]
                r = m.add_binary(f"R[{i},{lam}]")
                m.add_eq(LinExpr({r.idx: 1.0}) - rel_expr, 0.0)
                self.R[i, lam] = r
                # W1[i,λ,m] = XZ ∧ HasOut  (transfer possible at this slot)
                w1s = []
                for mm in self.levels:
                    if (i, lam, mm) in self.XZ and (lam, mm) in self.hasout:
                        w1s.append(m.add_and(
                            f"W1[{i},{lam},m{mm}]",
                            [self.XZ[i, lam, mm], self.hasout[lam, mm]]))
                ht = m.add_binary(f"HasT[{i},{lam}]")
                if w1s:
                    sw = sum(w1s, LinExpr({}))
                    m.add_le(LinExpr({ht.idx: 1.0}) - sw, 0.0)
                    m.add_le(ht - r, 0.0)
                    m.add_ge(LinExpr({ht.idx: 1.0}) - sw
                             - LinExpr({r.idx: 1.0}), -1.0)
                else:
                    m.add_eq(LinExpr({ht.idx: 1.0}), 0.0)
                hasT[i, lam] = ht
                # psi^DL via W2 = XZ ∧ DBdest
                w2s = []
                for mm in self.levels:
                    if (i, lam, mm) in self.XZ and (lam, mm) in dbdest:
                        w2s.append(m.add_and(
                            f"W2[{i},{lam},m{mm}]",
                            [self.XZ[i, lam, mm], dbdest[lam, mm]]))
                if w2s:
                    dl = m.add_or(f"psiDL[{i},{lam}]", w2s)
                else:
                    dl = m.add_binary(f"psiDL[{i},{lam}]")
                    m.add_eq(LinExpr({dl.idx: 1.0}), 0.0)
                a_d = m.add_and(f"ActD[{i},{lam}]", [ht, dl])
                act_double[i, lam] = a_d
                a_s = m.add_binary(f"ActS[{i},{lam}]")
                # single = HasT ∧ ¬DL  ->  a_s = ht - a_d
                m.add_eq(LinExpr({a_s.idx: 1.0}) - ht + a_d, 0.0)
                act_single[i, lam] = a_s
                # T[i,λ] >= TC[m,λ] when slot in block m and transfer active
                for mm in self.levels:
                    if (mm, lam) in self.TC and (i, lam, mm) in self.XZ:
                        e = self.T[i, lam] - self.TC[mm, lam] \
                            + m_tr * (1 - self.XZ[i, lam, mm] * 1.0) \
                            + m_tr * (1 - ht * 1.0)
                        m.add_ge(e, 0.0)

        # recursion rows (Table III), innermost upward
        self.PMAX = m.add_var("PMAX", l_mvm, m_lat)
        for i in range(n_slots - 1, -1, -1):
            L_i = self.L[i]
            if i == n_slots - 1:
                l_inner = LinExpr({}, l_mvm)
                p_inner = p_bound
                n_inner_rows = []          # inner N fixed to 1
            else:
                l_inner = LinExpr({self.L[i + 1].idx: 1.0})
                p_inner = {lam: LinExpr({self.P[i + 1, lam].idx: 1.0})
                           for lam in OPERANDS}
                n_inner_rows = [(k, f) for k, (d, j, f) in enumerate(ff)]
            # L_i >= L_{i+1} (propagation)
            m.add_ge(L_i - l_inner, 0.0)
            # L_i >= L_{i+1} * N_{i+1}   (per-factor big-M; gate scaled by F
            # because the bounded expression reaches F * m_lat)
            for k, f in n_inner_rows:
                m.add_ge(L_i - f * l_inner
                         + f * m_lat * (1 - self.XL[k, i + 1] * 1.0), 0.0)
            for lam in OPERANDS:
                t_v = self.T[i, lam]
                # combined components of L_i (active slots only)
                m.add_ge(L_i - p_inner[lam]
                         + m_lat * (1 - self.psiL[i] * 1.0), 0.0)
                m.add_ge(L_i - t_v - p_inner[lam]
                         + 2 * m_lat * (1 - act_single[i, lam] * 1.0), 0.0)
                m.add_ge(L_i - t_v
                         + m_lat * (1 - self.psiL[i] * 1.0), 0.0)
                # MX = max(T, P_inner); MXL = max(T, L_i)
                mx = m.add_var(f"MX[{i},{lam}]", 0.0, m_lat)
                m.add_ge(mx - t_v, 0.0)
                m.add_ge(mx - p_inner[lam], 0.0)
                mxl = m.add_var(f"MXL[{i},{lam}]", 0.0, m_lat)
                m.add_ge(mxl - t_v, 0.0)
                m.add_ge(mxl - L_i, 0.0)
                P_i = self.P[i, lam]
                m.add_ge(P_i - p_inner[lam], 0.0)   # monotone propagation
                ht = hasT[i, lam]
                a_s, a_d = act_single[i, lam], act_double[i, lam]
                is_o = lam == OUTPUT
                for k, (d, j, f) in enumerate(ff):
                    # Gates must dominate the full row magnitude, which
                    # scales with F: use (F+4)*m_lat.
                    gm = (f + 4) * m_lat
                    gate_slot = gm * (1 - self.XL[k, i] * 1.0)
                    # no-transfer row: P >= (F-1) L + P_inner
                    m.add_ge(P_i - (f - 1) * L_i - p_inner[lam]
                             + gate_slot + gm * (ht * 1.0), 0.0)
                    if not is_o:
                        cs = max(f - 2, 0)
                        m.add_ge(P_i - cs * L_i - 2 * t_v - p_inner[lam]
                                 + gate_slot
                                 + gm * (1 - a_s * 1.0), 0.0)
                        cd = max(f - 3, 0)
                        m.add_ge(P_i - cd * L_i - 2 * t_v - mx
                                 + gate_slot
                                 + gm * (1 - a_d * 1.0), 0.0)
                        m.add_ge(P_i - f * t_v + gate_slot
                                 + gm * (1 - a_d * 1.0), 0.0)
                    else:
                        cs = max(f - 1, 0)
                        m.add_ge(P_i - cs * L_i - 2 * t_v - p_inner[lam]
                                 + gate_slot
                                 + gm * (1 - a_s * 1.0), 0.0)
                        cd = max(f - 2, 0)
                        m.add_ge(P_i - cd * L_i - t_v - mxl - mx
                                 + gate_slot
                                 + gm * (1 - a_d * 1.0), 0.0)
                # inactive slot: P_i >= P_inner (already), == via minimization

        # ---- one-time fills -------------------------------------------------
        # A hop out of level m is "triggered" when some λ-relevant slot sits
        # at a level <= m; untriggered hops (fully-stationary tiles: initial
        # weight program-in, final output drain) cost one TC, charged once on
        # top of P_0 — mirrors latency.evaluate()'s one-time accounting.
        self.OTC = {}
        for lam in OPERANDS:
            for mm in self.levels:
                if (mm, lam) not in self.TC:
                    continue
                trig_terms = []
                for i in range(n_slots):
                    le_expr = LinExpr({})
                    for m2 in self.levels:
                        if m2 <= mm and (i, lam, m2) in self.XZ:
                            le_expr = le_expr + self.XZ[i, lam, m2]
                    tr = m.add_binary(f"TrL[{i},{lam},m{mm}]")
                    m.add_le(tr - self.R[i, lam], 0.0)
                    m.add_le(LinExpr({tr.idx: 1.0}) - le_expr, 0.0)
                    m.add_ge(LinExpr({tr.idx: 1.0}) - le_expr
                             - self.R[i, lam], -1.0)
                    trig_terms.append(tr)
                trig = m.add_or(f"Trig[{lam},m{mm}]", trig_terms) \
                    if trig_terms else None
                otc = m.add_var(f"OTC[{lam},m{mm}]", 0.0, m_tr)
                rhs = self.TC[mm, lam] - otc
                if trig is not None:
                    rhs = rhs - m_tr * trig
                m.add_le(rhs, 0.0)       # otc >= TC - M*trig
                self.OTC[lam, mm] = otc

        # One-time fills serialize with each other (shared DRAM/GBuf buses):
        # total = max_λ P_0,λ + Σ_{λ,m} OTC — matches latency.evaluate().
        ot_sum = LinExpr({})
        for (lam, mm), v in self.OTC.items():
            ot_sum = ot_sum + v
        for lam in OPERANDS:
            m.add_ge(self.PMAX - self.P[0, lam] - ot_sum, 0.0)

    # ------------------------------------------------------------------
    def _add_ws_constraints(self) -> None:
        """Weight-stationary baseline: weight-relevant loops outermost (each
        weight tile loaded exactly once) and no weight double-buffering."""
        m = self.m
        n = self.n_slots
        pos = {}
        for k, (d, j, f) in enumerate(self.ff):
            e = LinExpr({})
            for i in range(n):
                e = e + float(i) * self.XL[k, i]
            pos[k] = e
        for k, (d, j, f) in enumerate(self.ff):
            for k2, (d2, j2, f2) in enumerate(self.ff):
                if wl.is_relevant(d, WEIGHT) and not wl.is_relevant(d2, WEIGHT):
                    # pos_k <= pos_k2 whenever both factors are temporal:
                    # pos_k - pos_k2 + n*tk + n*tk2 <= 2n
                    tk = sum((self.XL[k, i] for i in range(n)), LinExpr({}))
                    tk2 = sum((self.XL[k2, i] for i in range(n)), LinExpr({}))
                    m.add_le(pos[k] - pos[k2] + n * tk + n * tk2, 2.0 * n)
        for (lam, mm), dm in list(self.psiDM.items()):
            if lam == WEIGHT:
                m.add_eq(LinExpr({dm.idx: 1.0}), 0.0)

    # ------------------------------------------------------------------
    def decode(self, sol) -> Mapping:
        arch = self.arch
        spatial: dict[str, list[tuple[str, int]]] = {ax.name: []
                                                     for ax in arch.spatial}
        slot_of: dict[int, int] = {}
        for k, (d, j, f) in enumerate(self.ff):
            placed = False
            for i in range(self.n_slots):
                if sol.binary(self.XL[k, i]):
                    slot_of[k] = i
                    placed = True
                    break
            if not placed:
                for ax in arch.spatial:
                    if (k, ax.name) in self.XU and \
                            sol.binary(self.XU[k, ax.name]):
                        spatial[ax.name].append((d, f))
                        break
        order = sorted(slot_of.items(), key=lambda kv: kv[1])
        temporal = tuple((self.ff[k][0], self.ff[k][2]) for k, _ in order)
        level_of = {}
        for lam in OPERANDS:
            lv = []
            for k, i in order:
                mm_sel = None
                for mm in self.levels:
                    if (k, lam, mm) in self.XM and \
                            sol.binary(self.XM[k, lam, mm]):
                        mm_sel = mm
                        break
                lv.append(mm_sel if mm_sel is not None else 0)
            level_of[lam] = tuple(lv)
        dbuf = set()
        for (lam, mm), dm in self.psiDM.items():
            if sol.binary(dm):
                dbuf.add((lam, mm))
        return Mapping(
            spatial={k: tuple(v) for k, v in spatial.items()},
            temporal=temporal, level_of=level_of,
            double_buf=frozenset(dbuf))


def pin_mapping(form: MiredoFormulation, mapping: Mapping) -> None:
    """Fix all structural binaries to encode a concrete mapping (testing:
    the MIP's internal latency must then equal latency.evaluate())."""
    m, arch = form.m, form.arch

    def pin(var, val):
        m._lb[var.idx] = m._ub[var.idx] = float(val)

    used = set()

    def take(d, fval):
        for k, (dd, j, fv) in enumerate(form.ff):
            if k not in used and dd == d and fv == fval:
                used.add(k)
                return k
        raise KeyError((d, fval))

    # canonical assignment order (matches the symmetry-breaking rows):
    # temporal slots first (by slot index), then spatial axes in arch order.
    spa, tmp = {}, {}
    for i, (d, fv) in enumerate(mapping.temporal):
        tmp[take(d, fv)] = i
    for ax in arch.spatial:
        for d, fv in mapping.spatial.get(ax.name, ()):
            spa[take(d, fv)] = ax.name
    for k in range(len(form.ff)):
        for i in range(form.n_slots):
            pin(form.XL[k, i], 1.0 if tmp.get(k) == i else 0.0)
        for ax in arch.spatial:
            if (k, ax.name) in form.XU:
                pin(form.XU[k, ax.name], 1.0 if spa.get(k) == ax.name
                    else 0.0)
    for k, i in tmp.items():
        for lam in OPERANDS:
            lv = mapping.level_of[lam][i]
            for mm in form.levels:
                if (k, lam, mm) in form.XM:
                    pin(form.XM[k, lam, mm], 1.0 if mm == lv else 0.0)
    for k in spa:
        for lam in OPERANDS:
            for mm in form.levels:
                if (k, lam, mm) in form.XM:
                    pin(form.XM[k, lam, mm], 0.0)
    for (lam, mm), dm in form.psiDM.items():
        pin(dm, 1.0 if (lam, mm) in mapping.double_buf else 0.0)


def mip_latency_of(layer: wl.Layer, arch: CimArch, mapping: Mapping,
                   cfg: FormulationConfig | None = None,
                   m_lat: float | None = None) -> float:
    """MIP-internal latency of a pinned mapping (consistency testing)."""
    cfg = cfg or FormulationConfig()
    if m_lat is None:
        m_lat = 8 * evaluate(mapping, layer, arch).total_cycles
    form = MiredoFormulation(layer, arch, cfg)
    form.build(m_lat, m_lat)
    pin_mapping(form, mapping)
    sol = form.m.solve(time_limit_s=cfg.time_limit_s, mip_rel_gap=1e-6)
    if not sol.ok:
        return math.nan
    return sol[form.PMAX]


def native_incumbents(layer: wl.Layer, arch: CimArch,
                      cfg: FormulationConfig) -> list[tuple[float, Mapping]]:
    """Greedy + accurate-heuristic incumbent pool, best first on ties.

    A stronger incumbent is pure upside: it tightens the MIP's pruning UB
    and raises the floor of the time-capped fallback (~0.2s for 2000
    accurate-model samples vs solver budgets in the tens of seconds).
    Shared by the single solve and every portfolio member
    (`core/portfolio.py` computes the pool once per layer)."""
    from repro.core.baselines import greedy_mapping, heuristic_search
    greedy = greedy_mapping(layer, arch)
    g_lat = evaluate(greedy, layer, arch).total_cycles
    seed_res = heuristic_search(layer, arch, budget=2000, seed=1,
                                accurate=True, k_min=cfg.k_min,
                                alpha=cfg.alpha)
    # ties prefer the earlier entry: search incumbent, then greedy, then
    # (appended by the caller) any neighbor warm start — the historical
    # fallback preference
    return [(seed_res.eval_latency, seed_res.mapping), (g_lat, greedy)]


def ladder_rungs(cfg: FormulationConfig) -> list[tuple[float, int]]:
    """The Flexible-Factorization coarsening ladder: (alpha, k_min) per
    rung, finest first. Rung indices are a portfolio-member dimension
    (`portfolio.PortfolioMember.rung`)."""
    return [
        (cfg.alpha, cfg.k_min),
        (max(cfg.alpha, 0.5), 2),
        (1.0, 1),
    ]


def _fallback_result(incumbents, layer, arch, status, t0, *,
                     incumbent_latency, form=None, sol=None) -> MiredoResult:
    """Best-incumbent result for budget-exhausted / solution-less solves."""
    fallback = min(incumbents, key=lambda lc: lc[0])[1]
    rep = evaluate(fallback, layer, arch)
    return MiredoResult(
        mapping=fallback, status=status, objective=math.nan,
        mip_latency=math.nan, eval_latency=rep.total_cycles,
        solve_seconds=time.monotonic() - t0,
        n_vars=form.m.n_vars if form is not None else 0,
        n_rows=form.m.n_rows if form is not None else 0,
        mip_gap=sol.mip_gap if sol is not None else math.nan,
        incumbent_latency=incumbent_latency,
        mip_node_count=sol.mip_node_count if sol is not None else math.nan,
        mip_dual_bound=sol.mip_dual_bound if sol is not None else math.nan)


def solve_ladder(layer: wl.Layer, arch: CimArch, cfg: FormulationConfig,
                 incumbents: Sequence[tuple[float, Mapping]], *,
                 t0: float, deadline: float,
                 incumbent_latency: float | None = None,
                 rung: int = 0, node_limit: int | None = None,
                 presolve: bool | None = None,
                 mip_rel_gap: float | None = None) -> MiredoResult:
    """One parameterized pass down the factorization ladder under a hard
    shared deadline.

    **Budget contract** (the ISSUE-10 ladder fix): *every* rung — builds
    included — is charged against the single ``deadline`` anchored at
    ``t0``. A rung that starts after the deadline is skipped, and the solve
    of the rung that does run gets exactly the remaining wall clock, so the
    3-rung combo-overflow fallback can no longer spend
    ``time_limit_s + ~10 s`` (each rung used to re-floor its budget at
    ``max(min(5, limit), remaining)``), which broke
    `network.allocate_budgets`' sum-to-total contract. When the deadline
    expires before any solve lands, the best incumbent is returned
    (`Status.ERROR`, the solution-less status) — never ``None``.

    ``rung``/``node_limit``/``presolve``/``mip_rel_gap`` are the portfolio
    member knobs (`core/portfolio.py`); the defaults reproduce the single
    baseline solve. SUSPECT solves (numerical trouble with an assignment)
    are decoded but only trusted if `mapping.validate` passes — the
    validate/fallback path stays authoritative.
    """
    gap = cfg.mip_rel_gap if mip_rel_gap is None else mip_rel_gap
    ub = min(l for l, _ in incumbents)
    if incumbent_latency is None:
        incumbent_latency = ub
    m_lat = max(cfg.latency_slack, BIG_M_FLOOR) * ub
    rungs = ladder_rungs(cfg)
    rungs = rungs[min(rung, len(rungs) - 1):]
    last_exc: Exception | None = None
    out_of_time = False
    for alpha, k_min in rungs:
        if time.monotonic() >= deadline:
            out_of_time = True
            break
        c = dataclasses.replace(cfg, alpha=alpha, k_min=k_min)
        try:
            form = MiredoFormulation(layer, arch, c)
            form.build(m_lat, m_lat)
        except ComboOverflow as e:
            last_exc = e
            continue
        # prune with the incumbent (+0.1% float slack)
        form.m.add_le(LinExpr({form.PMAX.idx: 1.0}), ub * 1.001)
        budget = max(0.0, deadline - time.monotonic())
        sol = form.m.solve(time_limit_s=budget, mip_rel_gap=gap,
                           verbose=cfg.verbose, node_limit=node_limit,
                           presolve=presolve)
        dt = time.monotonic() - t0
        if not sol.usable:
            # UB mapping may not be representable at this factorization
            # granularity; fall back to the best incumbent.
            return _fallback_result(incumbents, layer, arch, sol.status, t0,
                                    incumbent_latency=incumbent_latency,
                                    form=form, sol=sol)
        mapping = form.decode(sol)
        errs = validate(mapping, layer, arch)
        if errs:
            if sol.status is Status.SUSPECT:
                # numerical trouble produced a genuinely infeasible
                # assignment: flagged, not fatal — keep the incumbent
                return _fallback_result(
                    incumbents, layer, arch, sol.status, t0,
                    incumbent_latency=incumbent_latency, form=form, sol=sol)
            raise AssertionError(
                f"MIP produced infeasible mapping for {layer.name}: {errs}")
        rep = evaluate(mapping, layer, arch)
        # never return something worse than the incumbent
        if rep.total_cycles > ub:
            fallback = min(incumbents, key=lambda lc: lc[0])[1]
            rep_f = evaluate(fallback, layer, arch)
            if rep_f.total_cycles < rep.total_cycles:
                mapping, rep = fallback, rep_f
        return MiredoResult(
            mapping=mapping, status=sol.status, objective=sol.objective,
            mip_latency=sol[form.PMAX], eval_latency=rep.total_cycles,
            solve_seconds=dt, n_vars=form.m.n_vars, n_rows=form.m.n_rows,
            mip_gap=sol.mip_gap, incumbent_latency=incumbent_latency,
            mip_node_count=sol.mip_node_count,
            mip_dual_bound=sol.mip_dual_bound)
    if out_of_time or last_exc is None:
        # deadline exhausted (possibly before the first build): the
        # incumbent is the answer the budget paid for
        return _fallback_result(incumbents, layer, arch, Status.ERROR, t0,
                                incumbent_latency=incumbent_latency)
    raise last_exc


def optimize_layer(layer: wl.Layer, arch: CimArch,
                   cfg: FormulationConfig | None = None,
                   warm_start: Mapping | None = None,
                   portfolio=None) -> MiredoResult:
    """End-to-end: factorize -> build MIP -> solve -> decode -> re-score.

    The incumbent of a cheap accurate-model search provides (a) a valid upper
    bound that prunes the branch-and-bound tree (PMAX <= UB) and (b) tight
    big-M constants (any mapping worse than UB is never optimal). On combo
    explosion the layer retries with progressively coarser Flexible
    Factorization — the paper's own complexity-control knob — with all
    rungs charged against ONE deadline of ``cfg.time_limit_s`` seconds from
    entry (see `solve_ladder`).

    ``warm_start`` optionally injects a mapping solved for a *neighboring*
    architecture (incremental DSE re-solves): it is re-validated against
    this arch, and — only when feasible here and strictly better than the
    search incumbents — tightens the pruning UB and joins the fallback
    pool. ``None`` leaves behavior exactly unchanged.

    ``portfolio`` (a `portfolio.Portfolio`) races K deterministic solver
    parameterizations inside the same ``cfg.time_limit_s`` budget, sharing
    the best-known upper bound, and returns the best member's result by
    ``(eval_latency, member_index)`` — see `core/portfolio.py`. ``None``
    (default) runs the single baseline parameterization.
    """
    cfg = cfg or FormulationConfig()
    if portfolio is not None:
        from repro.core.portfolio import race
        return race(layer, arch, cfg, portfolio, warm_start=warm_start).result
    t0 = time.monotonic()
    deadline = t0 + cfg.time_limit_s
    incumbents = native_incumbents(layer, arch, cfg)
    native_ub = min(l for l, _ in incumbents)
    if warm_start is not None and not validate(warm_start, layer, arch):
        incumbents.append(
            (evaluate(warm_start, layer, arch).total_cycles, warm_start))
    return solve_ladder(layer, arch, cfg, incumbents, t0=t0,
                        deadline=deadline, incumbent_latency=native_ub)
