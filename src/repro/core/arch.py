"""Hierarchical CIM architecture abstraction (paper §III-A, Fig. 1, Table IV).

The accelerator is modeled as an ordered memory hierarchy plus spatial
unrolling axes plus a CIM macro:

    m=0  off-chip DRAM          (source of all operands)
    m=1  Global Buffer (GBuf)   (shared across operands, multicast network)
    m=2  Local Buffer  (LBuf)   (per CIM core)
    m=3  Register files         (IReg / WReg / OReg, dedicated per operand)
    m=4  CIM macro array        (weights resident; Memory-mode vs Compute-mode)

Larger ``m`` is *closer to the macro* — matching the paper's index convention
(eq. 5: "a larger index value m denotes a memory level closer to the CIM
macros").

Every level can be, per operand:
  * bypassed            (psi^U = 0),
  * single-buffered     (full capacity, transfers serialize with compute),
  * double-buffered     (transfers overlap compute, HALF effective capacity —
                         modeled per paper eq. 9 as (1 + psi^DM) * Size <= CA).

The CIM macro is special: Memory mode (weight update) and Compute mode (MVM)
share peripheral circuits, so weight reloads can never overlap computation
(Fig. 2(a)); this is expressed by forcing single-buffering for the weight
operand at the macro level plus a constant ``mode_switch_cycles`` charged per
reload event.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# Operand identifiers (paper index λ).
INPUT = "I"
WEIGHT = "W"
OUTPUT = "O"
OPERANDS = (INPUT, WEIGHT, OUTPUT)


@dataclasses.dataclass(frozen=True)
class MemLevel:
    """One memory hierarchy level.

    Attributes:
      name: human-readable name.
      capacity_bytes: total capacity (``None`` = unbounded, e.g. DRAM). For
        ``shared=True`` the capacity is shared across all served operands
        (paper eq. 9 sums over λ); otherwise it is per-operand.
      bus_bits: bus width in bits per cycle for transfers sourced from this
        level (paper constant BW_m, eq. 11).
      serves: which operands this level can hold (paper matrix C^M).
      shared: whether capacity is shared across operands.
      bypassable: whether an operand may skip this level (psi^U = 0).
      double_bufferable: whether psi^DM = 1 is allowed here.
      access_energy_pj_per_byte: per-byte access energy (PCACTI-class
        constant; used by energy.py — ratios, not absolute joules, matter
        for the paper's EDP comparisons).
    """

    name: str
    capacity_bytes: int | None
    bus_bits: int
    serves: tuple[str, ...] = OPERANDS
    shared: bool = True
    bypassable: bool = False
    double_bufferable: bool = True
    access_energy_pj_per_byte: float = 1.0

    def bytes_per_cycle(self) -> float:
        return self.bus_bits / 8.0


@dataclasses.dataclass(frozen=True)
class SpatialAxis:
    """A spatial unrolling axis (paper matrix C^X).

    Attributes:
      name: axis name ("core", "wordline", "bitline").
      size: number of parallel lanes.
      dims: tensor dims allowed to unroll on this axis.
      at_level: hierarchy level index at/below which the axis multiplies
        tile/transfer-chunk sizes (paper constant C_u: "the summation over u
        is performed for all indices satisfying C_u >= m"). Unrolling across
        cores multiplies GBuf->LBuf multicast traffic (at_level=2);
        wordline/bitline unrolling multiplies register->macro traffic
        (at_level=4).
      replicates_from: first hierarchy level that physically exists once per
        lane of this axis (cores replicate LBuf/Reg/Macro -> 2); ``None``
        when no memory level is per-lane (wordline/bitline lanes live
        *inside* the macro array). Governs capacity/bandwidth aggregation.
    """

    name: str
    size: int
    dims: tuple[str, ...]
    at_level: int
    replicates_from: int | None = None


@dataclasses.dataclass(frozen=True)
class CimArch:
    """Complete accelerator description (paper Table IV defaults)."""

    levels: tuple[MemLevel, ...]
    spatial: tuple[SpatialAxis, ...]
    macro_rows: int = 128          # wordlines: input-vector chunk length
    macro_cols: int = 32           # bitlines: output channels per macro
    l_mvm_cycles: int = 16         # bit-serial MVM latency (8b serial + ADC pipe)
    mode_switch_cycles: int = 10   # Memory<->Compute mode transition (Fig 2a)
    mac_energy_pj: float = 0.08    # per INT8 MAC inside the macro
    freq_ghz: float = 1.0
    name: str = "cim"

    # ---- derived helpers -------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def macro_level(self) -> int:
        return len(self.levels) - 1

    def level(self, m: int) -> MemLevel:
        return self.levels[m]

    def axis(self, name: str) -> SpatialAxis:
        for ax in self.spatial:
            if ax.name == name:
                return ax
        raise KeyError(name)

    def serves(self, m: int, operand: str) -> bool:
        return operand in self.levels[m].serves

    def validate(self) -> None:
        assert self.levels[0].capacity_bytes is None, "level 0 must be DRAM"
        for ax in self.spatial:
            assert 0 <= ax.at_level < self.n_levels
        # Macro must serve weights and be single-buffer-only for them.
        assert WEIGHT in self.levels[self.macro_level].serves


# Operand precision in bits, per level. Outputs travel as 32-bit partial sums
# near the macro and as 8-bit requantized activations in the outer hierarchy
# (SIMD unit requantizes on GBuf write-back) — a documented simplification.
def operand_bits(arch: CimArch, m: int, operand: str) -> int:
    if operand == OUTPUT:
        return 32 if m >= 2 else 8
    return 8


def default_arch(
    *,
    n_cores: int = 8,
    macro_rows: int = 128,
    macro_cols: int = 32,
    gbuf_kb: float = 8.0,
    lbuf_kb: float = 256.0,
    reg_bytes: int = 2048,
    gbuf_bus_bits: int = 256,
    lbuf_bus_bits: int = 128,
    dram_bus_bits: int = 64,
    double_buffered: bool = True,
    name: str = "miredo-tab4",
) -> CimArch:
    """The paper's Table IV configuration (defaults) with sweepable knobs.

    ``double_buffered=False`` is the single-buffer-only policy point of the
    co-design space (`core/dse.py`): no on-chip level may double-buffer, so
    every transfer serializes with compute (psi^DM forced to 0)."""
    levels = (
        MemLevel("DRAM", None, dram_bus_bits, OPERANDS, shared=True,
                 bypassable=False, double_bufferable=False,
                 access_energy_pj_per_byte=160.0),
        MemLevel("GBuf", int(gbuf_kb * 1024), gbuf_bus_bits, OPERANDS,
                 shared=True, bypassable=True,
                 double_bufferable=double_buffered,
                 access_energy_pj_per_byte=6.0),
        MemLevel("LBuf", int(lbuf_kb * 1024), lbuf_bus_bits, OPERANDS,
                 shared=True, bypassable=True,
                 double_bufferable=double_buffered,
                 access_energy_pj_per_byte=2.0),
        MemLevel("Reg", reg_bytes, lbuf_bus_bits, OPERANDS, shared=False,
                 bypassable=True, double_bufferable=double_buffered,
                 access_energy_pj_per_byte=0.6),
        MemLevel("Macro", macro_rows * macro_cols, lbuf_bus_bits, (WEIGHT,),
                 shared=False, bypassable=False, double_bufferable=False,
                 access_energy_pj_per_byte=0.3),
    )
    spatial = (
        # Partition output channels / output pixels across cores: no
        # cross-core psum reduction needed (SIMD accumulates within core).
        SpatialAxis("core", n_cores, ("K", "OY", "OX", "N"), at_level=2,
                    replicates_from=2),
        # Macro wordlines carry the flattened input-channel x filter window;
        # bitlines carry output channels (Fig. 1(c) orientation).
        SpatialAxis("wordline", macro_rows, ("C", "FY", "FX"), at_level=4,
                    replicates_from=None),
        SpatialAxis("bitline", macro_cols, ("K",), at_level=4,
                    replicates_from=None),
    )
    arch = CimArch(levels=levels, spatial=spatial, macro_rows=macro_rows,
                   macro_cols=macro_cols, name=name)
    arch.validate()
    return arch


def sweep_arch(**kw) -> CimArch:
    """Convenience for Fig. 5(b–d) hardware sweeps."""
    return default_arch(**kw)


def max_spatial_macs(arch: CimArch) -> int:
    """Peak MACs per cycle-group: product of all spatial axis sizes."""
    return math.prod(ax.size for ax in arch.spatial)


# ---------------------------------------------------------------------------
# Multi-chip mesh vocabulary (DESIGN.md §Mesh optimization)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshLink:
    """One inter-chip link of a CIM chip mesh (`core/mesh.py`).

    The link is the mesh-level analogue of a `MemLevel` bus: a bandwidth in
    bits per cycle, a fixed per-hop router/SerDes latency, and a per-byte
    transfer energy (chip-to-chip SerDes energy dwarfs on-chip SRAM access
    — the NoC dataflow literature's constant, arXiv:2111.11744). All three
    are solver-relevant and therefore part of the structural mesh
    fingerprint (`mesh.mesh_fingerprint` — the cache-key contract).

    Attributes:
      bandwidth_bits: bits per cycle per directed link.
      hop_latency_cycles: fixed per-hop latency (router traversal).
      energy_pj_per_byte: per-byte, per-hop transfer energy.
    """

    bandwidth_bits: int = 256
    hop_latency_cycles: int = 4
    energy_pj_per_byte: float = 10.0

    def bytes_per_cycle(self) -> float:
        return self.bandwidth_bits / 8.0

    def validate(self) -> None:
        assert self.bandwidth_bits >= 8, self.bandwidth_bits
        assert self.hop_latency_cycles >= 0, self.hop_latency_cycles


# ---------------------------------------------------------------------------
# Co-design support: area proxy + structural serde (DESIGN.md §Co-design DSE)
# ---------------------------------------------------------------------------

#: Bits per CIM crossbar cell (INT8 weights, one weight per cell column
#: group — the paper's precision setup).
CELL_BITS = 8


def n_macros(arch: CimArch) -> int:
    """Number of physical CIM macro arrays: product of the spatial axes that
    replicate the macro level (``replicates_from`` at or above it). Wordline/
    bitline lanes live *inside* one macro and do not multiply the count."""
    return math.prod(
        ax.size for ax in arch.spatial
        if ax.replicates_from is not None
        and ax.replicates_from <= arch.macro_level)


def area_proxy(arch: CimArch) -> int:
    """Silicon-cost proxy for the Pareto frontier (`core/dse.py`):
    macros x crossbar bits = n_macros x macro_rows x macro_cols x CELL_BITS.

    CIM die area is dominated by the macro arrays (cell mats + per-bitline
    ADCs scale with rows x cols x macro count); SRAM buffer capacity is
    deliberately *not* counted, so along the buffer-capacity knobs the DSE
    answers "how much buffer does this macro budget need" rather than
    trading buffers against macros — a documented simplification."""
    return n_macros(arch) * arch.macro_rows * arch.macro_cols * CELL_BITS


def core_axis(arch: CimArch) -> SpatialAxis | None:
    """The spatial axis whose lanes replicate the macro level — the unit the
    network scheduler (`core/scheduler.py`) allocates between pipeline
    stages. ``None`` when no axis replicates per-lane macros (a single-macro
    chip: nothing to partition)."""
    for ax in arch.spatial:
        if ax.replicates_from is not None and \
                ax.replicates_from <= arch.macro_level:
            return ax
    return None


def with_cores(arch: CimArch, n: int) -> CimArch:
    """Structural variant of ``arch`` with the core axis resized to ``n``
    lanes (buffers, macro geometry and all other axes unchanged). Used by
    the scheduler's core-scaling probes: how much slower does a layer get
    on a ``n``-core slice of the chip?"""
    ax = core_axis(arch)
    assert ax is not None and n >= 1, (ax, n)
    spatial = tuple(
        dataclasses.replace(a, size=n) if a.name == ax.name else a
        for a in arch.spatial)
    return dataclasses.replace(arch, spatial=spatial,
                               name=f"{arch.name}-c{n}")


def arch_fingerprint(arch: CimArch) -> str:
    """Canonical *structural* serialization for cache keys (`core/cache.py`
    digests this). Covers every field that can change a solve result:
    per-level capacity/bus/serves/shared/bypassable/double-bufferable and
    access energy, spatial axes, macro geometry and timing/energy constants.
    Excludes ``name`` (two structurally identical archs must share cache
    entries — the DSE grid generates archs by knobs, not by name) and
    ``freq_ghz`` (cycles and pJ are frequency-independent)."""
    parts = []
    for lv in arch.levels:
        parts.append(
            f"{lv.name}:{lv.capacity_bytes}:{lv.bus_bits}:"
            f"{','.join(lv.serves)}:{int(lv.shared)}:{int(lv.bypassable)}:"
            f"{int(lv.double_bufferable)}:{lv.access_energy_pj_per_byte!r}")
    for ax in arch.spatial:
        parts.append(f"{ax.name}:{ax.size}:{','.join(ax.dims)}:"
                     f"{ax.at_level}:{ax.replicates_from}")
    parts.append(f"{arch.macro_rows}x{arch.macro_cols}:{arch.l_mvm_cycles}:"
                 f"{arch.mode_switch_cycles}:{arch.mac_energy_pj!r}")
    return "|".join(parts)
