"""Network-level dataflow optimization pipeline (DESIGN.md §Network pipeline).

The paper's headline numbers (Fig. 5a) are *network*-level: a per-layer MIP
solved for every layer of a whole model. Doing that serially with a flat
wall-clock cap per layer wastes most of the time — ResNet repeats blocks,
transformers repeat the same handful of GEMMs per layer, and big layers
burn the full cap while tiny ones solve in milliseconds. This module:

  1. **dedups** structurally identical layers (same loop bounds + stride;
     ``cache.layer_cache_key``) — one solve covers every repeat, each
     instance re-scored from the shared mapping;
  2. allocates one **global wall-clock budget** across the unique layers
     still to be solved, weighted by MAC count (big layers dominate network
     latency, so they get the solver time) with a per-layer floor and cap;
  3. fans the solves out over a ``concurrent.futures.ProcessPoolExecutor``
     (HiGHS holds the GIL — processes, not threads);
  4. reads/writes the shared on-disk ``ResultCache`` so reruns are
     incremental.

Every MIP solve is warm-started with the greedy/heuristic incumbent inside
``optimize_layer`` (upper-bound row + fallback), so a time-capped solve
always yields a feasible mapping — the pipeline never returns ``None``.

``NetworkResult.totals`` is deliberately the **serial sum**: every layer
instance owns all cores and pays a full macro weight program-in at its
boundary. The pipelined end-to-end number — weight-resident segments,
layer-to-core allocation, reload paid once per segment — is the network
scheduler's (`core/scheduler.py`, DESIGN.md §Network scheduler) and is
surfaced as ``NetworkResult.scheduled``.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import multiprocessing
import os
import time
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import CimArch
from repro.core.cache import (MIP_MODES, ResultCache, layer_cache_key,
                              mapping_from_json, solve_layer,
                              solve_record_key)
#: Default global budget = fraction × (per-layer cap × unique layers to
#: solve). The serial seed spent the full cap on every layer; MAC-weighted
#: splitting preserves solution quality at roughly half the total time
#: because the cap is mostly burned by layers the solver cannot improve
#: within it anyway (see DESIGN.md §Network pipeline).
DEFAULT_BUDGET_FRACTION = 0.5
#: Minimum per-layer solver budget (seconds) when the global budget allows.
MIN_SOLVE_S = 5.0


# ---------------------------------------------------------------------------
# Dedup + budget allocation
# ---------------------------------------------------------------------------

def dedup_layers(layers: Sequence[wl.Layer]) -> tuple[list[wl.Layer],
                                                      list[str]]:
    """Return (unique layers in first-seen order, structural key per input
    layer). Two layers are identical iff all loop bounds and the stride
    match — names are ignored."""
    unique: list[wl.Layer] = []
    seen: dict[str, int] = {}
    keys: list[str] = []
    for layer in layers:
        k = layer_cache_key(layer)
        keys.append(k)
        if k not in seen:
            seen[k] = len(unique)
            unique.append(layer)
    return unique, keys


def allocate_budgets(layers: Sequence[wl.Layer], total_s: float,
                     min_s: float = MIN_SOLVE_S,
                     max_s: float | None = None) -> list[float]:
    """Split ``total_s`` seconds across layers proportionally to MACs,
    clamped to [min_s, max_s]; clamp slack is redistributed to the
    remaining layers so the budgets always sum to ``total_s`` (up to the
    hard bounds n*min_s / n*max_s).

    The sum-to-total contract is only as good as the solver's respect for
    each allocation: `formulation.solve_ladder` charges every fallback
    rung — and `portfolio.race` every racing member — against ONE deadline
    anchored at the solve's start, so a layer's wall clock stays within
    its allocated seconds (+ scheduling epsilon) no matter how many rungs
    or members run. (The pre-v8 ladder re-floored each rung at
    ``min(5, time_limit_s)`` and could overshoot a 5 s budget 3×.)"""
    n = len(layers)
    if n == 0:
        return []
    total_s = float(total_s)
    if total_s <= n * min_s:
        return [total_s / n] * n
    if max_s is not None and total_s >= n * max_s:
        return [float(max_s)] * n
    w = [float(max(1, l.macs)) for l in layers]
    fixed: dict[int, float] = {}
    while True:
        free = [i for i in range(n) if i not in fixed]
        rem = total_s - sum(fixed.values())
        if not free:
            return [fixed[i] for i in range(n)]
        if rem <= min_s * len(free):
            # floors no longer affordable: split what's left evenly
            share = rem / len(free)
            return [fixed.get(i, share) for i in range(n)]
        sw = sum(w[i] for i in free)
        alloc = {i: rem * w[i] / sw for i in free}
        # cap overweight layers first and re-spread their excess; only when
        # no caps bind do floors get applied — flooring too early would
        # strand the capped layers' excess instead of redistributing it
        over = [i for i in free
                if max_s is not None and alloc[i] > max_s]
        if over:
            for i in over:
                fixed[i] = max_s
            continue
        under = [i for i in free if alloc[i] < min_s]
        if under:
            for i in under:
                fixed[i] = min_s
            continue
        return [fixed[i] if i in fixed else alloc[i] for i in range(n)]


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LayerResult:
    layer: wl.Layer
    count: int                  # multiplicity of this instance in the net
    key: str                    # structural dedup/cache key
    record: dict                # solve record, re-scored for this instance

    @property
    def cycles(self) -> float:
        return self.record["cycles"]

    @property
    def energy_pj(self) -> float:
        return self.record["energy_pj"]

    @property
    def edp(self) -> float:
        return self.record["edp"]


@dataclasses.dataclass
class NetworkResult:
    mode: str
    arch_name: str
    layers: list[LayerResult]   # one per input layer, input order
    n_unique: int
    n_solved: int               # unique layers actually solved (cache misses)
    cache_hits: int
    budgets: dict[str, float]   # structural key -> allocated seconds
    wall_s: float
    totals: dict[str, float]    # serial-sum aggregates (see _aggregate)
    #: Multi-core schedule totals (`core/scheduler.py`): end-to-end cycles
    #: with weight-resident segments and core-partitioned pipelining —
    #: keys: cycles, serial_cycles, saved_cycles, n_segments, n_packed,
    #: energy_delta_pj, energy_pj (the executed mappings': serial records
    #: plus any pipelined greedy-basis swap deltas) and edp (energy x
    #: scheduled cycles). ``None`` when scheduling was disabled.
    scheduled: dict[str, float] | None = None
    #: The full `scheduler.Schedule` behind ``scheduled`` (segments, core
    #: allocations, per-stage latencies), for reporting and cross-checks.
    schedule: object | None = None

    @property
    def scheduled_cycles(self) -> float:
        """End-to-end latency: the multi-core schedule's cycles when
        scheduling ran, the serial sum otherwise."""
        return float((self.scheduled or self.totals)["cycles"])

    def record_of(self, name: str) -> dict:
        for lr in self.layers:
            if lr.layer.name == name:
                return lr.record
        raise KeyError(name)


def _aggregate(layers: list[LayerResult]) -> dict[str, float]:
    """Serial-sum aggregates: every layer instance owns all cores
    exclusively and pays its own weight program-in, so ``cycles`` is an
    upper bound on end-to-end latency, not the pipelined number — that is
    ``NetworkResult.scheduled`` (`core/scheduler.py`, DESIGN.md §Network
    scheduler). ``edp`` sums per-layer EDPs (the paper's Fig. 5 metric)."""
    tot = {"cycles": 0.0, "energy_pj": 0.0, "edp": 0.0, "macs": 0.0}
    for lr in layers:
        tot["cycles"] += lr.cycles * lr.count
        tot["energy_pj"] += lr.energy_pj * lr.count
        tot["edp"] += lr.edp * lr.count
        tot["macs"] += lr.layer.macs * lr.count
    return tot


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------

def _solve_job(args):
    """Process-pool entry point (top-level: must be picklable)."""
    layer, arch, mode, cfg, *rest = args
    ws = rest[0] if len(rest) > 0 else None
    pf = rest[1] if len(rest) > 1 else None
    return solve_layer(layer, arch, mode, cfg, warm_start=ws, portfolio=pf)


def optimize_network(layers: Sequence[wl.Layer], arch: CimArch | None = None,
                     mode: str = "miredo", *,
                     mesh=None,
                     counts: Sequence[int] | None = None,
                     cfg=None,
                     total_budget_s: float | None = None,
                     per_layer_cap_s: float = 60.0,
                     workers: int | None = None,
                     cache: ResultCache | None = None,
                     use_cache: bool = True,
                     schedule: bool = True,
                     schedule_boundaries: Sequence[int] | None = None,
                     warm_starts: dict[str, dict] | None = None,
                     portfolio=None,
                     verbose: bool = False) -> NetworkResult:
    """Optimize every layer of a network and aggregate latency/energy/EDP.

    ``mesh`` (a `mesh.MeshArch`, mutually exclusive with ``arch``) targets
    a multi-chip mesh: ``n_chips > 1`` dispatches to
    `mesh.optimize_mesh_network` (per-layer TP sharding + the (chip, core)
    placement scheduler); a **1-chip mesh IS its chip** — the call
    continues below on ``mesh.chip``, taking the single-chip path bit for
    bit (the invariant `tests/test_mesh.py` pins).

    ``warm_starts`` maps `layer_cache_key` -> mapping JSON; for MIP modes
    each matching unique layer's solve receives that mapping as an extra
    incumbent (re-validated against this arch — see
    `formulation.optimize_layer`). Warm-started solves cache under keys
    carrying a warm-start digest, so they never alias cold records.
    Baseline modes ignore warm starts entirely.

    ``portfolio`` (a `portfolio.Portfolio`) replaces each MIP-mode layer
    solve with a race of the portfolio's members inside the layer's
    allocated budget (`core/portfolio.py`); the portfolio digest joins the
    cache key so raced records never alias single-solve records. Baseline
    modes ignore it.

    ``counts`` gives per-input-layer multiplicity (e.g. ResNet block repeat
    counts, transformer depth); identical layers dedup to one solve either
    way. ``total_budget_s`` is the global solver wall-clock budget for MIP
    modes, split across the *unique* layers by MACs; it defaults to
    ``DEFAULT_BUDGET_FRACTION * per_layer_cap_s * n_unique``. The split is
    over all unique layers (not just cache misses) so a rerun re-derives
    identical per-layer budgets and hence identical cache keys. Baseline
    modes (heuristic/greedy/random) are cheap and ignore the budget.

    ``totals`` is the *serial sum* over instances (every layer alone on the
    chip, weight reload at every boundary); with ``schedule=True`` (default)
    the multi-core scheduler additionally packs weight-resident segments
    and pipelines them (`core/scheduler.py`), filling ``result.scheduled``
    (end-to-end cycles, never worse than ``totals['cycles']``) and
    ``result.schedule``. Callers pooling several *independent* workloads
    into one call (e.g. `benchmarks/lm_models.py`) must pass
    ``schedule_boundaries`` — the start index of each sub-stream — so no
    segment pipelines across unrelated networks.
    """
    from repro.core.energy import evaluate_edp
    from repro.core.formulation import FormulationConfig

    if mesh is not None:
        assert arch is None, "pass either arch or mesh, not both"
        if mesh.n_chips > 1:
            from repro.core.mesh import optimize_mesh_network
            return optimize_mesh_network(
                layers, mesh, mode, counts=counts, cfg=cfg,
                total_budget_s=total_budget_s,
                per_layer_cap_s=per_layer_cap_s, workers=workers,
                cache=cache, use_cache=use_cache, schedule=schedule,
                schedule_boundaries=schedule_boundaries,
                warm_starts=warm_starts, portfolio=portfolio,
                verbose=verbose)
        arch = mesh.chip
    assert arch is not None, "either arch or mesh is required"

    t0 = time.monotonic()
    layers = list(layers)
    counts = [1] * len(layers) if counts is None else list(counts)
    assert len(counts) == len(layers)
    base_cfg = cfg or FormulationConfig(time_limit_s=per_layer_cap_s)
    cache = cache if cache is not None else (
        ResultCache() if use_cache else None)

    unique, keys = dedup_layers(layers)
    is_mip = mode in MIP_MODES

    # Resolve cache hits before budgeting: only real solves get solver time.
    records: dict[str, dict] = {}
    cfg_of: dict[str, object] = {}
    ws_of: dict[str, dict | None] = {}
    to_solve: list[wl.Layer] = []
    if not is_mip:
        # budget-independent: cache key uses the base config as-is
        for ul in unique:
            k = layer_cache_key(ul)
            cfg_of[k] = base_cfg
            rec = cache.get(solve_record_key(mode, ul, arch, base_cfg)) \
                if cache else None
            if rec is not None:
                records[k] = rec
            else:
                to_solve.append(ul)
        budgets = {layer_cache_key(ul): 0.0 for ul in to_solve}
    else:
        # Budgets are allocated over ALL unique layers — not just cache
        # misses — so a rerun with the same inputs re-derives the same
        # per-layer budgets and hence the same cache keys.
        if total_budget_s is None:
            total_budget_s = (DEFAULT_BUDGET_FRACTION * per_layer_cap_s *
                              len(unique))
        alloc = allocate_budgets(
            unique, total_budget_s,
            min_s=min(MIN_SOLVE_S, per_layer_cap_s),
            max_s=per_layer_cap_s)
        budgets = {}
        for ul, b in zip(unique, alloc):
            k = layer_cache_key(ul)
            c = dataclasses.replace(base_cfg, time_limit_s=b)
            cfg_of[k] = c
            ws = warm_starts.get(k) if warm_starts else None
            ws_of[k] = ws
            rec = cache.get(solve_record_key(mode, ul, arch, c,
                                             warm_start=ws,
                                             portfolio=portfolio)) \
                if cache else None
            if rec is not None:
                records[k] = rec
            else:
                to_solve.append(ul)
                budgets[k] = b

    cache_hits = len(unique) - len(to_solve)

    # Fan out the remaining solves; longest budgets first for packing.
    if to_solve:
        nw = workers or os.cpu_count() or 1
        order = sorted(
            to_solve,
            key=lambda l: -budgets.get(layer_cache_key(l), l.macs))
        jobs = [(l, arch, mode, cfg_of[layer_cache_key(l)],
                 ws_of.get(layer_cache_key(l)),
                 portfolio if is_mip else None) for l in order]
        if nw > 1 and len(jobs) > 1:
            # spawn, not fork: the batched analytical model runs jax in the
            # parent, and forking a multithreaded jax process deadlocks the
            # children (os.fork() + jax's internal threads).
            with concurrent.futures.ProcessPoolExecutor(
                    max_workers=nw,
                    mp_context=multiprocessing.get_context("spawn")) as ex:
                out = list(ex.map(_solve_job, jobs))
        else:
            out = [_solve_job(j) for j in jobs]
        for l, rec in zip(order, out):
            k = layer_cache_key(l)
            records[k] = rec
            if cache is not None:
                cache.put(solve_record_key(mode, l, arch, cfg_of[k],
                                           warm_start=ws_of.get(k),
                                           portfolio=portfolio), rec)
            if verbose:
                print(f"[network/{mode}] {l.name}: {rec['status']} "
                      f"{rec['cycles']:.3g} cyc in {rec['solve_s']}s")

    # Re-score the shared mapping for every instance (identical structure =>
    # identical numbers, but the record carries the instance's own name and
    # the evaluation proves the mapping is valid for it).
    out_layers: list[LayerResult] = []
    for layer, count, k in zip(layers, counts, keys):
        rec = dict(records[k])
        mapping = mapping_from_json(rec["mapping"])
        edp = evaluate_edp(mapping, layer, arch)
        rec.update({
            "layer": layer.name,
            "cycles": edp.latency.total_cycles,
            "energy_pj": edp.energy.total_pj,
            "edp": edp.edp,
            "spatial_util": edp.latency.spatial_util,
            "temporal_util": edp.latency.temporal_util,
        })
        out_layers.append(LayerResult(layer=layer, count=count, key=k,
                                      record=rec))

    totals = _aggregate(out_layers)
    scheduled = sched = None
    if schedule:
        from repro.core.scheduler import schedule_network
        sched = schedule_network(out_layers, arch,
                                 boundaries=schedule_boundaries,
                                 verbose=verbose)
        scheduled = sched.totals()
        # energy of the mappings actually executed: the serial records'
        # energy plus the delta of any pipelined greedy-basis swaps
        # (zero when no swap engages — see scheduler.py guarantees)
        scheduled["energy_pj"] = totals["energy_pj"] + \
            sched.energy_delta_pj
        scheduled["edp"] = scheduled["energy_pj"] * sched.scheduled_cycles

    return NetworkResult(
        mode=mode, arch_name=arch.name, layers=out_layers,
        n_unique=len(unique), n_solved=len(to_solve),
        cache_hits=cache_hits, budgets=budgets,
        wall_s=round(time.monotonic() - t0, 2),
        totals=totals, scheduled=scheduled, schedule=sched)


def optimize_over_archs(layers: Sequence[wl.Layer],
                        archs: Sequence[CimArch],
                        mode: str = "miredo", *,
                        counts: Sequence[int] | None = None,
                        cache: ResultCache | None = None,
                        use_cache: bool = True,
                        incremental: bool = False,
                        verbose: bool = False,
                        **net_kwargs) -> dict[str, NetworkResult]:
    """Batch-over-archs entry point (the co-design DSE's full-fidelity pass,
    `core/dse.py`): run ``optimize_network`` for the same workload under
    every architecture, sharing ONE ``ResultCache`` across all of them.

    Cache keys are arch-aware (`cache.arch_cache_key` digests the structural
    `arch.arch_fingerprint`), so per-arch records never collide, reruns of a
    sweep are incremental, and a grid point that equals a previously solved
    arch — under any name — is free. Returns ``{arch.name: NetworkResult}``
    in input order; arch names must be unique.

    ``incremental=True`` (MIP modes only) threads *neighbor warm starts*
    along the sweep: each arch's solved per-layer mappings become extra
    incumbents for the next arch's solves (re-validated there — adjacent
    grid points usually share near-optimal dataflows, so the MIP starts
    from a tight UB). This changes solver inputs, so results may differ
    from independent cold solves and records cache under warm-start-
    digested keys; leave it off (the default) when byte-reproducible
    cold-solve output matters."""
    archs = list(archs)
    names = [a.name for a in archs]
    assert len(set(names)) == len(names), f"duplicate arch names: {names}"
    cache = cache if cache is not None else (
        ResultCache() if use_cache else None)
    out: dict[str, NetworkResult] = {}
    warm: dict[str, dict] | None = None
    for arch in archs:
        if verbose:
            print(f"[over-archs/{mode}] {arch.name}", flush=True)
        res = optimize_network(
            layers, arch, mode, counts=counts, cache=cache,
            use_cache=use_cache, warm_starts=warm, verbose=verbose,
            **net_kwargs)
        out[arch.name] = res
        if incremental and mode in MIP_MODES:
            warm = {lr.key: lr.record["mapping"] for lr in res.layers}
    return out
