"""MIREDO -> TPU bridge: the paper's MIP machinery re-instantiated over the
TPU memory hierarchy (HBM -> VMEM -> MXU) to select Pallas kernel block
shapes (DESIGN.md §TPU bridge).

The CIM concepts map one-to-one:
  * eq. (9)  capacity with (1 + psi^DM):  Pallas pipelining double-buffers
    every operand block in VMEM -> working set counts twice when the
    transfer/compute overlap is enabled;
  * Table III single vs double rows:  per-grid-step time is
    max(T_transfer, T_compute) when pipelined, T_transfer + T_compute when
    not;
  * C^X spatial legality:  MXU tiling — lane dim multiples of 128, sublane
    multiples of 8;
  * weight-reload mode-switch stall:  the weight block changes every grid
    step along the reduction axis; re-fetch traffic is modeled in the HBM
    term exactly like MIREDO models macro reloads.

The resulting MIP is tiny (tens of binaries) and solves in milliseconds —
it is deliberately *not* routed through the network pipeline or its solve
cache (those key on `workload.Layer` x `CimArch`; a block-shape pick is
neither). Call paths today: ``select_matmul_blocks`` feeds
kernels/matmul_int8 (ops zero-pad operands when a padded block comes
back), ``select_flash_blocks`` feeds kernels/flash_attention, and
``benchmarks/tpu_bridge_bench.py`` sweeps both for the report.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.mip.model import LinExpr, MipModel, Status

# TPU v5e per-core budgets
VMEM_BYTES = 64 * 1024 * 1024      # usable VMEM budget (conservative half)
HBM_BW = 819e9
MXU_FLOPS = 197e12                 # bf16; int8 ~2x but stay conservative
LANE = 128
SUBLANE = 8


@dataclasses.dataclass
class BlockChoice:
    bm: int
    bk: int
    bn: int
    double_buffered: bool
    est_seconds: float
    vmem_bytes: int
    status: str


def _round_up(x: int, align: int) -> int:
    return -(-x // align) * align


def _candidates(dim: int, *, align: int, cap: int) -> list[int]:
    """MXU-legal block-size candidates for one dim: aligned divisors of the
    dim when any exist, else the dim padded up to alignment (clamped to an
    aligned cap). Every returned candidate is a multiple of ``align`` — an
    unaligned block shape is illegal for the MXU regardless of fit."""
    out = [c for c in (128, 256, 512, 1024, 2048)
           if c <= min(dim, cap) and dim % c == 0 and c % align == 0]
    if not out and dim % align == 0 and align <= dim <= cap:
        out = [dim]                       # aligned dim smaller than 128
    if not out:
        # no aligned divisor exists: offer every aligned size up to the dim
        # padded to alignment (clamped to an aligned cap) — callers
        # (kernels/matmul_int8/ops.py) zero-pad the array to the block
        padded = min(_round_up(dim, align), max(align, cap - cap % align))
        out = [c for c in (128, 256, 512, 1024, 2048)
               if c % align == 0 and c <= padded]
        if padded not in out:
            out.append(padded)
    return out


def select_matmul_blocks(m: int, k: int, n: int, *,
                         bytes_in: int = 1, bytes_acc: int = 4,
                         vmem_bytes: int = VMEM_BYTES,
                         time_limit_s: float = 5.0) -> BlockChoice:
    """MIP block-shape selection for the INT8 matmul kernel.

    min  T              (per-step latency bound, eq. 14 latency term)
    s.t. T >= t_hbm     (+ t_mxu when single-buffered: Table III row select)
         T >= t_mxu
         (1 + psi^DM) * working_set(bm, bk, bn) <= VMEM     (eq. 9)
    """
    cm = _candidates(m, align=SUBLANE, cap=2048)
    ck = _candidates(k, align=LANE, cap=2048)
    cn = _candidates(n, align=LANE, cap=2048)
    mdl = MipModel("tpu-matmul-blocks")
    vm = mdl.add_one_hot("bm", len(cm))
    vk = mdl.add_one_hot("bk", len(ck))
    vn = mdl.add_one_hot("bn", len(cn))
    dm = mdl.add_binary("psiDM")

    # HBM traffic (bytes): x re-read N/bn times, w re-read M/bm times,
    # out written once — the weight-reload analogue.
    traffic = LinExpr({}, float(m * n * bytes_acc))
    for c, v in zip(cn, vn):
        traffic = traffic + (m * k * bytes_in) * math.ceil(n / c) * v
    for c, v in zip(cm, vm):
        traffic = traffic + (k * n * bytes_in) * math.ceil(m / c) * v
    t_hbm_scale = 1.0 / HBM_BW
    t_mxu = 2.0 * m * n * k / MXU_FLOPS

    # working set: bm*bk + bk*bn + bm*bn*acc (+ scales, negligible)
    # pairwise products of one-hots -> enumerate (tiny sets)
    ws = mdl.add_var("ws", 0.0, float(vmem_bytes) * 4)
    for i, cmi in enumerate(cm):
        for j, ckj in enumerate(ck):
            for l2, cnl in enumerate(cn):
                w = cmi * ckj * bytes_in + ckj * cnl * bytes_in + \
                    cmi * cnl * bytes_acc
                big = float(vmem_bytes * 8)
                mdl.add_ge(ws - w + big * (3 - vm[i] - vk[j] - vn[l2]),
                           0.0)
    # capacity: ws + psi^DM * ws <= vmem  ->  ws + dbx <= vmem
    dbx = mdl.add_var("dbx", 0.0, float(vmem_bytes) * 4)
    mdl.add_ge(dbx - ws + float(vmem_bytes * 8) * (1 - dm * 1.0), 0.0)
    mdl.add_le(ws + dbx, float(vmem_bytes))

    t = mdl.add_var("T", 0.0, 1e6)
    # double-buffered: T >= max(t_hbm, t_mxu); single: T >= t_hbm + t_mxu
    mdl.add_ge(t - t_hbm_scale * traffic, 0.0)
    mdl.add_ge(t, t_mxu)
    big_t = 1e3
    mdl.add_ge(t - t_hbm_scale * traffic - t_mxu - big_t * (dm * 1.0),
               -0.0)
    mdl.minimize(t)
    sol = mdl.solve(time_limit_s=time_limit_s, mip_rel_gap=1e-4)
    if not sol.ok:
        return BlockChoice(256, 512, 256, True, math.nan, -1, "fallback")
    pick = lambda cs, vs: cs[max(range(len(cs)), key=lambda i: sol[vs[i]])]
    bm_v, bk_v, bn_v = pick(cm, vm), pick(ck, vk), pick(cn, vn)
    ws_v = bm_v * bk_v * bytes_in + bk_v * bn_v * bytes_in + \
        bm_v * bn_v * bytes_acc
    return BlockChoice(bm_v, bk_v, bn_v, sol.binary(dm), sol[t], ws_v,
                       sol.status.name)


def _snap(hint: int, dim: int, *, align: int, cap: int = 2048) -> int:
    """Round a mapping tile extent up to MXU alignment and clamp it into
    [align, min(cap, dim padded to alignment)]."""
    padded = max(align, min(_round_up(dim, align), cap - cap % align))
    return max(align, min(_round_up(hint, align), padded))


def select_blocks_from_mapping(mapping, layer, arch, *,
                               bytes_in: int = 1, bytes_acc: int = 4,
                               vmem_bytes: int = VMEM_BYTES,
                               cap: int = 2048) -> BlockChoice:
    """Translate a solved MIREDO mapping into Pallas matmul block shapes.

    The measured-execution backend (`core/executor.py`) runs each optimized
    GEMM on kernels/matmul_int8; the block shapes come from the mapping the
    MIP actually chose rather than from a fresh bridge MIP: a dim's on-chip
    tile extent — spatial unrolls plus every temporal factor that *all*
    operands indexing the dim hold above DRAM — is the working set MIREDO
    decided to keep resident, i.e. the CIM analogue of the VMEM-resident
    Pallas block. Each extent is snapped to MXU alignment (lane 128 /
    sublane 8) and clamped to the padded dim; the working set is then
    halved-down until the double-buffered eq. 9 capacity holds. Callers
    zero-pad when a block does not divide the dim (kernels/matmul_int8/
    ops.py), exactly as for `select_matmul_blocks` picks.

    ``cap`` bounds every block dim; the measured-execution backend lowers
    it so each op spans several grid steps (per-step wall-clock is the
    measurement granularity — one giant block would time a single opaque
    step).
    """
    from repro.core import workload as wl

    m, k, n = layer.bound("N"), layer.bound("C"), layer.bound("K")
    hints = {d: 1 for d in ("N", "C", "K")}
    for ax in arch.spatial:
        for d, f in mapping.spatial.get(ax.name, ()):
            if d in hints:
                hints[d] *= f
    for i, (d, f) in enumerate(mapping.temporal):
        if d in hints and all(
                mapping.level_of[lam][i] >= 1
                for lam in mapping.level_of if wl.is_relevant(d, lam)):
            hints[d] *= f
    bm = _snap(hints["N"], m, align=SUBLANE, cap=cap)
    bk = _snap(hints["C"], k, align=LANE, cap=max(cap, LANE))
    bn = _snap(hints["K"], n, align=LANE, cap=max(cap, LANE))
    ws = lambda: bm * bk * bytes_in + bk * bn * bytes_in + bm * bn * bytes_acc
    while 2 * ws() > vmem_bytes:      # pipelined (double-buffered) eq. 9
        if bm >= max(bk, bn) and bm > SUBLANE:
            bm = max(SUBLANE, bm // 2 - bm // 2 % SUBLANE)
        elif bk >= bn and bk > LANE:
            bk = max(LANE, bk // 2 - bk // 2 % LANE)
        elif bn > LANE:
            bn = max(LANE, bn // 2 - bn // 2 % LANE)
        else:
            break
    return BlockChoice(bm, bk, bn, True, math.nan, ws(), "MAPPED")


def select_flash_blocks(seq_q: int, seq_k: int, head_dim: int, *,
                        bytes_el: int = 2,
                        vmem_bytes: int = VMEM_BYTES) -> tuple[int, int]:
    """Largest (block_q, block_k) whose pipelined working set fits VMEM —
    the degenerate (single-level) instance of eq. 9; closed-form, no solver
    needed, but uses the same accounting as select_matmul_blocks."""
    best = (128, 128)
    best_steps = math.inf
    for bq in (1024, 512, 256, 128):
        if seq_q % bq:
            continue
        for bk in (1024, 512, 256, 128):
            if seq_k % bk:
                continue
            ws = (bq * head_dim + 2 * bk * head_dim) * bytes_el + \
                bq * head_dim * 4 + bq * bk * 4
            if 2 * ws > vmem_bytes:     # double-buffered pipeline
                continue
            steps = (seq_q // bq) * (seq_k // bk)
            if steps < best_steps:
                best_steps, best = steps, (bq, bk)
    return best
