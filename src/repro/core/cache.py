"""Mapping (de)serialization + on-disk solve-record cache.

Promoted out of ``benchmarks/common.py`` so the network pipeline
(``core/network.py``), the benchmark scripts and the examples all share one
cache with one key schema (DESIGN.md §Network pipeline).

Cache keys cover the *complete* solve identity:

  * the layer structure (all loop bounds + stride — not the name, so
    structurally identical layers share entries; this same key is the
    network pipeline's dedup key),
  * the full architecture *structure* (hierarchy capacities/buses/serves/
    bypass/buffering flags, access energies, spatial axes, macro geometry,
    timing constants — but not the arch name; `arch.arch_fingerprint`),
  * every ``FormulationConfig`` field that can change the result (the seed's
    key omitted ``mu1``/``mu2_frac``/``latency_slack``/``mip_rel_gap``/
    ``combo_cap`` and silently served stale mappings when objective weights
    changed — hence ``CACHE_VERSION``).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

from repro.core import workload as wl
from repro.core.arch import CimArch, arch_fingerprint
from repro.core.mapping import Mapping

# v2: key covers all FormulationConfig fields.
# v3: arch key is structural (`arch.arch_fingerprint`): it now covers
#     per-level `bypassable` and access energies (the v2 key ignored both,
#     so archs differing only in energy constants shared stale records) and
#     drops the arch *name*, so the DSE grid's generated archs hit the same
#     entries as an identically-shaped hand-built arch.
# v4: records feed the network scheduler (`core/scheduler.py`), which
#     derives weight residency, per-instance weight footprints and resident
#     latency from the record's mapping + cycles. Those inputs are fully
#     determined by fields the structural key already covers (all loop
#     bounds + stride fix the weight tensor; the arch fingerprint fixes the
#     macro capacity and mode-switch cost — no new key fields needed), but
#     pre-scheduler v3 entries predate that contract, so the version bump
#     retires them wholesale rather than letting them serve records the
#     scheduler was never validated against.
# v5: the key space grows an optional warm-start digest component
#     (incremental DSE re-solves inject a neighboring arch's solved mapping
#     as an extra incumbent — `solve_record_key(..., warm_start=...)`), and
#     the cache is now routinely shared across runs on disk
#     (``--cache-dir`` / ``MIREDO_CACHE``). v4 records were written before
#     warm-started and cold solves could coexist, so the bump draws a clean
#     line: every v5 record states via its key whether a warm start shaped
#     it. Cold-solve keys are otherwise structurally identical to v4.
# v6: the arch position of the key accepts a `MeshArch` (`core/mesh.py`):
#     its fingerprint folds in every solver-relevant mesh field — chip
#     structure, chip count, topology, link bandwidth / hop latency / link
#     energy (the PR 1 lesson: a solver-relevant field missing from the key
#     serves stale records — two meshes differing only in link bandwidth
#     pick different shard choices). Mesh-level records additionally store
#     the shard decomposition, which v5 keys could never address, and
#     single-chip keys are unchanged except for the version prefix.
# v7: the layer key grows the written-resident-operand field
#     (`workload.Layer.weight_written`, the training frontend's wGrad
#     GEMMs). The scheduler's residency basis and dedup both key on the
#     structural layer key, and a wGrad layer whose bounds coincide with a
#     forward layer's must never share that layer's basis or record — its
#     stationary operand is produced per step, so residency packing and
#     fill amortization do not apply. Read-weight layer keys are unchanged
#     except for the version prefix.
# v8: two key-space changes land together. (a) The key space grows an
#     optional portfolio digest component (`core/portfolio.py` races K
#     solver parameterizations per layer — a different member grid is a
#     different solver, so `solve_record_key(..., portfolio=...)` appends
#     ``__pf<digest>`` for MIP modes). (b) ``latency_slack`` is
#     canonicalized to ``max(latency_slack, BIG_M_FLOOR)`` before keying:
#     the big-M row uses exactly that floor
#     (`formulation.solve_ladder`), so v7 keyed records that could never
#     differ (e.g. slack 2.0 vs 4.0) apart. v7 records also predate the
#     shared-deadline budget fix, so their ``solve_s`` no longer reflects
#     the budget contract — retired wholesale.
CACHE_VERSION = 8

#: Modes whose solves run the MIP (and therefore depend on every solver
#: field); baseline modes only consume the factorization knobs.
MIP_MODES = ("miredo", "ws")

# Config fields with no effect on the solve result (excluded from the key).
_CFG_KEY_EXCLUDE = ("verbose",)

# Solver-only fields, canonicalized out of baseline-mode keys: a heuristic
# record must hit the cache regardless of the MIP budget it ran beside.
_NON_MIP_CANONICAL = dict(time_limit_s=0.0, mu1=1.0, mu2_frac=0.0,
                          mip_rel_gap=0.0, combo_cap=0, latency_slack=0.0,
                          weight_stationary=False)


def default_cache_dir() -> str:
    return os.environ.get("MIREDO_CACHE", "reports/cache")


# ---------------------------------------------------------------------------
# Mapping (de)serialization
# ---------------------------------------------------------------------------

def mapping_to_json(m: Mapping) -> dict:
    return {
        "spatial": {k: list(map(list, v)) for k, v in m.spatial.items()},
        "temporal": list(map(list, m.temporal)),
        "level_of": {k: list(v) for k, v in m.level_of.items()},
        "double_buf": sorted(map(list, m.double_buf)),
    }


def mapping_from_json(d: dict) -> Mapping:
    return Mapping(
        spatial={k: tuple(tuple(x) for x in v)
                 for k, v in d["spatial"].items()},
        temporal=tuple(tuple(x) for x in d["temporal"]),
        level_of={k: tuple(v) for k, v in d["level_of"].items()},
        double_buf=frozenset((a, b) for a, b in d["double_buf"]))


# ---------------------------------------------------------------------------
# Key schema
# ---------------------------------------------------------------------------

def _digest(s: str) -> str:
    return hashlib.sha1(s.encode()).hexdigest()[:12]


def arch_cache_key(arch) -> str:
    """Structural arch key: digests ``arch.arch_fingerprint`` — the name is
    *not* part of the identity, so two archs differing only in LBuf capacity
    (or any other knob) get distinct keys while renamed-but-identical archs
    share entries (the DSE grid relies on both properties). A `MeshArch`
    (anything exposing ``fingerprint()``) keys on its own fingerprint, which
    embeds the chip fingerprint plus chip count, topology and all link
    fields — duck-typed here so `cache` need not import `mesh`."""
    fp = (arch_fingerprint(arch) if isinstance(arch, CimArch)
          else arch.fingerprint())
    return _digest(fp)


def layer_cache_key(layer: wl.Layer) -> str:
    """Structural key: loop bounds + stride + ``weight_written``, *not*
    the name — identical shapes share cache entries and dedup to one
    solve. The bounds also fix every scheduler-relevant derived quantity
    (the K*C*FY*FX weight footprint `scheduler.weight_bytes` packs
    against), so the scheduler introduces no additional key fields — only
    the v4 version bump. ``weight_written`` joined in v7: it flips the
    scheduler's residency basis (`scheduler.weight_residency`), so a
    wGrad layer must never alias a same-shaped forward layer."""
    dims = ",".join(f"{d}={layer.bound(d)}" for d in wl.DIMS)
    return _digest(f"{dims}|s{layer.stride}"
                   f"|wr{int(layer.weight_written)}")


def config_cache_key(cfg) -> str:
    """Key over every result-affecting FormulationConfig field.

    ``latency_slack`` is canonicalized to ``max(latency_slack,
    BIG_M_FLOOR)`` before keying: the solver applies exactly that floor to
    the big-M scale (`formulation.solve_ladder`), so every slack value at
    or below the floor produces the bit-identical solve — keying them
    apart would store duplicate records that can never differ (v8)."""
    from repro.core.formulation import BIG_M_FLOOR

    items = dataclasses.asdict(cfg)
    if "latency_slack" in items:
        items["latency_slack"] = max(items["latency_slack"], BIG_M_FLOOR)
    items = sorted(
        (k, v) for k, v in items.items() if k not in _CFG_KEY_EXCLUDE)
    return _digest("|".join(f"{k}={v!r}" for k, v in items))


def solve_record_key(mode: str, layer: wl.Layer, arch, cfg,
                     warm_start: dict | None = None,
                     portfolio=None) -> str:
    """``warm_start`` (a mapping JSON injected as a neighbor incumbent —
    incremental DSE re-solves) changes the solver's inputs, so warm-started
    records carry an extra digest component: they can never serve, or be
    served by, the structural key of an independent cold solve. Likewise
    ``portfolio`` (a `portfolio.Portfolio`): a different member grid is a
    different solver, so its digest joins the key — for MIP modes only,
    since baseline modes never run the MIP and must hit the same entry
    regardless of the portfolio racing beside them (v8)."""
    if mode not in MIP_MODES:
        cfg = dataclasses.replace(cfg, **_NON_MIP_CANONICAL)
    key = (f"v{CACHE_VERSION}__{mode}__{layer_cache_key(layer)}"
           f"__{arch_cache_key(arch)}__{config_cache_key(cfg)}")
    if warm_start is not None:
        key += "__ws" + _digest(json.dumps(warm_start, sort_keys=True))
    if portfolio is not None and mode in MIP_MODES:
        key += "__pf" + portfolio.digest()
    return key


# ---------------------------------------------------------------------------
# On-disk record cache
# ---------------------------------------------------------------------------

class ResultCache:
    """JSON record store keyed by ``solve_record_key``; one file per record."""

    def __init__(self, directory: str | None = None):
        self.directory = directory or default_cache_dir()

    def path(self, key: str) -> str:
        return os.path.join(self.directory, key + ".json")

    def get(self, key: str) -> dict | None:
        p = self.path(key)
        if not os.path.exists(p):
            return None
        try:
            with open(p) as f:
                return json.load(f)
        except (json.JSONDecodeError, OSError):
            return None          # partial write / corrupt entry: resolve

    def put(self, key: str, rec: dict) -> str:
        os.makedirs(self.directory, exist_ok=True)
        p = self.path(key)
        tmp = p + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(rec, f)
        os.replace(tmp, p)       # atomic vs concurrent workers
        return p


# ---------------------------------------------------------------------------
# Solving (uncached core + cached wrapper)
# ---------------------------------------------------------------------------

def solve_layer(layer: wl.Layer, arch: CimArch, mode: str,
                cfg=None, warm_start: dict | None = None,
                portfolio=None) -> dict:
    """One uncached solve. mode: 'miredo' | 'ws' | 'heuristic' | 'greedy' |
    'random'. Returns {mode, layer, mapping, cycles, energy_pj, edp,
    spatial_util, temporal_util, solve_s, status}; MIP-mode records
    additionally carry {incumbent_cycles, improved} (the native
    greedy/heuristic incumbent the MIP had to beat, and whether it did)
    and, when a portfolio raced, {portfolio: {winner, members: [...]}}.

    MIP modes always return a feasible mapping: ``optimize_layer`` seeds the
    solve with the greedy/heuristic incumbent (warm start) and falls back to
    it when the time-capped solver finds nothing better. ``warm_start`` (a
    mapping JSON, e.g. a neighboring arch's solved mapping during
    incremental DSE) adds one more incumbent to that pool for MIP modes;
    baseline modes ignore it. ``portfolio`` (a `portfolio.Portfolio`) races
    its members instead of the single-parameterization solve for MIP modes;
    baseline modes ignore it too.
    """
    from repro.core.baselines import greedy_mapping, heuristic_search
    from repro.core.energy import evaluate_edp
    from repro.core.formulation import FormulationConfig, optimize_layer

    cfg = cfg or FormulationConfig()
    ws = mapping_from_json(warm_start) if warm_start is not None else None
    t0 = time.monotonic()
    res = pf_out = None
    if mode in MIP_MODES:
        c = (dataclasses.replace(cfg, weight_stationary=True)
             if mode == "ws" else cfg)
        if portfolio is not None:
            from repro.core.portfolio import race
            pf_out = race(layer, arch, c, portfolio, warm_start=ws)
            res = pf_out.result
        else:
            res = optimize_layer(layer, arch, c, warm_start=ws)
        mapping, status = res.mapping, res.status.name
    elif mode == "heuristic":
        r = heuristic_search(layer, arch, budget=2000, seed=0,
                             accurate=False, k_min=cfg.k_min,
                             alpha=cfg.alpha)
        mapping, status = r.mapping, "HEURISTIC"
    elif mode == "random":
        r = heuristic_search(layer, arch, budget=2000, seed=0,
                             accurate=True, k_min=cfg.k_min, alpha=cfg.alpha)
        mapping, status = r.mapping, "RANDOM"
    elif mode == "greedy":
        mapping, status = greedy_mapping(layer, arch), "GREEDY"
    else:
        raise ValueError(mode)
    assert mapping is not None, (mode, layer.name)
    edp = evaluate_edp(mapping, layer, arch)
    rec = {
        "mode": mode,
        "layer": layer.name,
        "mapping": mapping_to_json(mapping),
        "cycles": edp.latency.total_cycles,
        "energy_pj": edp.energy.total_pj,
        "edp": edp.edp,
        "spatial_util": edp.latency.spatial_util,
        "temporal_util": edp.latency.temporal_util,
        "solve_s": round(time.monotonic() - t0, 1),
        "status": status,
    }
    if res is not None:                      # MIP modes: solver diagnostics
        rec["incumbent_cycles"] = res.incumbent_latency
        rec["improved"] = res.improved
    if pf_out is not None:
        rec["portfolio"] = pf_out.to_json()
    return rec


def solve_cached(layer: wl.Layer, arch: CimArch, mode: str,
                 cfg=None, budget_s: float = 60.0,
                 cache: ResultCache | None = None) -> dict:
    """Cached single-layer solve (the seed benchmark entry point, now
    library-level). Prefer ``network.optimize_network`` for whole models —
    it dedups, allocates budget and fans out across processes."""
    from repro.core.formulation import FormulationConfig

    cfg = cfg or FormulationConfig(time_limit_s=budget_s)
    cache = cache or ResultCache()
    key = solve_record_key(mode, layer, arch, cfg)
    rec = cache.get(key)
    if rec is not None:
        return rec
    rec = solve_layer(layer, arch, mode, cfg)
    cache.put(key, rec)
    return rec
