"""Request-level serving simulator: continuous batching over the MIP stack.

The rest of the repo scores ONE iteration (a prefill pass or a single
decode step) for one static configuration.  This module models *traffic*:
a stream of requests with mixed prompt/output lengths arrives over time
(`RequestStream`), a continuous-batching engine (`simulate_serving`)
interleaves whole-prompt prefills with single-token decode steps, and the
KV cache is a hard token capacity that gates admission and — under the
"optimistic" policy — triggers preemption/requeue.

The engine is a discrete-event simulator at *iteration* granularity: each
iteration batches `m` tokens (sum of prefill prompts + one token per
decoding sequence) and advances the clock by `cost.cycles(m)`, where the
cost model maps iteration token counts to end-to-end scheduled cycles of
the full model.  `NetworkCostModel` derives those cycles from the real
stack — `frontend.extract_workload` lowers a per-iteration
`ShapeSpec.serving_iteration` batch composition to its weight GEMMs
(M = m via `m_tokens`), and `network.optimize_network(schedule=True)`
charges the multi-core schedule's weight-resident-segment makespan
(DESIGN.md §Network scheduler) — so the serving numbers inherit the
segment packing and the item-stream pipelining for free.

Guarantees (enforced by `tests/test_serving.py`):

* token conservation — every admitted request's tokens are emitted
  exactly once, nobody starves;
* KV occupancy never exceeds ``kv_capacity_tokens``;
* the same seed produces a bit-identical event log;
* with the default "reserve" admission policy, the continuous-batching
  makespan is never worse than the serial one-request-at-a-time baseline
  (`serial_baseline`), and strictly better whenever two requests overlap
  — the serving-level analogue of the scheduler's `scheduled <= serial`
  gate.  This holds because the cost model is forced *monotone and
  subadditive* (`_SubadditiveClosure`): merging two iterations never
  costs more than running them back to back.
"""

from __future__ import annotations

import collections
import dataclasses
import random
from typing import Callable, Iterable, Sequence

__all__ = [
    "Request", "RequestStream", "ServeConfig", "ServeReport",
    "AffineCostModel", "NetworkCostModel", "simulate_serving",
    "serial_baseline", "ServeScenario", "arch_goodput", "percentile",
]


# --------------------------------------------------------------------------
# Requests and arrival streams
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request: arrives, prefills its prompt, decodes tokens.

    ``output_len`` counts generated tokens *including* the one produced by
    the prefill pass (every request emits >= 1 token)."""
    rid: int
    arrival_cycles: float
    prompt_len: int
    output_len: int

    def __post_init__(self) -> None:
        if self.prompt_len < 1 or self.output_len < 1:
            raise ValueError(f"request {self.rid}: prompt/output must be >=1")


@dataclasses.dataclass(frozen=True)
class RequestStream:
    """A deterministic, sorted request arrival sequence."""
    requests: tuple[Request, ...]
    source: str = "trace"

    def __post_init__(self) -> None:
        arr = [r.arrival_cycles for r in self.requests]
        if any(b < a for a, b in zip(arr, arr[1:])):
            raise ValueError("requests must be sorted by arrival time")

    @staticmethod
    def poisson(n: int, *, seed: int,
                mean_interarrival_cycles: float,
                prompt_lens: Sequence[int] = (8, 16, 32),
                output_lens: Sequence[int] = (4, 8, 16)) -> "RequestStream":
        """Poisson arrivals with prompt/output lengths drawn uniformly from
        the given choice sets.  Uses ``random.Random(seed)`` (stdlib, whose
        sequences are stable across versions) so the same seed is
        bit-identical everywhere."""
        rng = random.Random(seed)
        t = 0.0
        reqs = []
        for i in range(n):
            t += rng.expovariate(1.0 / float(mean_interarrival_cycles))
            reqs.append(Request(i, t, int(rng.choice(list(prompt_lens))),
                                int(rng.choice(list(output_lens)))))
        return RequestStream(tuple(reqs),
                             source=f"poisson(n={n},seed={seed})")

    @staticmethod
    def from_trace(trace: str | Iterable[tuple[float, int, int]]
                   ) -> "RequestStream":
        """Trace arrivals: a path to a whitespace/comma-separated file with
        ``arrival_cycles prompt_len output_len`` per line (``#`` comments),
        or an iterable of such triples."""
        if isinstance(trace, str):
            rows = []
            with open(trace) as fh:
                for line in fh:
                    line = line.split("#", 1)[0].strip().replace(",", " ")
                    if line:
                        a, p, o = line.split()
                        rows.append((float(a), int(p), int(o)))
            source = f"trace({trace})"
        else:
            rows = [(float(a), int(p), int(o)) for a, p, o in trace]
            source = f"trace(rows={len(rows)})"
        rows.sort(key=lambda r: r[0])
        reqs = tuple(Request(i, a, p, o) for i, (a, p, o) in enumerate(rows))
        return RequestStream(reqs, source=source)


# --------------------------------------------------------------------------
# Iteration cost models
# --------------------------------------------------------------------------

class _SubadditiveClosure:
    """Monotone + subadditive closure of a raw per-iteration cost.

    Given raw scheduled cycles at power-of-two anchor token counts, define

        env(m) = min over anchors a >= m of raw(a)        (monotone)
        f(m)   = min(env(m), min over 1<=j<m of f(j) + f(m-j))

    By induction f is non-decreasing and subadditive
    (``f(a+b) <= f(a) + f(b)``): a batched iteration is never charged more
    than the iterations it merged, which is what makes the continuous-
    batching makespan provably <= the serial baseline (both are charged
    through the same f).  The closure is exact at the anchors up to the
    envelope, and conservative in between."""

    def __init__(self, raw_at_anchor: Callable[[int], float], max_m: int):
        if max_m < 1:
            raise ValueError("max_m must be >= 1")
        anchors = []
        a = 1
        while a < max_m:
            anchors.append(a)
            a *= 2
        anchors.append(a)
        raw = [float(raw_at_anchor(a)) for a in anchors]
        # Monotone envelope over anchors: env at anchor i = min raw[i:].
        env = list(raw)
        for i in range(len(env) - 2, -1, -1):
            env[i] = min(env[i], env[i + 1])
        self._anchors = anchors
        self._env_at_anchor = env
        self._f = [0.0]  # f[0] = 0; extended lazily

    def _env(self, m: int) -> float:
        for a, e in zip(self._anchors, self._env_at_anchor):
            if a >= m:
                return e
        raise ValueError(f"m={m} beyond largest anchor {self._anchors[-1]}")

    def cycles(self, m: int) -> float:
        m = int(m)
        if m < 0:
            raise ValueError("m must be >= 0")
        f = self._f
        while len(f) <= m:
            i = len(f)
            best = self._env(i)
            for j in range(1, i // 2 + 1):
                best = min(best, f[j] + f[i - j])
            f.append(best)
        return f[m]


class AffineCostModel:
    """``cycles(m) = base + per_token * m`` (0 at m=0).

    Subadditive for ``base >= 0`` (strictly for ``base > 0``) and monotone
    for ``per_token >= 0`` — the fast, exactly-analyzable model the
    property/differential tests fuzz the engine with."""

    def __init__(self, base: float = 100.0, per_token: float = 10.0,
                 freq_ghz: float = 1.0):
        if base < 0 or per_token < 0:
            raise ValueError("base/per_token must be >= 0")
        self.base, self.per_token = float(base), float(per_token)
        self.freq_ghz = float(freq_ghz)

    def cycles(self, m: int) -> float:
        return 0.0 if m <= 0 else self.base + self.per_token * m

    def seconds(self, m: int) -> float:
        return self.cycles(m) / (self.freq_ghz * 1e9)


class NetworkCostModel:
    """Iteration cost from the real MIREDO stack.

    Each power-of-two anchor token count ``m`` is lowered through
    ``ShapeSpec.serving_iteration`` -> ``frontend.extract_workload`` ->
    ``network.optimize_network(schedule=True)`` and charged the multi-core
    *scheduled* cycles (weight-resident segments + item-stream makespan;
    serial sum when scheduling finds nothing to pack).  Arbitrary m is
    served through the monotone+subadditive closure over those anchors
    (`_SubadditiveClosure`), which keeps the batched-vs-serial guarantee
    while bounding the number of solves to O(log max_m)."""

    def __init__(self, cfg, arch, *, max_m: int = 1024,
                 context_len: int = 4096, mode: str = "greedy",
                 per_layer_cap_s: float = 2.0, use_cache: bool = False,
                 cache=None, workers: int = 1,
                 schedule_boundaries: bool = True, verbose: bool = False):
        from repro.core.frontend import extract_workload
        from repro.core.network import optimize_network
        from repro.configs.base import ShapeSpec

        self.cfg, self.arch = cfg, arch
        self.freq_ghz = float(getattr(arch, "freq_ghz", 1.0))
        self.n_solves = 0
        self.anchor_cycles: dict[int, float] = {}

        def raw(m: int) -> float:
            spec = ShapeSpec.serving_iteration((), m,
                                               context_len=context_len)
            work = extract_workload(cfg, spec)
            net = optimize_network(
                list(work.layers), arch, mode,
                counts=list(work.counts),
                per_layer_cap_s=per_layer_cap_s,
                workers=workers, cache=cache, use_cache=use_cache,
                schedule=True, verbose=verbose)
            self.n_solves += 1
            self.anchor_cycles[m] = float(net.scheduled_cycles)
            return self.anchor_cycles[m]

        self._closure = _SubadditiveClosure(raw, max_m)

    def cycles(self, m: int) -> float:
        return self._closure.cycles(m)

    def seconds(self, m: int) -> float:
        return self.cycles(m) / (self.freq_ghz * 1e9)


# --------------------------------------------------------------------------
# The continuous-batching engine
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine knobs.  ``admission``:

    * ``"reserve"`` (default): admit a request only when its *worst-case*
      KV need (prompt + output tokens) fits inside the unreserved
      capacity.  No preemption can ever be needed, so the batched-vs-
      serial guarantee holds.
    * ``"optimistic"``: admit as soon as the (re)prefill itself fits; when
      KV growth would overflow capacity, the latest-admitted participant
      is preempted — its KV freed, generated-so-far kept — and requeued at
      the *front* of the waiting queue (recompute-style requeue).

    SLO thresholds are in cycles (convert seconds via the arch's
    ``freq_ghz``); ``None`` disables that bound, so with no SLOs goodput
    equals throughput."""
    kv_capacity_tokens: int = 4096
    max_batch_requests: int = 64
    max_batch_tokens: int = 1024
    admission: str = "reserve"
    slo_ttft_cycles: float | None = None
    slo_itl_cycles: float | None = None

    def __post_init__(self) -> None:
        if self.admission not in ("reserve", "optimistic"):
            raise ValueError(f"unknown admission policy {self.admission!r}")
        if min(self.kv_capacity_tokens, self.max_batch_requests,
               self.max_batch_tokens) < 1:
            raise ValueError("capacities must be >= 1")


@dataclasses.dataclass
class RequestMetrics:
    rid: int
    arrival_cycles: float
    prompt_len: int
    output_len: int
    first_token_cycles: float = 0.0
    finish_cycles: float = 0.0
    itls: tuple[float, ...] = ()
    n_preemptions: int = 0

    @property
    def ttft_cycles(self) -> float:
        return self.first_token_cycles - self.arrival_cycles

    def meets_slo(self, cfg: ServeConfig) -> bool:
        if cfg.slo_ttft_cycles is not None and \
                self.ttft_cycles > cfg.slo_ttft_cycles:
            return False
        if cfg.slo_itl_cycles is not None and self.itls and \
                max(self.itls) > cfg.slo_itl_cycles:
            return False
        return True


def percentile(values: Sequence[float], q: float) -> float:
    """Deterministic nearest-rank percentile (q in [0, 100])."""
    if not values:
        return 0.0
    s = sorted(values)
    idx = max(0, -(-int(q) * len(s) // 100) - 1) if q > 0 else 0
    return s[min(idx, len(s) - 1)]


@dataclasses.dataclass
class ServeReport:
    """Everything one simulation produced.  ``events`` is the bit-identical
    determinism surface: tuples ``(t_cycles, kind, rid, aux)`` with kinds
    arrive / reject / admit / preempt / token / finish / iter (for iter,
    rid = iteration tokens m, aux = KV occupancy after the iteration)."""
    cfg: ServeConfig
    finished: list[RequestMetrics]
    rejected: list[int]
    events: list[tuple[float, str, int, int]]
    makespan_cycles: float
    n_iterations: int
    n_merged_iterations: int
    n_preemptions: int
    max_kv_occupancy: int
    max_concurrency: int

    @property
    def ttfts(self) -> list[float]:
        return [m.ttft_cycles for m in self.finished]

    @property
    def itls(self) -> list[float]:
        return [v for m in self.finished for v in m.itls]

    @property
    def total_output_tokens(self) -> int:
        return sum(m.output_len for m in self.finished)

    def tokens_per_sec(self, freq_ghz: float = 1.0) -> float:
        if self.makespan_cycles <= 0:
            return 0.0
        return self.total_output_tokens / (self.makespan_cycles /
                                           (freq_ghz * 1e9))

    def goodput_tokens_per_sec(self, freq_ghz: float = 1.0) -> float:
        """Sustained tokens/sec counting only requests that met the SLO."""
        if self.makespan_cycles <= 0:
            return 0.0
        good = sum(m.output_len for m in self.finished
                   if m.meets_slo(self.cfg))
        return good / (self.makespan_cycles / (freq_ghz * 1e9))

    def summary(self, freq_ghz: float = 1.0) -> dict[str, float]:
        return {
            "n_finished": len(self.finished),
            "n_rejected": len(self.rejected),
            "ttft_p50_cycles": percentile(self.ttfts, 50),
            "ttft_p99_cycles": percentile(self.ttfts, 99),
            "itl_p50_cycles": percentile(self.itls, 50),
            "itl_p99_cycles": percentile(self.itls, 99),
            "makespan_cycles": self.makespan_cycles,
            "tokens_per_sec": self.tokens_per_sec(freq_ghz),
            "goodput_tokens_per_sec": self.goodput_tokens_per_sec(freq_ghz),
            "n_iterations": self.n_iterations,
            "n_merged_iterations": self.n_merged_iterations,
            "n_preemptions": self.n_preemptions,
            "max_kv_occupancy": self.max_kv_occupancy,
            "max_concurrency": self.max_concurrency,
        }


@dataclasses.dataclass
class _Run:
    """Mutable per-request engine state.  ``kv_held`` counts KV slots the
    request occupies right now: ``prompt + generated`` once (re)prefilled
    (the emitted token's KV is appended the moment it is generated)."""
    req: Request
    generated: int = 0
    kv_held: int = 0
    prefilled: bool = False
    last_emit_cycles: float = 0.0
    metrics: RequestMetrics | None = None
    _itls: list[float] = dataclasses.field(default_factory=list)


def simulate_serving(stream: RequestStream, cost,
                     cfg: ServeConfig = ServeConfig()) -> ServeReport:
    """Run the continuous-batching engine over a request stream.

    ``cost`` is any object with ``cycles(m) -> float`` (monotone and
    subadditive for the batched-vs-serial guarantee; `AffineCostModel` and
    `NetworkCostModel` both qualify by construction).

    Event loop (iteration granularity):

    1. pull arrivals with ``arrival <= t``; requests that can never
       complete (worst-case KV need > capacity, or whose largest possible
       prefill > ``max_batch_tokens``) are *rejected* up front;
    2. admit from the FIFO waiting queue (FIFO-blocking: stop at the first
       request that does not fit, so nobody is overtaken forever);
    3. compose the iteration: one token per decoding sequence, plus
       whole-prompt prefills for admitted-but-unprefilled requests in
       admission order while the token total fits ``max_batch_tokens``
       (also FIFO-blocking; a lone oversize prefill runs by itself);
    4. under "optimistic" admission, preempt latest-admitted participants
       until the iteration's KV growth fits capacity (never the last one
       — a lone feasible request always fits, see `ServeConfig`);
    5. advance the clock by ``cost.cycles(m)`` and emit one token per
       participant; finished requests free their KV immediately.
    """
    reqs = sorted(stream.requests, key=lambda r: (r.arrival_cycles, r.rid))
    arrivals = collections.deque(reqs)
    waiting: collections.deque[_Run] = collections.deque()
    running: list[_Run] = []        # admission order (LIFO preemption)
    finished: list[RequestMetrics] = []
    rejected: list[int] = []
    events: list[tuple[float, str, int, int]] = []
    t = 0.0
    occupied = 0                    # KV slots held, all running requests
    reserved = 0                    # worst-case KV reserved ("reserve")
    n_iter = n_merged = n_preempt = max_occ = max_conc = 0
    kv_cap = cfg.kv_capacity_tokens
    optimistic = cfg.admission == "optimistic"

    def feasible(r: Request) -> bool:
        if r.prompt_len + r.output_len > kv_cap:
            return False
        # Largest (re)prefill the request can ever need in one iteration:
        # preemption-recompute covers prompt + generated-so-far tokens.
        worst_prefill = r.prompt_len + \
            (r.output_len - 1 if optimistic else 0)
        return worst_prefill <= cfg.max_batch_tokens

    def pull_arrivals() -> None:
        while arrivals and arrivals[0].arrival_cycles <= t:
            r = arrivals.popleft()
            events.append((r.arrival_cycles, "arrive", r.rid, 0))
            if feasible(r):
                waiting.append(_Run(r, metrics=RequestMetrics(
                    r.rid, r.arrival_cycles, r.prompt_len, r.output_len)))
            else:
                rejected.append(r.rid)
                events.append((r.arrival_cycles, "reject", r.rid, 0))

    def admit() -> None:
        nonlocal reserved
        while waiting and len(running) < cfg.max_batch_requests:
            run = waiting[0]
            need = run.req.prompt_len + run.req.output_len
            if optimistic:
                # (re)prefill appends prompt+generated+1 KV slots.
                if occupied + run.req.prompt_len + run.generated + 1 > \
                        kv_cap:
                    break
            else:
                if reserved + need > kv_cap:
                    break
                reserved += need
            waiting.popleft()
            run.prefilled = False
            running.append(run)
            events.append((t, "admit", run.req.rid, run.generated))

    def emit(run: _Run) -> None:
        nonlocal occupied, reserved, max_occ
        new_held = run.req.prompt_len + run.generated + 1
        occupied += new_held - run.kv_held
        run.kv_held = new_held
        run.generated += 1
        m = run.metrics
        events.append((t, "token", run.req.rid, run.generated))
        if run.generated == 1:
            m.first_token_cycles = t
        else:
            run._itls.append(t - run.last_emit_cycles)
        run.last_emit_cycles = t
        if run.generated >= run.req.output_len:
            running.remove(run)
            occupied -= run.kv_held
            run.kv_held = 0
            if not optimistic:
                reserved -= run.req.prompt_len + run.req.output_len
            m.finish_cycles = t
            m.itls = tuple(run._itls)
            finished.append(m)
            events.append((t, "finish", run.req.rid, run.generated))

    while arrivals or waiting or running:
        pull_arrivals()
        admit()
        if not running:
            # Idle: nothing admitted; jump to the next arrival.  (An empty
            # engine always admits any feasible waiting request, so
            # waiting is empty here.)  If this pull rejected the tail of
            # the stream there is nothing left at all: we are done.
            if not arrivals:
                break
            t = max(t, arrivals[0].arrival_cycles)
            continue

        # -- compose the iteration ---------------------------------------
        decodes = [r for r in running if r.prefilled]
        prefills: list[_Run] = []
        tok = len(decodes)
        for r in running:
            if r.prefilled:
                continue
            p = r.req.prompt_len + r.generated
            if (decodes or prefills) and tok + p > cfg.max_batch_tokens:
                break               # FIFO-blocking: wait for space
            prefills.append(r)
            tok += p

        # -- optimistic KV gate: preempt latest-admitted participants ----
        if optimistic:
            def growth() -> int:
                return sum(r.req.prompt_len + r.generated + 1 - r.kv_held
                           for r in prefills) + len(decodes)
            while occupied + growth() > kv_cap and \
                    len(decodes) + len(prefills) > 1:
                victim = next(r for r in reversed(running)
                              if r in decodes or r in prefills)
                running.remove(victim)
                (decodes if victim in decodes else prefills).remove(victim)
                tok -= 1 if victim.prefilled else \
                    victim.req.prompt_len + victim.generated
                occupied -= victim.kv_held
                victim.kv_held = 0
                victim.prefilled = False
                victim.metrics.n_preemptions += 1
                n_preempt += 1
                waiting.appendleft(victim)
                events.append((t, "preempt", victim.req.rid,
                               victim.generated))

        # -- execute ------------------------------------------------------
        participants = len(decodes) + len(prefills)
        n_iter += 1
        n_merged += participants >= 2
        max_conc = max(max_conc, participants)
        t += float(cost.cycles(tok))
        for r in prefills:
            r.prefilled = True
            emit(r)
        for r in decodes:
            emit(r)
        assert occupied <= kv_cap, "KV capacity invariant violated"
        max_occ = max(max_occ, occupied)
        events.append((t, "iter", tok, occupied))

    return ServeReport(cfg=cfg, finished=finished, rejected=rejected,
                       events=events, makespan_cycles=t,
                       n_iterations=n_iter, n_merged_iterations=n_merged,
                       n_preemptions=n_preempt, max_kv_occupancy=max_occ,
                       max_concurrency=max_conc)


def serial_baseline(stream: RequestStream, cost,
                    cfg: ServeConfig = ServeConfig()) -> ServeReport:
    """One request at a time, FIFO, charged through the SAME cost model:
    the differential baseline.  Implemented as the engine itself with
    ``max_batch_requests=1`` under "reserve" admission (no batching, no
    preemption), so any divergence is continuous batching, not modeling."""
    serial_cfg = dataclasses.replace(cfg, max_batch_requests=1,
                                     admission="reserve")
    return simulate_serving(stream, cost, serial_cfg)


# --------------------------------------------------------------------------
# DSE integration: rank architectures by goodput under SLO
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeScenario:
    """The traffic scenario `dse.run_dse(rank_by="slo_goodput")` ranks
    architectures under: models x one seeded Poisson stream x SLOs."""
    model_ids: tuple[str, ...] = ("minicpm-2b",)
    reduced: bool = True
    n_requests: int = 32
    seed: int = 0
    mean_interarrival_cycles: float = 50_000.0
    prompt_lens: tuple[int, ...] = (8, 16, 32)
    output_lens: tuple[int, ...] = (4, 8, 16)
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)
    context_len: int = 4096
    cost_mode: str = "greedy"
    per_layer_cap_s: float = 1.0

    def stream(self) -> RequestStream:
        return RequestStream.poisson(
            self.n_requests, seed=self.seed,
            mean_interarrival_cycles=self.mean_interarrival_cycles,
            prompt_lens=self.prompt_lens, output_lens=self.output_lens)


def arch_goodput(scenario: ServeScenario, arch, *, cache=None,
                 use_cache: bool = False) -> dict[str, float]:
    """Mean SLO goodput (tokens/sec) of one architecture under a traffic
    scenario; per-model values under their model id, the mean under
    ``"mean"``."""
    from repro.configs import get_config

    out: dict[str, float] = {}
    for mid in scenario.model_ids:
        cfg = get_config(mid)
        if scenario.reduced:
            cfg = cfg.reduced()
        cost = NetworkCostModel(
            cfg, arch, max_m=scenario.serve.max_batch_tokens,
            context_len=scenario.context_len, mode=scenario.cost_mode,
            per_layer_cap_s=scenario.per_layer_cap_s,
            cache=cache, use_cache=use_cache)
        rep = simulate_serving(scenario.stream(), cost, scenario.serve)
        out[mid] = rep.goodput_tokens_per_sec(cost.freq_ghz)
    out["mean"] = sum(out.values()) / max(len(scenario.model_ids), 1)
    return out
