"""Analytical latency model (paper §IV-D, Table III) — direct evaluator.

This module is the *semantic oracle*: the MIP in ``formulation.py`` encodes
exactly this recursion with big-M row selection, the heuristic baselines call
it directly, and ``simulator.py`` validates it event-by-event (Fig. 4(a)).

Recursion, innermost MVM upward (i = temporal slot index, λ = operand):

    L_{imax+1} = P_{imax+1,λ} = L_MVM                      (boundary)
    L_i  = max( L_{i+1} * N_{i+1},  max_λ combined(i, λ) )
    combined = P_{i+1,λ}                    (no transfer at this slot)
             | T_{i,λ} + P_{i+1,λ}          (single-buffered transfer)
             | max(T_{i,λ}, P_{i+1,λ})      (double-buffered transfer)
    P_{i,λ} = Table III row (single/double × I,W / O, or no-transfer)
    total   = max_λ P_{0,λ} + one-time fills

Transfer placement: slot i carries a transfer for λ iff its dim is relevant
to λ (otherwise the operand is *data-stationary* across the slot: "incurs no
transfer latency") and some used level lies below the slot's level. The chunk
is B^T of the slot's level; weight transfers whose destination is the CIM
macro pay ``mode_switch_cycles`` on top (Memory-mode reload, Fig. 2(a)) and
are never overlapped.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import workload as wl
from repro.core.arch import CimArch, INPUT, MeshLink, OPERANDS, OUTPUT, WEIGHT
from repro.core.mapping import Mapping, SizeContext


@dataclasses.dataclass
class SlotInfo:
    dim: str
    n: int
    level: dict[str, int]
    transfer: dict[str, float]      # T_{i,λ} in cycles (0 = no transfer)
    double: dict[str, bool]         # psi^DL_{i,λ}


@dataclasses.dataclass
class LatencyReport:
    total_cycles: float
    p0: dict[str, float]
    one_time_cycles: float
    slots: list[SlotInfo]
    l_path: list[float]             # L_i per slot
    spatial_util: float             # used PE lanes / physical lanes
    temporal_util: float            # ideal busy cycles / total cycles
    macs: int

    @property
    def ideal_cycles(self) -> float:
        return self.total_cycles * self.temporal_util


def _hop_cycles(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                operand: str, m_src: int, m_dst: int | None,
                ctx: SizeContext | None = None) -> float:
    """Eq. (11) for one hop: chunk bytes / source-level effective bandwidth,
    plus the Memory-mode switch penalty for weight reloads into the macro."""
    if ctx is not None:
        chunk = ctx.transfer_bytes(operand, m_src)
        bw = ctx.eff_bw_bytes(m_src)
    else:
        chunk = mapping.transfer_bytes(layer, operand, arch, m_src)
        bw = mapping.eff_bw_bytes(arch, m_src)
    t = math.ceil(chunk / bw)
    if operand == WEIGHT and m_dst == arch.macro_level:
        t += arch.mode_switch_cycles
    return float(t)


def transfer_cycles(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                    operand: str, slot: int) -> float:
    """T_{i,λ} per eq. (11) for the slot's source level."""
    m = mapping.level_of[operand][slot]
    return _hop_cycles(mapping, layer, arch, operand, m,
                       mapping.next_used_below(operand, m))


def operand_transfer_table(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                           operand: str,
                           ctx: SizeContext | None = None) -> dict[int, float]:
    """T cycles keyed by *source* level, for every hop of the operand's
    used-level chain (plus the initial DRAM hop under key 0 when level 0
    holds no slots for the operand). T_{i,λ} depends on the slot only
    through its level, so this table — computed once per (mapping, operand)
    — is the single source of truth the scalar slot analysis, the one-time
    fill accounting and the batched packer (`latency_batched.py`) all read."""
    used = mapping.used_levels(operand)
    table: dict[int, float] = {}
    for m_prev, m_dst in zip(used, used[1:]):
        table[m_prev] = _hop_cycles(mapping, layer, arch, operand,
                                    m_prev, m_dst, ctx)
    if used and used[0] != 0:
        table[0] = _hop_cycles(mapping, layer, arch, operand, 0, used[0], ctx)
    return table


def analyze_slots(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                  tables: dict[str, dict[int, float]] | None = None
                  ) -> list[SlotInfo]:
    if tables is None:
        tables = {lam: operand_transfer_table(mapping, layer, arch, lam)
                  for lam in OPERANDS}
    dest_of = {lam: {m: mapping.next_used_below(lam, m)
                     for m in mapping.used_levels(lam)}
               for lam in OPERANDS}
    slots = []
    for i, (dim, n) in enumerate(mapping.temporal):
        level = {lam: mapping.level_of[lam][i] for lam in OPERANDS}
        transfer, double = {}, {}
        for lam in OPERANDS:
            m = level[lam]
            dest = dest_of[lam][m]
            has = wl.is_relevant(dim, lam) and dest is not None
            transfer[lam] = tables[lam][m] if has else 0.0
            dbl = has and mapping.is_double_buffered(lam, dest, arch)
            if lam == WEIGHT and dest == arch.macro_level:
                dbl = False  # mode exclusivity
            double[lam] = dbl
        slots.append(SlotInfo(dim, n, level, transfer, double))
    return slots


def _row(operand: str, t: float, dbl: bool, l_i: float, n: float,
         p_inner: float) -> float:
    """Table III, verbatim rows with coefficients clamped at >= 0."""
    c = lambda x: max(x, 0.0)
    if t == 0.0:
        return l_i * c(n - 1) + p_inner
    if not dbl:
        if operand in (INPUT, WEIGHT):
            return l_i * c(n - 2) + 2 * t + p_inner
        return l_i * c(n - 1) + 2 * t + p_inner
    if operand in (INPUT, WEIGHT):
        return max(l_i * c(n - 3) + 2 * t + max(t, p_inner), t * n)
    return l_i * c(n - 2) + t + max(t, l_i) + max(t, p_inner)


def operand_fill_hops(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                      operand: str,
                      table: dict[int, float] | None = None
                      ) -> list[tuple[bool, float]]:
    """Per hop of the operand's used-level chain, ``(triggered, cycles)``.

    A hop is *triggered* when some relevant temporal slot at or above its
    source level re-runs it inside the loop nest (charged by the Table III
    recursion); untriggered hops are one-time fills charged on top by
    ``evaluate``. The initial DRAM hop (when level 0 holds no slots for the
    operand) is by construction never triggered. The weight chain with NO
    triggered hop is the scheduler's residency condition
    (`scheduler.weight_residency`), so this is the single source of truth
    for both accountings."""
    used = mapping.used_levels(operand)
    n = mapping.n_slots()
    if table is None:
        table = operand_transfer_table(mapping, layer, arch, operand)
    hops: list[tuple[bool, float]] = []
    for m_prev in used[:-1]:
        triggered = any(
            wl.is_relevant(mapping.temporal[i][0], operand)
            and mapping.level_of[operand][i] <= m_prev
            for i in range(n))
        hops.append((triggered, table[m_prev]))
    if used and used[0] != 0:
        hops.append((False, table[0]))
    return hops


def evaluate(mapping: Mapping, layer: wl.Layer,
             arch: CimArch) -> LatencyReport:
    slots = analyze_slots(mapping, layer, arch)
    n_slots = len(slots)
    l_mvm = float(arch.l_mvm_cycles)

    l_next = l_mvm                      # L_{i+1}
    n_next = 1.0                        # N_{i+1}
    p_next = {lam: l_mvm for lam in OPERANDS}
    l_path = [0.0] * n_slots

    for i in range(n_slots - 1, -1, -1):
        s = slots[i]
        combined = 0.0
        for lam in OPERANDS:
            t = s.transfer[lam]
            if t == 0.0:
                combined = max(combined, p_next[lam])
            elif s.double[lam]:
                combined = max(combined, max(t, p_next[lam]))
            else:
                combined = max(combined, t + p_next[lam])
        l_i = max(l_next * n_next, combined)
        l_path[i] = l_i
        p_cur = {lam: _row(lam, s.transfer[lam], s.double[lam], l_i,
                           float(s.n), p_next[lam]) for lam in OPERANDS}
        l_next, n_next, p_next = l_i, float(s.n), p_cur

    # One-time fills: operand hops never triggered by any relevant temporal
    # slot above the destination (fully-stationary tiles loaded once). The
    # chain includes the initial DRAM hop when level 0 holds no slots for λ
    # — charged at B^T_0 (full multicast traffic, source precision),
    # identical to the MIP's OTC for the DRAM hop.
    one_time = 0.0
    for lam in OPERANDS:
        one_time += sum(t for triggered, t in
                        operand_fill_hops(mapping, layer, arch, lam)
                        if not triggered)

    total = max(p_next.values()) + one_time

    phys = math.prod(ax.size for ax in arch.spatial)
    used_lanes = math.prod(
        mapping.spatial_extent(ax.name) for ax in arch.spatial)
    spatial_util = used_lanes / phys
    temporal_iters = math.prod(f for _, f in mapping.temporal)
    ideal = temporal_iters * l_mvm
    return LatencyReport(
        total_cycles=total,
        p0=p_next,
        one_time_cycles=one_time,
        slots=slots,
        l_path=l_path,
        spatial_util=spatial_util,
        temporal_util=min(1.0, ideal / max(total, 1e-9)),
        macs=layer.macs,
    )


# ---------------------------------------------------------------------------
# Inter-chip link transfer terms (mesh extension, DESIGN.md §Mesh optimization)
# ---------------------------------------------------------------------------

def link_transfer_cycles(bytes_: float, link: MeshLink, hops: int) -> float:
    """Point-to-point transfer of ``bytes_`` over ``hops`` store-and-forward
    links: each hop re-serializes the payload at the link bandwidth and pays
    the fixed router latency. This is the mesh-level analogue of eq. (11) —
    chunk bytes over effective bandwidth, ceil'd to whole cycles — with the
    hop count playing the multicast-traffic role the on-chip model charges
    via ``eff_bw_bytes``. Monotone non-increasing in ``bandwidth_bits`` and
    exactly zero for zero hops (same-chip transfer)."""
    if hops <= 0 or bytes_ <= 0:
        return 0.0
    per_hop = math.ceil(bytes_ / link.bytes_per_cycle())
    return float(hops) * (per_hop + link.hop_latency_cycles)


def ring_allreduce_cycles(bytes_: float, link: MeshLink,
                          n_chips: int) -> float:
    """Ring all-reduce of ``bytes_`` of partial sums across ``n_chips``:
    2(N-1) steps each moving a 1/N chunk over one link (reduce-scatter +
    all-gather). Both the ring and the grid topology embed a Hamiltonian
    ring, so the same bound serves both. Zero for a single chip."""
    if n_chips <= 1 or bytes_ <= 0:
        return 0.0
    chunk = math.ceil(math.ceil(bytes_ / n_chips) / link.bytes_per_cycle())
    return 2.0 * (n_chips - 1) * (chunk + link.hop_latency_cycles)


def idealized_cycles(mapping: Mapping, layer: wl.Layer,
                     arch: CimArch) -> float:
    """The oversimplified cost model of prior work (paper limitation ❶):
    latency per level = max(compute, transfer) assuming perfect overlap
    everywhere. Used by the ZigZag-style heuristic baseline to *pick* its
    mapping; the resulting mapping is then re-scored with `evaluate`."""
    compute, terms = idealized_terms(mapping, layer, arch)
    worst = compute
    for num, bw in terms:
        worst = max(worst, num / bw)
    return float(worst)


def idealized_terms(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                    ctx: SizeContext | None = None
                    ) -> tuple[int, list[tuple[float, float]]]:
    """The idealized model's raw terms: ``(compute_cycles, [(num, bw), ...])``
    with one ``num / bw`` transfer bound per (operand, used level with a
    destination), in the scalar evaluation order. Shared with the batched
    packer (`latency_batched.py`) so both front-ends derive the same
    quantities."""
    temporal_iters = math.prod(f for _, f in mapping.temporal)
    compute = temporal_iters * arch.l_mvm_cycles
    terms: list[tuple[float, float]] = []
    for lam in OPERANDS:
        for m in mapping.used_levels(lam):
            if mapping.next_used_below(lam, m) is None:
                continue
            # iterations of loops at or above this level that change the tile
            iters = 1
            for i, (dim, f) in enumerate(mapping.temporal):
                if mapping.level_of[lam][i] <= m and wl.is_relevant(dim, lam):
                    iters *= f
            if ctx is not None:
                terms.append((iters * ctx.transfer_bytes(lam, m),
                              ctx.eff_bw_bytes(m)))
            else:
                chunk = mapping.transfer_bytes(layer, lam, arch, m)
                terms.append((iters * chunk, mapping.eff_bw_bytes(arch, m)))
    return compute, terms
