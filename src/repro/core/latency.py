"""Analytical latency model (paper §IV-D, Table III) — direct evaluator.

This module is the *semantic oracle*: the MIP in ``formulation.py`` encodes
exactly this recursion with big-M row selection, the heuristic baselines call
it directly, and ``simulator.py`` validates it event-by-event (Fig. 4(a)).

Recursion, innermost MVM upward (i = temporal slot index, λ = operand):

    L_{imax+1} = P_{imax+1,λ} = L_MVM                      (boundary)
    L_i  = max( L_{i+1} * N_{i+1},  max_λ combined(i, λ) )
    combined = P_{i+1,λ}                    (no transfer at this slot)
             | T_{i,λ} + P_{i+1,λ}          (single-buffered transfer)
             | max(T_{i,λ}, P_{i+1,λ})      (double-buffered transfer)
    P_{i,λ} = Table III row (single/double × I,W / O, or no-transfer)
    total   = max_λ P_{0,λ} + one-time fills

Transfer placement: slot i carries a transfer for λ iff its dim is relevant
to λ (otherwise the operand is *data-stationary* across the slot: "incurs no
transfer latency") and some used level lies below the slot's level. The chunk
is B^T of the slot's level; weight transfers whose destination is the CIM
macro pay ``mode_switch_cycles`` on top (Memory-mode reload, Fig. 2(a)) and
are never overlapped.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core import workload as wl
from repro.core.arch import CimArch, INPUT, OPERANDS, OUTPUT, WEIGHT
from repro.core.mapping import Mapping


@dataclasses.dataclass
class SlotInfo:
    dim: str
    n: int
    level: dict[str, int]
    transfer: dict[str, float]      # T_{i,λ} in cycles (0 = no transfer)
    double: dict[str, bool]         # psi^DL_{i,λ}


@dataclasses.dataclass
class LatencyReport:
    total_cycles: float
    p0: dict[str, float]
    one_time_cycles: float
    slots: list[SlotInfo]
    l_path: list[float]             # L_i per slot
    spatial_util: float             # used PE lanes / physical lanes
    temporal_util: float            # ideal busy cycles / total cycles
    macs: int

    @property
    def ideal_cycles(self) -> float:
        return self.total_cycles * self.temporal_util


def transfer_cycles(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                    operand: str, slot: int) -> float:
    """T_{i,λ} per eq. (11): chunk bytes / source-level effective bandwidth,
    plus the Memory-mode switch penalty for weight reloads into the macro."""
    m = mapping.level_of[operand][slot]
    chunk = mapping.transfer_bytes(layer, operand, arch, m)
    bw = mapping.eff_bw_bytes(arch, m)
    t = math.ceil(chunk / bw)
    dest = mapping.next_used_below(operand, m)
    if operand == WEIGHT and dest == arch.macro_level:
        t += arch.mode_switch_cycles
    return float(t)


def analyze_slots(mapping: Mapping, layer: wl.Layer,
                  arch: CimArch) -> list[SlotInfo]:
    slots = []
    for i, (dim, n) in enumerate(mapping.temporal):
        level = {lam: mapping.level_of[lam][i] for lam in OPERANDS}
        transfer, double = {}, {}
        for lam in OPERANDS:
            m = level[lam]
            dest = mapping.next_used_below(lam, m)
            has = wl.is_relevant(dim, lam) and dest is not None
            transfer[lam] = transfer_cycles(mapping, layer, arch, lam, i) \
                if has else 0.0
            dbl = has and dest is not None and \
                mapping.is_double_buffered(lam, dest, arch)
            if lam == WEIGHT and dest == arch.macro_level:
                dbl = False  # mode exclusivity
            double[lam] = dbl
        slots.append(SlotInfo(dim, n, level, transfer, double))
    return slots


def _row(operand: str, t: float, dbl: bool, l_i: float, n: float,
         p_inner: float) -> float:
    """Table III, verbatim rows with coefficients clamped at >= 0."""
    c = lambda x: max(x, 0.0)
    if t == 0.0:
        return l_i * c(n - 1) + p_inner
    if not dbl:
        if operand in (INPUT, WEIGHT):
            return l_i * c(n - 2) + 2 * t + p_inner
        return l_i * c(n - 1) + 2 * t + p_inner
    if operand in (INPUT, WEIGHT):
        return max(l_i * c(n - 3) + 2 * t + max(t, p_inner), t * n)
    return l_i * c(n - 2) + t + max(t, l_i) + max(t, p_inner)


def operand_fill_hops(mapping: Mapping, layer: wl.Layer, arch: CimArch,
                      operand: str) -> list[tuple[bool, float]]:
    """Per hop of the operand's used-level chain, ``(triggered, cycles)``.

    A hop is *triggered* when some relevant temporal slot at or above its
    source level re-runs it inside the loop nest (charged by the Table III
    recursion); untriggered hops are one-time fills charged on top by
    ``evaluate``. The initial DRAM hop (when level 0 holds no slots for the
    operand) is by construction never triggered. The weight chain with NO
    triggered hop is the scheduler's residency condition
    (`scheduler.weight_residency`), so this is the single source of truth
    for both accountings."""
    used = mapping.used_levels(operand)
    n = mapping.n_slots()
    hops: list[tuple[bool, float]] = []
    for m_prev, m_dst in zip(used, used[1:]):
        triggered = any(
            wl.is_relevant(mapping.temporal[i][0], operand)
            and mapping.level_of[operand][i] <= m_prev
            for i in range(n))
        chunk = mapping.transfer_bytes(layer, operand, arch, m_prev)
        t = math.ceil(chunk / mapping.eff_bw_bytes(arch, m_prev))
        if operand == WEIGHT and m_dst == arch.macro_level:
            t += arch.mode_switch_cycles
        hops.append((triggered, float(t)))
    if used and used[0] != 0:
        chunk = mapping.transfer_bytes(layer, operand, arch, 0)
        t = math.ceil(chunk / mapping.eff_bw_bytes(arch, 0))
        if operand == WEIGHT and used[0] == arch.macro_level:
            t += arch.mode_switch_cycles
        hops.append((False, float(t)))
    return hops


def evaluate(mapping: Mapping, layer: wl.Layer,
             arch: CimArch) -> LatencyReport:
    slots = analyze_slots(mapping, layer, arch)
    n_slots = len(slots)
    l_mvm = float(arch.l_mvm_cycles)

    l_next = l_mvm                      # L_{i+1}
    n_next = 1.0                        # N_{i+1}
    p_next = {lam: l_mvm for lam in OPERANDS}
    l_path = [0.0] * n_slots

    for i in range(n_slots - 1, -1, -1):
        s = slots[i]
        combined = 0.0
        for lam in OPERANDS:
            t = s.transfer[lam]
            if t == 0.0:
                combined = max(combined, p_next[lam])
            elif s.double[lam]:
                combined = max(combined, max(t, p_next[lam]))
            else:
                combined = max(combined, t + p_next[lam])
        l_i = max(l_next * n_next, combined)
        l_path[i] = l_i
        p_cur = {lam: _row(lam, s.transfer[lam], s.double[lam], l_i,
                           float(s.n), p_next[lam]) for lam in OPERANDS}
        l_next, n_next, p_next = l_i, float(s.n), p_cur

    # One-time fills: operand hops never triggered by any relevant temporal
    # slot above the destination (fully-stationary tiles loaded once). The
    # chain includes the initial DRAM hop when level 0 holds no slots for λ
    # — charged at B^T_0 (full multicast traffic, source precision),
    # identical to the MIP's OTC for the DRAM hop.
    one_time = 0.0
    for lam in OPERANDS:
        one_time += sum(t for triggered, t in
                        operand_fill_hops(mapping, layer, arch, lam)
                        if not triggered)

    total = max(p_next.values()) + one_time

    phys = math.prod(ax.size for ax in arch.spatial)
    used_lanes = math.prod(
        mapping.spatial_extent(ax.name) for ax in arch.spatial)
    spatial_util = used_lanes / phys
    temporal_iters = math.prod(f for _, f in mapping.temporal)
    ideal = temporal_iters * l_mvm
    return LatencyReport(
        total_cycles=total,
        p0=p_next,
        one_time_cycles=one_time,
        slots=slots,
        l_path=l_path,
        spatial_util=spatial_util,
        temporal_util=min(1.0, ideal / max(total, 1e-9)),
        macs=layer.macs,
    )


def idealized_cycles(mapping: Mapping, layer: wl.Layer,
                     arch: CimArch) -> float:
    """The oversimplified cost model of prior work (paper limitation ❶):
    latency per level = max(compute, transfer) assuming perfect overlap
    everywhere. Used by the ZigZag-style heuristic baseline to *pick* its
    mapping; the resulting mapping is then re-scored with `evaluate`."""
    temporal_iters = math.prod(f for _, f in mapping.temporal)
    compute = temporal_iters * arch.l_mvm_cycles
    worst = compute
    for lam in OPERANDS:
        for m in mapping.used_levels(lam):
            dest = mapping.next_used_below(lam, m)
            if dest is None:
                continue
            # iterations of loops at or above this level that change the tile
            iters = 1
            for i, (dim, f) in enumerate(mapping.temporal):
                if mapping.level_of[lam][i] <= m and wl.is_relevant(dim, lam):
                    iters *= f
            chunk = mapping.transfer_bytes(layer, lam, arch, m)
            worst = max(worst, iters * chunk / mapping.eff_bw_bytes(arch, m))
    return float(worst)
