"""Hardware/dataflow co-design DSE (DESIGN.md §Co-design DSE).

The paper's headline claim — EDP reductions "across various DNN models and
hardware setups" — is a *joint* statement about dataflow and architecture.
This module closes the architecture half: instead of optimizing dataflow
against a handful of hand-picked ``CimArch`` presets, it sweeps a
parameterized architecture space (macro geometry, core count, buffer
capacities, link bandwidths, double-buffering policy) against any workload
the frontends produce and emits a Pareto frontier over

    (latency cycles, energy pJ, area proxy = macros x crossbar bits).

Exhaustive MIP over the grid is unaffordable (minutes per arch), so the
exploration is **multi-fidelity**:

  1. **Screening pass (cheap, no MIP).** Every grid arch is scored with the
     same incumbent machinery that warm-starts the MIP (`baselines`):
     greedy constructor plus a small accurate-model stochastic search, run
     on a MAC-coverage-representative subset of the unique layers. Archs
     that another no-larger-area arch beats *decisively* — by more than the
     screening slack in BOTH latency and energy — are pruned: the slack
     absorbs the incumbent-vs-MIP fidelity gap, so a point the MIP could
     still promote onto the frontier survives (regression-tested against
     exhaustive MIP on a tiny grid in ``tests/test_dse.py``). Exact
     screening ties — knobs the incumbent mappings never exercised —
     collapse to their most-capable representative.
  2. **Full pass (MIP).** Survivors get warm-started MIP solves through the
     existing network pipeline (`network.optimize_over_archs`): structural
     layer dedup, MAC-weighted budgets and process fan-out all apply per
     arch, and ONE shared ``ResultCache`` with arch-aware keys makes sweep
     reruns incremental. The latency objective is the **scheduled**
     end-to-end number (`core/scheduler.py`): weight-resident segment
     packing and layer-to-core pipelining, so the frontier credits extra
     cores/macros for the parallelism they enable — the per-layer serial
     sum (which treats the chip as a per-layer constant) only rides along
     for reporting (`DsePoint.serial_cycles`).

Every frontier point's mapping set is re-checked with the mapping validator
(`mapping.validate`) — the frontier is only as good as the feasibility of
the mappings behind it.

    from repro.core.dse import ArchSpace, run_dse
    res = run_dse(layers, counts, ArchSpace())
    for p in res.frontier:
        print(p.arch_name, p.cycles, p.energy_pj, p.area_bits)
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Sequence

from repro.core import workload as wl
from repro.core.arch import CimArch, area_proxy, default_arch
from repro.core.cache import (ResultCache, layer_cache_key,
                              mapping_from_json)
from repro.core.mapping import validate
from repro.core.network import (NetworkResult, dedup_layers,
                                optimize_over_archs)

#: Default screening-prune slack: an arch is pruned only when a no-larger
#: arch beats it by >25% in BOTH latency and energy at screening fidelity.
DEFAULT_SLACK = 0.25
#: Default stochastic-search budget per (layer, arch) during screening.
DEFAULT_SCREEN_SAMPLES = 64
#: Screening layer subset: top unique layers by multiplicity-weighted MACs
#: until this fraction of total MACs is covered (capped at _MAX_LAYERS).
SCREEN_MAC_COVERAGE = 0.97
SCREEN_MAX_LAYERS = 8


# ---------------------------------------------------------------------------
# Architecture space
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchSpace:
    """Cartesian grid over ``default_arch`` knobs.

    Each field lists candidate values; ``enumerate()`` yields one validated
    ``CimArch`` per grid point with a deterministic knob-derived name.
    Capacities in KB, bandwidths in bus bits/cycle; ``double_buffered``
    toggles the policy for every on-chip level at once (the macro stays
    single-buffered regardless — Fig. 2(a))."""

    macro: tuple[tuple[int, int], ...] = ((64, 32), (128, 32), (256, 64))
    n_cores: tuple[int, ...] = (4, 8, 16)
    gbuf_kb: tuple[float, ...] = (8.0,)
    lbuf_kb: tuple[float, ...] = (256.0,)
    gbuf_bus_bits: tuple[int, ...] = (256,)
    lbuf_bus_bits: tuple[int, ...] = (128,)
    double_buffered: tuple[bool, ...] = (True,)
    prefix: str = "dse"

    @property
    def size(self) -> int:
        return (len(self.macro) * len(self.n_cores) * len(self.gbuf_kb) *
                len(self.lbuf_kb) * len(self.gbuf_bus_bits) *
                len(self.lbuf_bus_bits) * len(self.double_buffered))

    def enumerate(self) -> list[CimArch]:
        out = []
        for (rows, cols), nc, g, l, gbw, lbw, db in itertools.product(
                self.macro, self.n_cores, self.gbuf_kb, self.lbuf_kb,
                self.gbuf_bus_bits, self.lbuf_bus_bits,
                self.double_buffered):
            name = (f"{self.prefix}-m{rows}x{cols}-c{nc}-g{g:g}k-l{l:g}k"
                    f"-bw{gbw}x{lbw}-{'db' if db else 'sb'}")
            out.append(default_arch(
                macro_rows=rows, macro_cols=cols, n_cores=nc,
                gbuf_kb=g, lbuf_kb=l, gbuf_bus_bits=gbw,
                lbuf_bus_bits=lbw, double_buffered=db, name=name))
        return out


# ---------------------------------------------------------------------------
# Points + Pareto dominance
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DsePoint:
    """One arch's position in objective space at one fidelity.

    At MIP fidelity ``cycles`` is the *scheduled* end-to-end latency
    (`core/scheduler.py`: weight-resident segments, pipelined cores) —
    extra cores and macros now genuinely help an arch onto the frontier
    instead of idling in the serial sum, which is kept in
    ``serial_cycles``. Screening points are incumbent serial sums."""

    arch_name: str
    cycles: float
    energy_pj: float
    area_bits: int
    fidelity: str = "mip"            # "screen" | "mip"
    serial_cycles: float | None = None
    #: SLO goodput (tokens/sec, mean over the serve scenario's models) from
    #: the request-level serving simulator (`core/serving.py`); None when
    #: no serve scenario was evaluated.
    goodput_tok_s: float | None = None
    #: Which objective vector `objectives()` exposes: "latency" ranks by
    #: scheduled cycles, "slo_goodput" by -goodput (both alongside energy
    #: and area, all minimized).
    rank_by: str = "latency"

    @property
    def edp(self) -> float:
        return self.cycles * self.energy_pj

    def objectives(self) -> tuple[float, float, float]:
        if self.rank_by == "slo_goodput":
            if self.goodput_tok_s is None:
                raise ValueError(
                    f"{self.arch_name}: rank_by='slo_goodput' needs a "
                    "goodput (run_dse(serve=ServeScenario(...)))")
            return (-self.goodput_tok_s, self.energy_pj,
                    float(self.area_bits))
        return (self.cycles, self.energy_pj, float(self.area_bits))


def dominates(a: DsePoint, b: DsePoint) -> bool:
    """Standard Pareto dominance: ``a`` no worse than ``b`` in every
    objective and strictly better in at least one (minimization)."""
    ao, bo = a.objectives(), b.objectives()
    return all(x <= y for x, y in zip(ao, bo)) and ao != bo


def pareto_frontier(points: Sequence[DsePoint]) -> list[DsePoint]:
    """Non-dominated subset, input order preserved. Exact ties in objective
    space keep the first occurrence only."""
    out: list[DsePoint] = []
    for p in points:
        if any(dominates(q, p) for q in points):
            continue
        if any(q.objectives() == p.objectives() for q in out):
            continue
        out.append(p)
    return out


def _capability(arch: CimArch) -> tuple:
    """Total buffering capability, used only to pick the representative of a
    screening tie: more capacity/bandwidth/buffering = more mappings for the
    MIP pass to exploit."""
    return (sum(lv.capacity_bytes or 0 for lv in arch.levels),
            sum(lv.bus_bits for lv in arch.levels),
            sum(lv.double_bufferable for lv in arch.levels))


def screen_prune(points: Sequence[DsePoint],
                 slack: float = DEFAULT_SLACK,
                 archs: dict[str, CimArch] | None = None
                 ) -> tuple[list[DsePoint], list[DsePoint]]:
    """Split screening points into (survivors, pruned). Two rules:

    1. **Decisive dominance.** ``p`` is pruned iff some ``q`` with no larger
       area beats it by more than ``slack`` in BOTH latency and energy:

           area_q <= area_p  and  cycles_q * (1+slack) <= cycles_p
                             and  energy_q * (1+slack) <= energy_p.

       Area is exact (a grid constant, not an estimate), so it carries no
       slack; latency/energy are incumbent estimates, so a decisive margin
       is required before a point is written off — the MIP typically
       improves the incumbent by far less than ``slack``, which is what the
       never-prunes-the-MIP-optimum regression in ``tests/test_dse.py``
       checks. Since the full pass ranks by *scheduled* latency the slack
       must additionally absorb the serial-vs-scheduled gap (observed
       single-digit % on the zoo; widen ``slack`` for workloads where
       cross-layer pipelining dominates — a documented limitation).

    2. **Exact ties.** Points with *identical* (cycles, energy, area) are
       archs the screening fidelity cannot distinguish — typically a knob
       the incumbent mappings never exercised (e.g. GBuf 2 KB vs 8 KB when
       every incumbent bypasses the GBuf). One representative goes to the
       MIP pass: the arch with the greatest buffering capability when
       ``archs`` is given (most headroom for the MIP to exploit), else the
       first in input order."""
    drop_idx: set[int] = set()
    for i, p in enumerate(points):
        if any(q.area_bits <= p.area_bits
               and q.cycles * (1.0 + slack) <= p.cycles
               and q.energy_pj * (1.0 + slack) <= p.energy_pj
               for q in points if q is not p):
            drop_idx.add(i)
    ties: dict[tuple, list[int]] = {}
    for i, p in enumerate(points):
        if i not in drop_idx:
            ties.setdefault(p.objectives(), []).append(i)
    for group in ties.values():
        if len(group) < 2:
            continue
        if archs is not None:
            rep = max(group,
                      key=lambda i: _capability(archs[points[i].arch_name]))
        else:
            rep = group[0]
        drop_idx.update(i for i in group if i != rep)
    keep = [p for i, p in enumerate(points) if i not in drop_idx]
    drop = [p for i, p in enumerate(points) if i in drop_idx]
    return keep, drop


# ---------------------------------------------------------------------------
# Screening pass (cheap incumbents, no MIP)
# ---------------------------------------------------------------------------

def _screen_subset(layers: Sequence[wl.Layer], counts: Sequence[int],
                   *, coverage: float = SCREEN_MAC_COVERAGE,
                   max_layers: int = SCREEN_MAX_LAYERS
                   ) -> list[tuple[wl.Layer, int]]:
    """Representative (unique layer, total multiplicity) subset: heaviest
    unique layers by multiplicity-weighted MACs until ``coverage`` of total
    MACs is reached (capped). The same subset scores every arch, so the
    screening ranking is consistent even though it is not the full sum."""
    unique, keys = dedup_layers(layers)
    mult: dict[str, int] = {}
    for k, c in zip(keys, counts):
        mult[k] = mult.get(k, 0) + int(c)
    weighted = [(ul, mult[layer_cache_key(ul)]) for ul in unique]
    weighted.sort(key=lambda lc: -(lc[0].macs * lc[1]))
    total = sum(l.macs * c for l, c in weighted)
    subset, seen = [], 0
    for l, c in weighted[:max_layers]:
        if subset and seen >= coverage * total:
            break
        subset.append((l, c))
        seen += l.macs * c
    return subset


def screen_arch(subset: Sequence[tuple[wl.Layer, int]], arch: CimArch, *,
                samples: int = DEFAULT_SCREEN_SAMPLES,
                seed: int = 0) -> DsePoint:
    """Incumbent-fidelity score of one arch: per subset layer, the better of
    the greedy constructor and a ``samples``-budget accurate-model
    stochastic search (exactly the incumbents that warm-start the MIP),
    aggregated with multiplicities. No MIP is built or solved.

    Scoring is batched: the greedy candidate and the search winner go
    through `latency_batched.score_mappings` in one dispatch (bit-equal to
    the scalar `evaluate_edp`, so the selected incumbent and the summed
    cycles/energy are unchanged)."""
    import numpy as np

    from repro.core import latency_batched as lb
    from repro.core.baselines import greedy_mapping, heuristic_search

    cycles = energy = 0.0
    for layer, mult in subset:
        cands = [greedy_mapping(layer, arch)]
        if samples > 0:
            r = heuristic_search(layer, arch, budget=samples, seed=seed,
                                 accurate=True)
            cands.append(r.mapping)
        sc = lb.score_mappings(cands, layer, arch,
                               need=("feasible", "latency", "energy"))
        k = int(np.argmin(sc.edp))     # tie -> greedy, as before
        cycles += float(sc.cycles[k]) * mult
        energy += float(sc.energy_pj[k]) * mult
    return DsePoint(arch_name=arch.name, cycles=cycles, energy_pj=energy,
                    area_bits=area_proxy(arch), fidelity="screen")


# ---------------------------------------------------------------------------
# Full co-exploration
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DseResult:
    archs: dict[str, CimArch]              # full grid, name -> arch
    screen_points: dict[str, DsePoint]     # screening fidelity (whole grid)
    survivors: list[str]                   # arch names sent to the MIP pass
    pruned: list[str]                      # arch names screened out
    networks: dict[str, NetworkResult]     # MIP pass, survivors only
    points: dict[str, DsePoint]            # MIP fidelity, survivors only
    frontier: list[DsePoint]               # non-dominated MIP points,
                                           # sorted by ascending area
    validation: dict[str, list[str]]       # frontier arch -> mapping errors
    wall_s: float
    rank_by: str = "latency"               # objective set behind `frontier`

    @property
    def prune_fraction(self) -> float:
        n = len(self.archs)
        return len(self.pruned) / n if n else 0.0

    def frontier_by(self, rank_by: str) -> list[DsePoint]:
        """The Pareto frontier under either objective set, from the same
        MIP-fidelity points — lets one run compare the latency-ranked and
        goodput-ranked frontiers directly (``rank_by="slo_goodput"``
        requires the run to have evaluated a serve scenario)."""
        pts = [dataclasses.replace(p, rank_by=rank_by)
               for p in self.points.values()]
        return sorted(pareto_frontier(pts),
                      key=lambda p: (p.area_bits, p.cycles))

    def best_under_area(self, area_bits: float,
                        objective: str = "edp") -> DsePoint | None:
        """Co-design answer: best frontier point within an area budget."""
        feas = [p for p in self.frontier if p.area_bits <= area_bits]
        return min(feas, key=lambda p: getattr(p, objective), default=None)


def run_dse(layers: Sequence[wl.Layer],
            counts: Sequence[int] | None,
            space: ArchSpace | Sequence[CimArch],
            mode: str = "miredo", *,
            screen: bool = True,
            screen_slack: float = DEFAULT_SLACK,
            screen_samples: int = DEFAULT_SCREEN_SAMPLES,
            per_layer_cap_s: float = 10.0,
            total_budget_s: float | None = None,
            cache: ResultCache | None = None,
            use_cache: bool = True,
            workers: int | None = None,
            validate_frontier: bool = True,
            schedule_boundaries: Sequence[int] | None = None,
            rank_by: str = "latency",
            serve=None,
            verbose: bool = False) -> DseResult:
    """Co-explore the architecture grid against one workload.

    ``space`` is an ``ArchSpace`` or an explicit arch list; ``counts`` the
    per-layer network multiplicities (``None`` = all 1);
    ``schedule_boundaries`` the sub-stream start indices when ``layers``
    pools several independent workloads (the scheduler must not pipeline
    across them). ``screen=False``
    skips the pruning pass and runs the MIP on the whole grid (the
    exhaustive reference the screening guarantee is tested against).
    ``total_budget_s`` is the *per-arch* global solver budget forwarded to
    ``optimize_network``; the default derives from ``per_layer_cap_s`` as
    usual. Returns a ``DseResult`` whose ``frontier`` holds the
    non-dominated (scheduled cycles, energy, area) points at MIP fidelity
    — latency is the multi-core schedule's end-to-end number, not the
    serial per-layer sum — each with every mapping re-validated when
    ``validate_frontier`` is on.

    ``rank_by="slo_goodput"`` (with a ``serve=ServeScenario(...)`` traffic
    scenario from `core/serving.py`) ranks the frontier by sustained
    tokens/sec under SLO instead of single-pass latency: every survivor is
    additionally run through the request-level serving simulator (iteration
    costs from this arch's own scheduled solves) and the first objective
    becomes ``-goodput``.  Passing ``serve`` with the default
    ``rank_by="latency"`` annotates ``DsePoint.goodput_tok_s`` without
    changing the frontier, and ``DseResult.frontier_by`` re-ranks the same
    points either way.  Note the screening prune still uses incumbent
    latency/energy — its never-prunes-the-optimum guarantee is argued for
    the latency objectives; use ``screen=False`` when goodput and latency
    rankings are expected to diverge hard (see DESIGN.md §Serving
    simulator)."""
    t0 = time.monotonic()
    if rank_by not in ("latency", "slo_goodput"):
        raise ValueError(f"unknown rank_by {rank_by!r}")
    if rank_by == "slo_goodput" and serve is None:
        raise ValueError("rank_by='slo_goodput' requires a serve scenario "
                         "(serving.ServeScenario)")
    layers = list(layers)
    counts = [1] * len(layers) if counts is None else list(counts)
    assert len(counts) == len(layers)
    grid = space.enumerate() if isinstance(space, ArchSpace) else list(space)
    names = [a.name for a in grid]
    assert len(set(names)) == len(names), f"duplicate arch names: {names}"
    archs = {a.name: a for a in grid}

    # -- screening pass -----------------------------------------------------
    subset = _screen_subset(layers, counts)
    screen_points = {a.name: screen_arch(subset, a, samples=screen_samples)
                     for a in grid}
    if screen:
        kept, dropped = screen_prune(list(screen_points.values()),
                                     slack=screen_slack, archs=archs)
        survivors = [p.arch_name for p in kept]
        pruned = [p.arch_name for p in dropped]
    else:
        survivors, pruned = list(names), []
    if verbose:
        print(f"[dse] grid {len(grid)} -> {len(survivors)} survivors "
              f"({len(pruned)} pruned by screening)", flush=True)

    # -- full pass: warm-started MIPs through the network pipeline ----------
    networks = optimize_over_archs(
        layers, [archs[n] for n in survivors], mode, counts=counts,
        cache=cache, use_cache=use_cache, per_layer_cap_s=per_layer_cap_s,
        total_budget_s=total_budget_s, workers=workers,
        schedule_boundaries=schedule_boundaries, verbose=verbose)
    # MIP-fidelity latency is the *scheduled* end-to-end number: the
    # network scheduler decides how the arch's cores are actually shared
    # across layers, so core/macro-rich grid points are credited for the
    # parallelism they enable rather than scored as if every layer ran
    # alone (the serial sum rides along for reporting).
    # Traffic fidelity: run each survivor through the serving simulator
    # (iteration cost anchored on that arch's own scheduled solves) so the
    # frontier can rank by sustained tokens/sec under SLO.
    goodputs: dict[str, float] = {}
    if serve is not None:
        from repro.core.serving import arch_goodput
        for n in networks:
            goodputs[n] = arch_goodput(serve, archs[n], cache=cache,
                                       use_cache=use_cache)["mean"]
            if verbose:
                print(f"[dse] serve {n}: goodput "
                      f"{goodputs[n]:.3g} tok/s", flush=True)
    points = {
        n: DsePoint(arch_name=n,
                    cycles=(net.scheduled or net.totals)["cycles"],
                    # scheduled energy too: it carries any greedy-basis
                    # swap delta, so EDP pairs cycles with the energy of
                    # the mappings the schedule actually executes
                    energy_pj=(net.scheduled or net.totals)["energy_pj"],
                    area_bits=area_proxy(archs[n]), fidelity="mip",
                    serial_cycles=net.totals["cycles"],
                    goodput_tok_s=goodputs.get(n), rank_by=rank_by)
        for n, net in networks.items()}

    frontier = sorted(pareto_frontier(list(points.values())),
                      key=lambda p: (p.area_bits, p.cycles))

    # -- frontier feasibility: re-validate every mapping --------------------
    validation: dict[str, list[str]] = {}
    if validate_frontier:
        for p in frontier:
            arch, errs, seen = archs[p.arch_name], [], set()
            for lr in networks[p.arch_name].layers:
                if lr.key in seen:      # shared mapping, validated once
                    continue
                seen.add(lr.key)
                mp = mapping_from_json(lr.record["mapping"])
                errs += [f"{lr.layer.name}: {e}"
                         for e in validate(mp, lr.layer, arch)]
            validation[p.arch_name] = errs
    return DseResult(archs=archs, screen_points=screen_points,
                     survivors=survivors, pruned=pruned, networks=networks,
                     points=points, frontier=frontier,
                     validation=validation,
                     wall_s=round(time.monotonic() - t0, 2),
                     rank_by=rank_by)


# ---------------------------------------------------------------------------
# Mesh DSE: chip-count / link-bandwidth axes (DESIGN.md §Mesh optimization)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MeshSpace:
    """Cartesian grid over `mesh.MeshArch` knobs: chip presets x chip count
    x link bandwidth/hop latency x topology. ``enumerate()`` yields one
    validated mesh per grid point (1-chip points included — they ARE the
    single chip, anchoring the frontier's scaling story). Chips default to
    the Table-IV preset; pass explicit ``CimArch``es to co-sweep chip
    geometry with the mesh axes."""

    chips: tuple[CimArch, ...] = dataclasses.field(
        default_factory=lambda: (default_arch(),))
    n_chips: tuple[int, ...] = (1, 2, 4)
    link_bits: tuple[int, ...] = (128, 256)
    hop_latency: tuple[int, ...] = (4,)
    topologies: tuple[str, ...] = ("ring",)
    prefix: str = "mesh"

    @property
    def size(self) -> int:
        return (len(self.chips) * len(self.n_chips) * len(self.link_bits) *
                len(self.hop_latency) * len(self.topologies))

    def enumerate(self) -> list:
        from repro.core.arch import MeshLink
        from repro.core.mesh import make_mesh
        out = []
        for chip, n, bits, hl, topo in itertools.product(
                self.chips, self.n_chips, self.link_bits,
                self.hop_latency, self.topologies):
            name = (f"{self.prefix}-{chip.name}-n{n}-{topo}"
                    f"-lb{bits}-hl{hl}")
            out.append(make_mesh(chip, n,
                                 link=MeshLink(bandwidth_bits=bits,
                                               hop_latency_cycles=hl),
                                 topology=topo, name=name))
        return out


def run_mesh_dse(layers: Sequence[wl.Layer],
                 counts: Sequence[int] | None,
                 space: MeshSpace | Sequence,
                 mode: str = "miredo", *,
                 per_layer_cap_s: float = 10.0,
                 total_budget_s: float | None = None,
                 cache: ResultCache | None = None,
                 use_cache: bool = True,
                 workers: int | None = None,
                 validate_frontier: bool = True,
                 schedule_boundaries: Sequence[int] | None = None,
                 verbose: bool = False) -> DseResult:
    """Sweep a mesh grid against one workload: `run_dse`'s chip-count /
    link-bandwidth axes. Every mesh point runs through
    ``optimize_network(mesh=...)`` — 1-chip points take the single-chip
    path, multi-chip points the sharded mesh pipeline — and the frontier
    ranks (scheduled cycles, energy, mesh area = n_chips x chip area).

    No screening pass: the mesh grid multiplies a handful of link/count
    knobs onto each chip, and all sub-layer solves of every mesh sharing a
    chip hit the same chip-keyed records in the shared cache, so the MIP
    pass is already incremental where screening would help
    (``screen_points`` comes back empty, ``survivors`` is the whole grid).
    Frontier validation checks each record's mapping against the
    **sub-layer it actually maps** (the shard decomposition) on
    ``mesh.chip``. Returns a `DseResult` whose ``archs`` values are
    `mesh.MeshArch` instances."""
    from repro.core.mesh import shard_sub_layer
    from repro.core.network import optimize_network

    t0 = time.monotonic()
    layers = list(layers)
    counts = [1] * len(layers) if counts is None else list(counts)
    assert len(counts) == len(layers)
    grid = space.enumerate() if isinstance(space, MeshSpace) else list(space)
    names = [m.name for m in grid]
    assert len(set(names)) == len(names), f"duplicate mesh names: {names}"
    meshes = {m.name: m for m in grid}
    cache = cache if cache is not None else (
        ResultCache() if use_cache else None)

    networks: dict[str, NetworkResult] = {}
    for m in grid:
        networks[m.name] = optimize_network(
            layers, mesh=m, mode=mode, counts=counts, cache=cache,
            use_cache=use_cache, per_layer_cap_s=per_layer_cap_s,
            total_budget_s=total_budget_s, workers=workers,
            schedule_boundaries=schedule_boundaries, verbose=verbose)
        if verbose:
            net = networks[m.name]
            print(f"[mesh-dse] {m.name}: "
                  f"{(net.scheduled or net.totals)['cycles']:.4g} cycles",
                  flush=True)

    points = {
        n: DsePoint(arch_name=n,
                    cycles=(net.scheduled or net.totals)["cycles"],
                    energy_pj=(net.scheduled or net.totals)["energy_pj"],
                    area_bits=meshes[n].n_chips * area_proxy(meshes[n].chip),
                    fidelity="mip",
                    serial_cycles=net.totals["cycles"])
        for n, net in networks.items()}
    frontier = sorted(pareto_frontier(list(points.values())),
                      key=lambda p: (p.area_bits, p.cycles))

    validation: dict[str, list[str]] = {}
    if validate_frontier:
        for p in frontier:
            m, errs, seen = meshes[p.arch_name], [], set()
            for lr in networks[p.arch_name].layers:
                if lr.key in seen:
                    continue
                seen.add(lr.key)
                shard = lr.record.get("shard") or {}
                sub = shard_sub_layer(lr.layer,
                                      shard.get("choice", "replicate"),
                                      m.n_chips)
                mp = mapping_from_json(lr.record["mapping"])
                errs += [f"{lr.layer.name}: {e}"
                         for e in validate(mp, sub, m.chip)]
            validation[p.arch_name] = errs
    return DseResult(archs=meshes, screen_points={}, survivors=list(names),
                     pruned=[], networks=networks, points=points,
                     frontier=frontier, validation=validation,
                     wall_s=round(time.monotonic() - t0, 2))
