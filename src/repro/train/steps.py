"""Jittable train / serve step builders for every architecture.

``make_train_step``: cross-entropy LM loss (+ MoE aux), grad, AdamW update,
optional microbatch gradient accumulation (lax.scan) and cross-pod int8
gradient compression with error feedback. ``make_prefill_step`` /
``make_decode_step``: serving counterparts carrying KV caches / SSM states.

All steps are pure functions of (state, batch) so they pjit cleanly; the
dry-run lowers them with ShapeDtypeStructs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import transformer
from repro.models.attention import KVCache
from repro.models.ssm import SSMState, init_ssm_state, ssd_dims
from repro.runtime.compression import (compress_grads_with_feedback,
                                       init_residuals)
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_update,
                                   init_adamw)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    residuals: Any | None       # error-feedback state (pod-compression)
    rng: jax.Array


@dataclasses.dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    remat: bool = True
    use_flash: bool = False
    compress_pod_grads: bool = False
    compute_dtype: Any = jnp.bfloat16
    aux_loss_weight: float = 0.01


def lm_loss(params, cfg: ModelConfig, tokens, labels, *, shard,
            step_cfg: StepConfig, frontend=None):
    out = transformer.forward(
        params, cfg, tokens, mode="train", shard=shard,
        use_flash=step_cfg.use_flash, remat=step_cfg.remat,
        compute_dtype=step_cfg.compute_dtype, frontend_embeds=frontend)
    logits = out.logits.astype(jnp.float32)        # (B, L, V) vocab-sharded
    # Cross-entropy that keeps the vocab axis sharded: label logit via a
    # one-hot contraction (partitions under TP; take_along_axis would force
    # an all-gather of the full fp32 logits) + stable logsumexp whose
    # max/sum reductions partition into small cross-model collectives.
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.squeeze(m, -1) + jnp.log(
        jnp.sum(jnp.exp(logits - m), axis=-1))
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("blv,blv->bl", logits, onehot)
    ll = label_logit - lse
    mask = (labels >= 0).astype(jnp.float32)
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + step_cfg.aux_loss_weight * out.aux_loss
    return total, {"loss": loss, "aux_loss": out.aux_loss}


def make_train_step(cfg: ModelConfig, opt_cfg: OptimizerConfig,
                    step_cfg: StepConfig, shard=None):
    shard = shard or (lambda name, x: x)

    def grads_of(params, tokens, labels, frontend):
        (loss, metrics), grads = jax.value_and_grad(
            lm_loss, has_aux=True)(params, cfg, tokens, labels, shard=shard,
                                   step_cfg=step_cfg, frontend=frontend)
        return grads, loss, metrics

    def train_step(state: TrainState, batch: dict):
        tokens = batch["tokens"]
        labels = batch["labels"]
        frontend = batch.get("frontend")
        mb = step_cfg.microbatches
        if mb > 1:
            def mb_split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            mb_tok, mb_lab = mb_split(tokens), mb_split(labels)
            mb_fr = mb_split(frontend) if frontend is not None else None

            def acc_body(carry, xs):
                g_acc, l_acc = carry
                if mb_fr is not None:
                    t, l, fr = xs
                else:
                    (t, l), fr = xs, None
                g, loss, _ = grads_of(state.params, t, l, fr)
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 state.params)
            xs = (mb_tok, mb_lab, mb_fr) if mb_fr is not None \
                else (mb_tok, mb_lab)
            (grads, loss_sum), _ = jax.lax.scan(acc_body, (zeros, 0.0), xs)
            grads = jax.tree.map(lambda g: g / mb, grads)
            metrics = {"loss": loss_sum / mb,
                       "aux_loss": jnp.zeros((), jnp.float32)}
        else:
            grads, loss, metrics = grads_of(state.params, tokens, labels,
                                            frontend)
        residuals = state.residuals
        if step_cfg.compress_pod_grads and residuals is not None:
            grads, residuals = compress_grads_with_feedback(grads, residuals)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = {**metrics, **opt_metrics}
        new_state = TrainState(params=new_params, opt=new_opt,
                               residuals=residuals,
                               rng=jax.random.fold_in(state.rng, 1))
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, step_cfg: StepConfig,
                     param_dtype=jnp.float32) -> TrainState:
    params = transformer.init_model(key, cfg, param_dtype)
    return TrainState(
        params=params,
        opt=init_adamw(params),
        residuals=init_residuals(params)
        if step_cfg.compress_pod_grads else None,
        rng=key)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, step_cfg: StepConfig, shard=None):
    shard = shard or (lambda name, x: x)

    def prefill(params, batch):
        out = transformer.forward(
            params, cfg, batch["tokens"], mode="prefill", shard=shard,
            use_flash=step_cfg.use_flash,
            compute_dtype=step_cfg.compute_dtype,
            frontend_embeds=batch.get("frontend"))
        last = out.logits[:, -1]
        return last, out.caches

    return prefill


def make_decode_step(cfg: ModelConfig, step_cfg: StepConfig, shard=None):
    shard = shard or (lambda name, x: x)

    def decode(params, batch, caches):
        out = transformer.forward(
            params, cfg, batch["tokens"], mode="decode", caches=caches,
            shard=shard, compute_dtype=step_cfg.compute_dtype)
        return out.logits[:, -1], out.caches

    return decode


def _kv_cache_stack(n: int, batch: int, max_seq: int, kv: int, hd: int,
                    compute_dtype):
    import repro.models.attention as attn_mod
    if attn_mod.KV_QUANT:
        return KVCache(
            k=jnp.zeros((n, batch, max_seq, kv, hd), jnp.int8),
            v=jnp.zeros((n, batch, max_seq, kv, hd), jnp.int8),
            length=jnp.zeros((n, batch), jnp.int32),
            k_scale=jnp.zeros((n, batch, max_seq, kv, 1), jnp.float32),
            v_scale=jnp.zeros((n, batch, max_seq, kv, 1), jnp.float32))
    return KVCache(
        k=jnp.zeros((n, batch, max_seq, kv, hd), compute_dtype),
        v=jnp.zeros((n, batch, max_seq, kv, hd), compute_dtype),
        length=jnp.zeros((n, batch), jnp.int32))


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                compute_dtype=jnp.bfloat16):
    """Decode-mode cache pytree (zeros), family-dependent."""
    fam = cfg.family
    hd = cfg.resolved_head_dim
    if fam in ("dense", "moe", "vlm"):
        return _kv_cache_stack(cfg.n_layers, batch, max_seq,
                               cfg.n_kv_heads, hd, compute_dtype)
    if fam == "ssm":
        st = init_ssm_state(batch, cfg, cfg.d_model)
        return jax.tree.map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), st)
    if fam == "hybrid":
        st = init_ssm_state(batch, cfg, cfg.d_model)
        states = jax.tree.map(
            lambda t: jnp.zeros((cfg.n_layers,) + t.shape, t.dtype), st)
        n_groups = cfg.n_layers // cfg.attn_every
        kv = KVCache(
            k=jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads, hd),
                        compute_dtype),
            v=jnp.zeros((n_groups, batch, max_seq, cfg.n_kv_heads, hd),
                        compute_dtype),
            length=jnp.zeros((n_groups, batch), jnp.int32))
        return (states, kv)
    if fam == "encdec":
        kv = KVCache(
            k=jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                        compute_dtype),
            v=jnp.zeros((cfg.n_layers, batch, max_seq, cfg.n_kv_heads, hd),
                        compute_dtype),
            length=jnp.zeros((cfg.n_layers, batch), jnp.int32))
        mem = cfg.frontend_seq or 1024
        cross = (jnp.zeros((cfg.n_layers, batch, mem, cfg.n_kv_heads, hd),
                           compute_dtype),
                 jnp.zeros((cfg.n_layers, batch, mem, cfg.n_kv_heads, hd),
                           compute_dtype))
        memory = jnp.zeros((batch, mem, cfg.d_model), compute_dtype)
        return (kv, cross, memory)
    raise ValueError(fam)
