"""AdamW + LR schedules (cosine, and MiniCPM's WSD warmup-stable-decay),
hand-rolled on pytrees (no optax in this environment).

Optimizer state shards exactly like parameters (FSDP): the step functions
pass the params' shardings through to m/v.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


# Optimizer-moment storage dtype (module switch, perf-harness pattern):
# fp32 is the baseline; bf16 halves optimizer HBM at ~equal convergence
# (stochastic-rounding-free bf16 moments are standard at this scale).
OPT_STATE_DTYPE = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | wsd | constant
    wsd_stable_frac: float = 0.8      # WSD: fraction of steps at peak LR
    min_lr_frac: float = 0.1


def schedule_lr(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((s - cfg.warmup_steps) /
                 max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
            (1 + jnp.cos(math.pi * t))
    elif cfg.schedule == "wsd":
        # MiniCPM warmup-stable-decay: hold peak LR, then fast 1-cos decay
        stable_end = cfg.wsd_stable_frac
        d = jnp.clip((t - stable_end) / max(1 - stable_end, 1e-6), 0.0, 1.0)
        decay = jnp.where(t < stable_end, 1.0,
                          cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 *
                          (1 + jnp.cos(math.pi * d)))
    else:
        decay = jnp.ones_like(t)
    return cfg.lr * warm * decay


def init_adamw(params) -> AdamWState:
    zeros = lambda: jax.tree.map(
        lambda p: jnp.zeros(p.shape, OPT_STATE_DTYPE
                            if p.dtype == jnp.float32 else p.dtype), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params, grads,
                 state: AdamWState):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(m.dtype), v32.astype(v.dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), \
        {"lr": lr, "grad_norm": gnorm}
