"""Network-level pipeline (core/network.py) + library cache (core/cache.py):
dedup, global budget allocation, warm-start feasibility under time caps, and
cache key completeness / round-trips."""

import dataclasses
import math

import pytest

from repro.core.arch import default_arch
from repro.core.cache import (ResultCache, config_cache_key, layer_cache_key,
                              mapping_from_json, mapping_to_json,
                              solve_cached, solve_layer, solve_record_key)
from repro.core.formulation import FormulationConfig
from repro.core.mapping import validate
from repro.core.network import (allocate_budgets, dedup_layers,
                                optimize_network)
from repro.core.workload import conv, gemm

ARCH = default_arch()
TINY = gemm("tiny", 32, 64, 64)


# ---------------------------------------------------------------------------
# Dedup
# ---------------------------------------------------------------------------

def test_dedup_structural_identity():
    a = gemm("block0.ffn", 64, 128, 256)
    b = gemm("block7.ffn", 64, 128, 256)       # same shape, different name
    c = gemm("other", 64, 128, 512)
    unique, keys = dedup_layers([a, b, c])
    assert [l.name for l in unique] == ["block0.ffn", "other"]
    assert keys[0] == keys[1] != keys[2]
    assert layer_cache_key(a) == layer_cache_key(b)


def test_dedup_respects_stride():
    a = conv("x", 1, 8, 8, 4, 4, 3, 3, stride=1)
    b = conv("y", 1, 8, 8, 4, 4, 3, 3, stride=2)
    assert layer_cache_key(a) != layer_cache_key(b)


def test_two_identical_layers_one_solve_shared_mapping():
    a = gemm("l0", 32, 64, 64)
    b = gemm("l5", 32, 64, 64)
    res = optimize_network([a, b], ARCH, "greedy", use_cache=False)
    assert res.n_unique == 1 and res.n_solved == 1
    r0, r1 = res.layers[0].record, res.layers[1].record
    # shared mapping, re-scored per layer: identical numbers, own names
    assert r0["mapping"] == r1["mapping"]
    assert r0["cycles"] == r1["cycles"] and r0["edp"] == r1["edp"]
    assert r0["layer"] == "l0" and r1["layer"] == "l5"
    mp = mapping_from_json(r0["mapping"])
    assert not validate(mp, a, ARCH) and not validate(mp, b, ARCH)


def test_counts_scale_aggregates():
    a = gemm("a", 32, 64, 64)
    res1 = optimize_network([a], ARCH, "greedy", use_cache=False)
    res4 = optimize_network([a], ARCH, "greedy", counts=[4],
                            use_cache=False)
    assert res4.totals["cycles"] == pytest.approx(4 * res1.totals["cycles"])
    assert res4.totals["edp"] == pytest.approx(4 * res1.totals["edp"])


# ---------------------------------------------------------------------------
# Budget allocation
# ---------------------------------------------------------------------------

LAYERS = [gemm("big", 512, 512, 512), gemm("mid", 128, 128, 128),
          gemm("small", 8, 8, 8)]


def test_budgets_sum_to_global_budget():
    for total in (12.0, 30.0, 100.0, 7.0):
        b = allocate_budgets(LAYERS, total, min_s=2.0, max_s=60.0)
        assert sum(b) == pytest.approx(total)
    # floors + weighted remainder still sum exactly
    b = allocate_budgets(LAYERS, 20.0, min_s=5.0, max_s=60.0)
    assert sum(b) == pytest.approx(20.0)
    assert b[2] == pytest.approx(5.0)          # tiny layer pinned to floor


def test_budgets_weighted_by_macs_and_clamped():
    b = allocate_budgets(LAYERS, 30.0, min_s=2.0, max_s=20.0)
    assert b[0] >= b[1] >= b[2] >= 2.0
    assert max(b) <= 20.0
    # below the affordable floor: even split, sum preserved
    b = allocate_budgets(LAYERS, 3.0, min_s=2.0, max_s=20.0)
    assert b == [1.0, 1.0, 1.0]
    # above all caps: everyone capped (sum intentionally < total)
    b = allocate_budgets(LAYERS, 1000.0, min_s=2.0, max_s=20.0)
    assert b == [20.0, 20.0, 20.0]
    assert allocate_budgets([], 10.0) == []


# ---------------------------------------------------------------------------
# Warm start under time caps
# ---------------------------------------------------------------------------

def test_time_capped_mip_always_returns_feasible_mapping():
    # a cap far below what the solver needs: the greedy/heuristic incumbent
    # must come back as the mapping (never None)
    res = optimize_network([TINY], ARCH, "miredo", per_layer_cap_s=0.2,
                           use_cache=False, workers=1)
    rec = res.layers[0].record
    assert rec["mapping"] is not None
    mp = mapping_from_json(rec["mapping"])
    assert not validate(mp, TINY, ARCH)
    assert math.isfinite(rec["cycles"]) and rec["cycles"] > 0


def test_solve_layer_ws_time_capped_feasible():
    cfg = FormulationConfig(time_limit_s=0.2)
    rec = solve_layer(TINY, ARCH, "ws", cfg)
    assert rec["mapping"] is not None and rec["status"]


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def test_cache_round_trip_equals_fresh_solve(tmp_path):
    cache = ResultCache(str(tmp_path))
    cfg = FormulationConfig(time_limit_s=1.0)
    fresh = solve_layer(TINY, ARCH, "greedy", cfg)
    first = solve_cached(TINY, ARCH, "greedy", cfg, cache=cache)
    again = solve_cached(TINY, ARCH, "greedy", cfg, cache=cache)
    assert first == again                     # served from disk
    for k in ("cycles", "energy_pj", "edp", "mapping", "status"):
        assert first[k] == fresh[k], k
    # mapping JSON round-trips to the identical Mapping
    mp = mapping_from_json(first["mapping"])
    assert mapping_to_json(mp) == first["mapping"]


def test_pipeline_cache_hits(tmp_path):
    cache = ResultCache(str(tmp_path))
    layers = [gemm("a", 32, 64, 64), gemm("b", 32, 64, 64),
              gemm("c", 16, 64, 64)]
    r1 = optimize_network(layers, ARCH, "greedy", cache=cache)
    assert (r1.n_solved, r1.cache_hits) == (2, 0)
    r2 = optimize_network(layers, ARCH, "greedy", cache=cache)
    assert (r2.n_solved, r2.cache_hits) == (0, 2)
    assert r2.totals == r1.totals


def test_cache_key_covers_all_config_fields():
    """The seed's key ignored mu1/mu2_frac/latency_slack/mip_rel_gap/
    combo_cap — changing objective weights silently returned stale
    mappings. Every result-affecting field must now change the key."""
    base = FormulationConfig()
    for field, value in [
        ("alpha", 0.5), ("k_min", 2), ("mu1", 2.0), ("mu2_frac", 0.1),
        ("time_limit_s", 10.0), ("mip_rel_gap", 0.2), ("combo_cap", 999),
        ("latency_slack", 4.0), ("weight_stationary", True),
    ]:
        changed = dataclasses.replace(base, **{field: value})
        assert config_cache_key(changed) != config_cache_key(base), field
        assert solve_record_key("miredo", TINY, ARCH, changed) != \
            solve_record_key("miredo", TINY, ARCH, base), field
    # verbose has no effect on the result -> same key
    assert config_cache_key(dataclasses.replace(base, verbose=True)) == \
        config_cache_key(base)


def test_cache_key_canonicalizes_aliased_latency_slack():
    """``latency_slack`` values at or below BIG_M_FLOOR all build the same
    big-M (``max(slack, floor) * UB``) — they are result-aliased, so they
    must digest to ONE cache key; values above the floor stay distinct."""
    from repro.core.formulation import BIG_M_FLOOR
    base = FormulationConfig()          # default slack == 8.0, above floor
    at_floor = dataclasses.replace(base, latency_slack=BIG_M_FLOOR)
    below = dataclasses.replace(base, latency_slack=1.0)
    lower = dataclasses.replace(base, latency_slack=2.0)
    assert config_cache_key(at_floor) == config_cache_key(below) == \
        config_cache_key(lower)
    assert config_cache_key(base) != config_cache_key(at_floor)
    assert solve_record_key("miredo", TINY, ARCH, below) == \
        solve_record_key("miredo", TINY, ARCH, at_floor)
    assert solve_record_key("miredo", TINY, ARCH, base) != \
        solve_record_key("miredo", TINY, ARCH, at_floor)


def test_baseline_mode_keys_ignore_solver_budget():
    """Heuristic/greedy solves don't consume the MIP budget: their cache
    keys must not change with it (else every benchmark budget re-runs the
    same 2000-sample searches)."""
    a = FormulationConfig(time_limit_s=60.0)
    b = dataclasses.replace(a, time_limit_s=45.0, mu1=2.0,
                            latency_slack=4.0)
    for mode in ("heuristic", "greedy", "random"):
        assert solve_record_key(mode, TINY, ARCH, a) == \
            solve_record_key(mode, TINY, ARCH, b), mode
    # ...but factorization knobs still matter for the sampled searches
    c = dataclasses.replace(a, alpha=0.9)
    assert solve_record_key("heuristic", TINY, ARCH, c) != \
        solve_record_key("heuristic", TINY, ARCH, a)
    # and MIP modes keep budget sensitivity
    assert solve_record_key("miredo", TINY, ARCH, a) != \
        solve_record_key("miredo", TINY, ARCH, b)


def test_stale_cache_not_served_across_configs(tmp_path):
    cache = ResultCache(str(tmp_path))
    a = FormulationConfig(time_limit_s=1.0)
    b = dataclasses.replace(a, mu1=3.0)       # objective weight changed
    cache.put(solve_record_key("miredo", TINY, ARCH, a), {"stub": 1})
    assert cache.get(solve_record_key("miredo", TINY, ARCH, a)) is not None
    assert cache.get(solve_record_key("miredo", TINY, ARCH, b)) is None
