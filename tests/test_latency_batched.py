"""Differential sweep: the batched analytical model vs the scalar oracle.

`latency_batched` promises *bit-equality* with `latency.evaluate` /
`energy.evaluate_edp` (DESIGN.md §Batched analytical model): every float op
replayed in the scalar order under float64, padding provably inert. These
tests enforce the promise with exact ``==`` — no tolerances — across random
(layer, arch, pool) draws on both backends, including the edge cases the
packing has to get right:

  * mixed slot counts in one pool (right-aligned identity padding),
  * operands with no transfers at all (DRAM-resident level chains),
  * weight hops into the macro level (mode-switch cycles),
  * capacity-infeasible rows (gated packs must return ``inf``; ungated
    packs must still reproduce the scalar numbers for those rows).

Runs under ``hypothesis`` when available, else the seeded-random shim from
``tests/test_mapping_fuzz.py``. Also holds the `baselines._assign_levels`
shared-level budget regression (the fair-share fix this PR lands).
"""

import math
import random

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(fn, "_max_examples", 25)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

from repro.core import latency_batched as lb
from repro.core import workload as wl
from repro.core.arch import default_arch
from repro.core.baselines import greedy_mapping, sample_mapping_raw
from repro.core.energy import evaluate_edp
from repro.core.factorization import factorize_layer_dims
from repro.core.latency import idealized_cycles
from repro.core.mapping import validate

#: Same arch grid as the mapping fuzz: spans core count, macro geometry,
#: buffer capacities and the double-buffering policy.
ARCHS = (
    default_arch(),
    default_arch(n_cores=2, macro_rows=64, macro_cols=16, gbuf_kb=2.0,
                 lbuf_kb=8.0, name="lb-tiny"),
    default_arch(double_buffered=False, name="lb-single-buf"),
    default_arch(n_cores=4, macro_rows=256, macro_cols=64, lbuf_kb=16.0,
                 reg_bytes=512, name="lb-wide"),
)
BACKENDS = ("numpy",) + (("jax",) if lb.HAVE_JAX else ())
DIM_CHOICES = (3, 8, 24, 100, 128, 360)


def _layer(kind: int, a: int, b: int, c: int) -> wl.Layer:
    if kind == 0:
        return wl.gemm("lb.gemm", a, b, c)
    return wl.conv("lb.conv", 1, a, c, min(b, 28), min(b, 28), 3, 3)


def _pool(layer, arch, n, seed):
    """greedy (few slots) + raw samples (varying slots, ~90% capacity-
    infeasible): one pool exercises mixed slot counts, padded rows, macro
    weight hops and the gated-inf path all at once."""
    rng = random.Random(seed)
    factors = factorize_layer_dims({d: layer.bound(d) for d in wl.DIMS})
    return [greedy_mapping(layer, arch)] + [
        sample_mapping_raw(layer, arch, rng, factors) for _ in range(n)]


def _assert_rows_exact(sc, pool, layer, arch, feas, backend):
    for i, mp in enumerate(pool):
        where = f"{arch.name}/{layer.name}/{backend} row {i}"
        if feas[i]:
            e = evaluate_edp(mp, layer, arch)
            assert float(sc.cycles[i]) == e.latency.total_cycles, where
            assert float(sc.energy_pj[i]) == e.energy.total_pj, where
            assert float(sc.edp[i]) == e.edp, where
            assert float(sc.idealized[i]) == \
                idealized_cycles(mp, layer, arch), where
        else:
            assert math.isinf(float(sc.cycles[i])), where
            assert math.isinf(float(sc.edp[i])), where


@given(st.integers(0, 1),
       st.sampled_from(DIM_CHOICES), st.sampled_from(DIM_CHOICES),
       st.sampled_from(DIM_CHOICES), st.integers(0, len(ARCHS) - 1),
       st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_differential_sweep_exact(kind, a, b, c, ai, seed):
    """Every batched score equals the scalar oracle bit-for-bit; the
    feasibility vector equals ``validate``'s verdict (sampler-constructed
    candidates can only violate the eq. 9 clause the gate checks)."""
    layer, arch = _layer(kind, a, b, c), ARCHS[ai]
    pool = _pool(layer, arch, 24, seed)
    feas = [not validate(mp, layer, arch) for mp in pool]
    for backend in BACKENDS:
        sc = lb.score_mappings(pool, layer, arch, backend=backend)
        assert list(map(bool, sc.feasible)) == feas
        _assert_rows_exact(sc, pool, layer, arch, feas, backend)


def test_mixed_slot_counts_padding_inert():
    """A mapping's scores are identical whether it is scored alone or
    packed into a pool of mappings with different slot counts — the
    right-aligned identity padding and the slot/batch bucketing must be
    arithmetically invisible."""
    layer, arch = wl.gemm("lb.pad", 32, 512, 512), ARCHS[0]
    pool = _pool(layer, arch, 40, seed=3)
    assert len({mp.n_slots() for mp in pool}) > 1, "pool must mix widths"
    feas = [not validate(mp, layer, arch) for mp in pool]
    for backend in BACKENDS:
        together = lb.score_mappings(pool, layer, arch, backend=backend)
        for i in (0, len(pool) // 2, len(pool) - 1):
            alone = lb.score_mappings([pool[i]], layer, arch,
                                      backend=backend)
            for field in ("cycles", "energy_pj", "edp", "idealized"):
                t = float(getattr(together, field)[i])
                s = float(getattr(alone, field)[0])
                assert t == s or (math.isinf(t) and math.isinf(s)), \
                    (backend, i, field, t, s)
        _assert_rows_exact(together, pool, layer, arch, feas, backend)


def test_ungated_pack_scores_infeasible_rows():
    """Omitting 'feasible' from ``need`` disables the capacity gate: every
    row — including capacity-violating ones — must reproduce the scalar
    model's numbers (the analytical recursion is defined regardless of
    eq. 9; gating is a scoring policy, not a model property)."""
    layer, arch = wl.gemm("lb.ungated", 32, 512, 512), ARCHS[1]
    pool = _pool(layer, arch, 30, seed=5)
    infeasible = [mp for mp in pool if validate(mp, layer, arch)]
    assert infeasible, "pool must contain capacity-infeasible rows"
    for backend in BACKENDS:
        pb = lb.pack(pool, layer, arch, need=("latency", "energy"))
        assert not pb.gated
        sc = lb.evaluate_batch(pb, backend=backend)
        for i, mp in enumerate(pool):
            e = evaluate_edp(mp, layer, arch)
            assert float(sc.cycles[i]) == e.latency.total_cycles
            assert float(sc.energy_pj[i]) == e.energy.total_pj


@pytest.mark.skipif(not lb.HAVE_JAX, reason="jax not installed")
def test_backends_bitwise_equal():
    """numpy and jax backends agree bitwise on the whole score vector —
    the auto-backend cutover (`_JAX_MIN_BATCH`) can never change results."""
    layer, arch = wl.conv("lb.be", 1, 64, 64, 14, 14, 3, 3), ARCHS[3]
    pool = _pool(layer, arch, 50, seed=11)
    a = lb.score_mappings(pool, layer, arch, backend="numpy")
    b = lb.score_mappings(pool, layer, arch, backend="jax")
    for field in ("cycles", "energy_pj", "edp", "idealized", "feasible"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), field


def test_empty_and_singleton_pools():
    layer, arch = wl.gemm("lb.edge", 8, 64, 64), ARCHS[0]
    sc = lb.score_mappings([], layer, arch)
    assert len(sc.cycles) == 0 and len(sc.feasible) == 0
    g = greedy_mapping(layer, arch)
    e = evaluate_edp(g, layer, arch)
    for backend in BACKENDS:
        one = lb.score_mappings([g], layer, arch, backend=backend)
        assert bool(one.feasible[0])
        assert float(one.cycles[0]) == e.latency.total_cycles
        assert float(one.edp[0]) == e.edp


def test_assign_levels_shared_budget_regression():
    """`baselines._assign_levels` must budget shared levels at a fair
    share per served operand. The old expression (``cap if shared else
    cap``) granted full capacity to each operand in isolation, the
    combined placement over-committed the level, final validation failed
    and greedy fell back to streaming everything from DRAM. On this
    pinned config the fixed sweep keeps at least one non-weight operand
    on-chip; the all-DRAM fallback is the regression signature."""
    arch = default_arch(gbuf_kb=0.5, lbuf_kb=2.0, reg_bytes=128,
                        name="lb-shared-tight")
    layer = wl.gemm("lb.shared", 32, 512, 512)
    mp = greedy_mapping(layer, arch)
    assert validate(mp, layer, arch) == []
    on_chip = any(m != 0 for lam in ("I", "O")
                  for m in mp.level_of[lam])
    assert on_chip, ("greedy hit the all-DRAM fallback: the shared-level "
                     "capacity sweep over-committed (fair-share budget "
                     "regression)")
    # the fair-share placement must still respect the hard eq. 9 bound
    for m in range(arch.n_levels):
        cap = mp.eff_capacity(arch, m)
        if cap is None or not arch.level(m).shared:
            continue
        used = sum(
            (2 if mp.is_double_buffered(lam, m, arch) else 1) *
            mp.stored_bytes(layer, lam, arch, m)
            for lam in mp.level_of
            if m in mp.used_levels(lam) and arch.serves(m, lam))
        assert used <= cap + 1e-6, (m, used, cap)
