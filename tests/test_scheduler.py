"""Network-level multi-core scheduler (core/scheduler.py): segment packing
respects macro capacity, the MIP core allocation never loses to the greedy
water-filling fallback, scheduled latency never exceeds the serial sum on
any zoo workload (and strictly beats it where segments pack), and the
network-mode event simulator agrees with the analytical schedule model —
the Fig. 4(a) discipline of test_latency_model.py, one level up."""

import math

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.arch import core_axis, default_arch, with_cores
from repro.core.baselines import greedy_mapping
from repro.core.cache import CACHE_VERSION, solve_record_key
from repro.core.formulation import FormulationConfig
from repro.core.frontend import extract_workload
from repro.core.latency import evaluate
from repro.core.network import optimize_network
from repro.core.scheduler import (chip_macro_bytes, cross_check,
                                  schedule_network, weight_bytes,
                                  weight_residency)
from repro.core.simulator import simulate_segment
from repro.core.workload import (MODEL_ZOO, RESNET18_MULTIPLICITY, gemm,
                                 resnet18)

ARCH = default_arch()
N_CORES = core_axis(ARCH).size
TINY = gemm("tiny", 32, 64, 64)


def _net(layers, counts=None, mode="greedy", **kw):
    return optimize_network(layers, ARCH, mode, counts=counts,
                            use_cache=False, workers=1, **kw)


def _decode_workload(arch_id="minicpm-2b", batch=4):
    cfg = get_config(arch_id).reduced()
    spec = ShapeSpec("serve_decode", seq_len=1, global_batch=batch,
                     kind="decode")
    return extract_workload(cfg, spec)


# ---------------------------------------------------------------------------
# Weight residency
# ---------------------------------------------------------------------------

def test_weight_residency_is_the_one_time_weight_share():
    layer = gemm("g", 8, 64, 64)
    mp = greedy_mapping(layer, ARCH)
    resident, fill = weight_residency(mp, layer, ARCH)
    assert resident, "tiny GEMM weights must be stationary under greedy"
    rep = evaluate(mp, layer, ARCH)
    # the weight share of the one-time fills: positive (there IS a program-
    # in, including the mode switch) and never more than all one-time fills
    assert ARCH.mode_switch_cycles <= fill <= rep.one_time_cycles
    assert rep.total_cycles - fill >= 1.0


def test_weight_bytes_is_the_kcfyfx_footprint():
    assert weight_bytes(gemm("g", 7, 64, 128)) == 64 * 128
    assert chip_macro_bytes(ARCH) == \
        N_CORES * ARCH.macro_rows * ARCH.macro_cols


# ---------------------------------------------------------------------------
# Segment packing
# ---------------------------------------------------------------------------

def test_segment_packing_respects_macro_capacity():
    work = _decode_workload()
    net = _net(list(work.layers), list(work.counts))
    chip = chip_macro_bytes(ARCH)
    core_bytes = chip // N_CORES
    assert net.schedule.segments, "no segments produced"
    for seg in net.schedule.segments:
        if seg.mode != "pipelined":
            continue
        # all resident weights fit the chip's macros simultaneously...
        assert sum(st.load_bytes for st in seg.stages) <= chip
        # ...the core split fits the core axis...
        assert sum(st.cores for st in seg.stages) <= N_CORES
        # ...and every stage's weights fit its own cores' macros
        for st in seg.stages:
            assert 1 <= st.cores
            assert st.load_bytes <= st.cores * core_bytes


def test_oversized_layer_is_a_serial_singleton():
    # 2048x2048 weights = 4 MiB >> the chip's 32 KiB of macro cells
    big = gemm("big", 8, 2048, 2048)
    assert weight_bytes(big) > chip_macro_bytes(ARCH)
    net = _net([big, TINY, big])
    segs = net.schedule.segments
    for seg in segs:
        if any(st.name == "big" for st in seg.stages):
            assert len(seg.stages) == 1 and seg.mode == "serial"


def test_non_resident_mapping_never_packs():
    # force a non-resident weight mapping: stream everything from DRAM with
    # a weight-relevant loop above the macro hop
    layer = gemm("nr", 4, 64, 64)
    from repro.core.mapping import Mapping
    mp = Mapping(spatial={ax.name: () for ax in ARCH.spatial},
                 temporal=(("C", 64), ("K", 64), ("N", 4)),
                 level_of={"I": (0, 0, 0), "W": (0, 0, ARCH.macro_level),
                           "O": (0, 0, 0)},
                 double_buf=frozenset())
    resident, fill = weight_residency(mp, layer, ARCH)
    assert not resident and fill == 0.0


# ---------------------------------------------------------------------------
# Core allocation: MIP vs greedy fallback
# ---------------------------------------------------------------------------

def test_mip_allocation_never_loses_to_greedy():
    work = _decode_workload()
    net = _net(list(work.layers), list(work.counts))
    with_mip = schedule_network(net.layers, ARCH, use_mip=True)
    greedy_only = schedule_network(net.layers, ARCH, use_mip=False)
    assert with_mip.scheduled_cycles <= greedy_only.scheduled_cycles + 1e-6
    assert with_mip.serial_cycles == pytest.approx(
        greedy_only.serial_cycles)


def test_allocation_uses_spare_cores_across_plateaus():
    # a solo stage whose weights need only 1 core must still be granted
    # more cores when they genuinely speed it up (factor staircase)
    layer = gemm("solo", 128, 64, 64)
    net = _net([layer], counts=[2])
    (seg,) = net.schedule.segments
    if seg.mode == "pipelined":
        arch_1 = with_cores(ARCH, 1)
        one = evaluate(greedy_mapping(layer, arch_1), layer,
                       arch_1).total_cycles
        full = evaluate(greedy_mapping(layer, ARCH), layer,
                        ARCH).total_cycles
        if one > full:                 # cores matter for this shape
            assert seg.stages[0].cores > seg.stages[0].c_min


# ---------------------------------------------------------------------------
# Scheduled <= serial, strict wins where packing engages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", sorted(MODEL_ZOO))
def test_scheduled_never_worse_than_serial_conv_zoo(model):
    layers = MODEL_ZOO[model]()
    counts = [RESNET18_MULTIPLICITY.get(l.name, 1) for l in layers] \
        if model == "resnet18" else None
    net = _net(layers, counts)
    assert net.scheduled is not None
    assert net.scheduled["cycles"] <= net.totals["cycles"] + 1e-6
    assert net.scheduled["serial_cycles"] == pytest.approx(
        net.totals["cycles"])


@pytest.mark.parametrize("arch_id", ["minicpm-2b", "glm4-9b",
                                     "mamba2-1.3b"])
def test_reduced_lm_decode_strictly_beats_serial(arch_id):
    work = _decode_workload(arch_id)
    net = _net(list(work.layers), list(work.counts))
    assert net.schedule.n_packed >= 1, "decode workload must pack"
    assert net.scheduled["cycles"] < net.totals["cycles"]
    # a packed segment's win includes at least the saved mode switches
    saved = net.totals["cycles"] - net.scheduled["cycles"]
    assert saved >= ARCH.mode_switch_cycles


def test_mip_mode_time_capped_also_schedules():
    # the acceptance path runs mode=miredo; a hard cap must still produce
    # a feasible, never-worse schedule (warm-start guarantee upstream),
    # and the reduced decode workload must pack under it
    work = _decode_workload(batch=128)          # = decode_32k's M
    net = _net(list(work.layers), list(work.counts), mode="miredo",
               per_layer_cap_s=0.5)
    assert net.scheduled["cycles"] < net.totals["cycles"]
    assert net.schedule.n_packed >= 1


def test_schedule_can_be_disabled():
    net = _net([TINY], schedule=False)
    assert net.scheduled is None and net.schedule is None


def test_boundaries_keep_independent_streams_apart():
    # two copies of the same stream, pooled: without a boundary the DP may
    # pack across the junction; with one, no segment spans index 2
    layers = [gemm("a", 4, 64, 64), gemm("b", 4, 64, 128)] * 2
    net = _net(layers, counts=[1] * 4,
               schedule_boundaries=[0, 2])
    starts, idx = [], 0
    for seg in net.schedule.segments:
        starts.append(idx)
        idx += len(seg.stages)
    assert idx == 4
    assert 2 in starts, f"segment crossed the stream boundary: {starts}"
    # boundaries never make the schedule worse than serial
    assert net.scheduled["cycles"] <= net.totals["cycles"] + 1e-6


def test_energy_follows_executed_mappings():
    work = _decode_workload()
    net = _net(list(work.layers), list(work.counts))
    s = net.scheduled
    delta = sum(seg.energy_delta_pj for seg in net.schedule.segments)
    assert s["energy_pj"] == pytest.approx(
        net.totals["energy_pj"] + delta)
    assert s["edp"] == pytest.approx(s["energy_pj"] * s["cycles"])
    # record-basis segments contribute no delta
    for seg in net.schedule.segments:
        if all(st.basis == "record" for st in seg.stages):
            assert seg.energy_delta_pj == 0.0


# ---------------------------------------------------------------------------
# Simulator agreement (network mode)
# ---------------------------------------------------------------------------

def test_simulate_segment_matches_pipeline_algebra():
    sw = ARCH.mode_switch_cycles
    # no weight bytes -> ready = mode switch only; classic 2-stage pipeline
    rep = simulate_segment([(3, 10.0, 0), (3, 10.0, 0)], ARCH)
    assert rep.total_cycles == sw + 10 + 10 + 2 * 10   # fill + bottleneck
    assert rep.load_cycles == 0.0
    # weight loads serialize on the DRAM bus
    bw = ARCH.level(0).bytes_per_cycle()
    rep = simulate_segment([(1, 5.0, 4096), (1, 5.0, 4096)], ARCH)
    assert rep.load_cycles == 2 * math.ceil(4096 / bw)
    assert rep.total_cycles >= rep.load_cycles


def test_simulator_agrees_with_analytical_schedule():
    """Mean network-mode accuracy over the packed segments of a reduced
    decode workload — gated at the same 0.8 the single-layer agreement
    test (Fig. 4(a) discipline) uses."""
    work = _decode_workload()
    net = _net(list(work.layers), list(work.counts))
    acc, n = cross_check(net.schedule, ARCH)
    assert n >= 1, "nothing to cross-check"
    assert acc > 0.8, acc


def test_analytical_segment_model_is_conservative():
    """The analytical pipelined cost serializes the whole segment load
    before compute; the event replay may overlap — so the model never
    reports fewer cycles than the replay."""
    work = _decode_workload("glm4-9b")
    net = _net(list(work.layers), list(work.counts))
    checked = 0
    for seg in net.schedule.segments:
        if seg.mode != "pipelined":
            continue
        sim = simulate_segment(
            [(st.count, st.t_cycles, st.load_bytes) for st in seg.stages],
            ARCH)
        assert seg.pipelined_cycles >= sim.total_cycles - 1e-6
        checked += 1
    assert checked >= 1


def test_segment_charge_covers_surplus_downstream_items():
    """Regression: when a downstream stage has MORE items than an upstream
    bottleneck stage, the surplus items serialize after the upstream's
    last item — the closed fill+bottleneck form misses that ((2,30)/(4,10)
    costs 90 compute cycles, the closed form says 70), so segments must be
    charged with the exact item recursion, which equals the replay's
    compute exactly."""
    from repro.core.scheduler import _exact_compute, _pipeline_compute
    from repro.core.simulator import stream_finish_times

    ts, counts = [30.0, 10.0], [2, 4]
    assert _pipeline_compute(ts, counts) == 30 + 10 + 3 * 10    # optimistic
    exact = _exact_compute(ts, counts)
    assert exact == max(stream_finish_times(counts, ts, [0.0, 0.0]))
    assert exact == 90.0        # 2x30 upstream, then 3 serialized 10s
    # analytic charge = load + exact >= the replay's total
    sim = simulate_segment([(2, 30.0, 8), (4, 10.0, 8)], ARCH)
    bw = ARCH.level(0).bytes_per_cycle()
    load = 2 * math.ceil(8 / bw) + ARCH.mode_switch_cycles
    assert load + exact >= sim.total_cycles


# ---------------------------------------------------------------------------
# Cache: pre-scheduler entries cannot serve
# ---------------------------------------------------------------------------

def test_cache_version_bumped_for_scheduler():
    assert CACHE_VERSION >= 4
    key = solve_record_key("miredo", TINY, ARCH, FormulationConfig())
    assert key.startswith(f"v{CACHE_VERSION}__")
    assert not key.startswith("v3__")      # v3-era records never match


# ---------------------------------------------------------------------------
# Resnet regression: schedule surfaces through NetworkResult
# ---------------------------------------------------------------------------

def test_network_result_scheduled_totals_shape():
    layers = resnet18()[:4]
    net = _net(layers)
    s = net.scheduled
    for k in ("cycles", "serial_cycles", "saved_cycles", "n_segments",
              "n_packed", "energy_pj", "edp"):
        assert k in s, k
    assert s["energy_pj"] == pytest.approx(net.totals["energy_pj"])
    assert s["edp"] == pytest.approx(s["energy_pj"] * s["cycles"])
    assert s["saved_cycles"] == pytest.approx(
        s["serial_cycles"] - s["cycles"])
