"""Per-kernel correctness: shape/dtype sweeps, Pallas interpret=True vs the
pure-jnp ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_bh
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.matmul_int8.kernel import matmul_int8
from repro.kernels.matmul_int8.ops import quantized_matmul
from repro.kernels.matmul_int8.ref import matmul_int8_ref, quantize_rowwise
from repro.kernels.ssd_scan.ops import ssd_intra_chunk
from repro.kernels.ssd_scan.ref import ssd_intra_chunk_ref


# ---------------------------------------------------------------------------
# matmul_int8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(32, 64, 32), (64, 128, 64),
                                   (128, 256, 128), (32, 512, 16)])
@pytest.mark.parametrize("bm,bk,bn", [(16, 32, 16), (32, 64, 32)])
def test_matmul_int8_shapes(m, k, n, bm, bk, bn):
    if m % bm or k % bk or n % bn:
        pytest.skip("non-divisible")
    rng = np.random.default_rng(0)
    x_q = rng.integers(-127, 128, (m, k)).astype(np.int8)
    w_q = rng.integers(-127, 128, (k, n)).astype(np.int8)
    sx = rng.uniform(0.01, 0.1, (m,)).astype(np.float32)
    sw = rng.uniform(0.01, 0.1, (n,)).astype(np.float32)
    out = matmul_int8(jnp.asarray(x_q), jnp.asarray(w_q), jnp.asarray(sx),
                      jnp.asarray(sw), bm=bm, bk=bk, bn=bn,
                      out_dtype=jnp.float32, interpret=True)
    ref = matmul_int8_ref(x_q, w_q, sx, sw, jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantized_matmul_close_to_fp(dtype):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((64, 128)), dtype)
    w = jnp.asarray(rng.standard_normal((128, 96)) * 0.1, dtype)
    out = quantized_matmul(x, w, use_kernel=True, interpret=True,
                           out_dtype=jnp.float32)
    exact = (x.astype(jnp.float32) @ w.astype(jnp.float32))
    # int8 quantization error bound (~1%)
    rel = np.linalg.norm(np.asarray(out) - np.asarray(exact)) / \
        np.linalg.norm(np.asarray(exact))
    assert rel < 0.03, rel


def test_quantized_matmul_bridge_padded_blocks():
    """Regression: bridge blocks for dims with no MXU-aligned divisor
    (n=360 -> bn=384 padded) must run through the kernel via zero-padding
    instead of tripping the divisibility assert."""
    from repro.core.tpu_bridge import select_matmul_blocks
    c = select_matmul_blocks(512, 256, 360)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((512, 256)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((256, 360)) * 0.1, jnp.float32)
    out = quantized_matmul(x, w, block_shapes=(c.bm, c.bk, c.bn),
                           use_kernel=True, interpret=True,
                           out_dtype=jnp.float32)
    assert out.shape == (512, 360)
    exact = x @ w
    rel = np.linalg.norm(np.asarray(out) - np.asarray(exact)) / \
        np.linalg.norm(np.asarray(exact))
    assert rel < 0.03, rel


@pytest.mark.parametrize("m,k,n", [(100, 200, 360), (8, 72, 100),
                                   (130, 24, 1000)])
def test_matmul_bridge_candidate_blocks_padded(m, k, n):
    """Golden numerics on dims with no MXU-aligned divisor: every bridge
    candidate pick must run through the kernel's zero-padding path and
    match the fp oracle (the executor's matmul dispatch contract)."""
    from repro.core.tpu_bridge import select_matmul_blocks
    c = select_matmul_blocks(m, k, n)
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, n)) * 0.1, jnp.float32)
    out = quantized_matmul(x, w, block_shapes=(c.bm, c.bk, c.bn),
                           use_kernel=True, interpret=True,
                           out_dtype=jnp.float32)
    assert out.shape == (m, n)
    exact = x @ w
    rel = np.linalg.norm(np.asarray(out) - np.asarray(exact)) / \
        np.linalg.norm(np.asarray(exact))
    assert rel < 0.03, rel


def test_matmul_mapping_derived_blocks():
    """Blocks derived from an optimized CIM mapping
    (`tpu_bridge.select_blocks_from_mapping`) are MXU-legal, capped, and
    numerically exact vs the int8 oracle on identical quantized operands."""
    from repro.core.arch import default_arch
    from repro.core.baselines import greedy_mapping
    from repro.core.tpu_bridge import select_blocks_from_mapping
    from repro.core.workload import gemm
    from repro.kernels.matmul_int8.ops import quantized_matmul_and_ref
    arch = default_arch()
    layer = gemm("t.g", 96, 360, 200)       # (96 x 200) @ (200 x 360)
    mp = greedy_mapping(layer, arch)
    c = select_blocks_from_mapping(mp, layer, arch, cap=128)
    assert c.bm % 8 == 0 and c.bk % 128 == 0 and c.bn % 128 == 0
    assert max(c.bm, c.bk, c.bn) <= 256    # cap + alignment floor
    assert 2 * c.vmem_bytes <= 64 * 1024 * 1024
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((96, 200)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((200, 360)) * 0.1, jnp.float32)
    out, ref = quantized_matmul_and_ref(x, w,
                                        block_shapes=(c.bm, c.bk, c.bn))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_quantize_roundtrip():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    q, s = quantize_rowwise(x, axis=1)
    back = q.astype(jnp.float32) * s[:, None]
    np.testing.assert_allclose(np.asarray(back), np.asarray(x),
                               atol=float(jnp.max(jnp.abs(x))) / 100)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("l,hd,bq,bk", [(128, 64, 32, 32), (256, 64, 64, 64),
                                        (128, 128, 64, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(l, hd, bq, bk, causal):
    rng = np.random.default_rng(3)
    b, h = 2, 2
    q = jnp.asarray(rng.standard_normal((b, l, h, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, l, h, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, l, h, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((1, 128, 2, 64)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                          interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=tol, atol=tol)


def test_flash_attention_legal_block_clamp():
    """Sequence lengths that are not 128-multiples (VLM prefill = text +
    patch tokens) must clamp the requested blocks to exact divisors instead
    of tripping the kernel's tiling assert."""
    from repro.kernels.flash_attention.ops import legal_block
    assert legal_block(264, 256) == 88           # largest 8-aligned divisor
    assert legal_block(96, 128) == 96
    assert legal_block(1, 128) == 1              # decode step (lq = 1)
    assert legal_block(7, 256) == 7              # no aligned divisor at all
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.standard_normal((1, 264, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 264, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 264, 2, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_decode_step_vs_cache():
    """The executor's decode dispatch: one query step (lq=1) against a
    longer KV cache, non-causal."""
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((4, 1, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((4, 256, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((4, 256, 2, 16)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ssd intra-chunk
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,h,n,p", [(32, 2, 16, 16), (64, 4, 32, 32),
                                     (128, 2, 64, 64)])
def test_ssd_intra_chunk_vs_ref(q, h, n, p):
    rng = np.random.default_rng(5)
    b, nc = 2, 2
    c = jnp.asarray(rng.standard_normal((b, nc, q, h, n)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, nc, q, h, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, nc, q, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    s = jnp.cumsum(dt * a, axis=2)
    x = jnp.asarray(rng.standard_normal((b, nc, q, h, p)), jnp.float32)
    out = ssd_intra_chunk(c, bb, s, dt, x, interpret=True)
    ref = ssd_intra_chunk_ref(c, bb, s, dt, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_intra_chunk_and_ref_helper():
    """The executor's fused SSD dispatch (`ssd_intra_chunk_and_ref`) on an
    odd chunk length: kernel and oracle on identical inputs."""
    from repro.kernels.ssd_scan.ops import ssd_intra_chunk_and_ref
    rng = np.random.default_rng(12)
    b, nc, q, h, n, p = 1, 1, 24, 1, 8, 8
    c = jnp.asarray(rng.standard_normal((b, nc, q, h, n)), jnp.float32)
    bb = jnp.asarray(rng.standard_normal((b, nc, q, h, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, nc, q, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    s = jnp.cumsum(dt * a, axis=2)
    x = jnp.asarray(rng.standard_normal((b, nc, q, h, p)), jnp.float32)
    out, ref = ssd_intra_chunk_and_ref(c, bb, s, dt, x, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_ssd_chunked_matches_sequential():
    """End-to-end SSD (chunked algorithm incl. inter-chunk recurrence) vs
    the step-by-step recurrence oracle."""
    from repro.kernels.ssd_scan.ref import ssd_sequential_ref
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(6)
    b, l, h, p, g, n = 2, 64, 4, 16, 1, 16
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (b, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    y, hf = ssd_chunked(x, dt, a, bm, cm, d, chunk=16)
    y_ref, h_ref = ssd_sequential_ref(x, dt, a, bm, cm, d)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_path_in_chunked():
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(7)
    b, l, h, p, g, n = 1, 64, 2, 16, 1, 16
    x = jnp.asarray(rng.standard_normal((b, l, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.2, (b, l, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, l, g, n)), jnp.float32)
    d = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    y0, _ = ssd_chunked(x, dt, a, bm, cm, d, chunk=32, use_kernel=False)
    y1, _ = ssd_chunked(x, dt, a, bm, cm, d, chunk=32, use_kernel=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-3, atol=2e-3)
