"""Integration: short real training runs through the full driver stack —
loss decreases, checkpoint restart resumes identically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticLMData
from repro.train.optimizer import OptimizerConfig
from repro.train.steps import StepConfig, init_train_state, make_train_step


def _run(steps, state=None, seed=0, micro=1):
    cfg = get_config("minicpm-2b").reduced()
    step_cfg = StepConfig(remat=False, microbatches=micro,
                          compute_dtype=jnp.float32)
    opt = OptimizerConfig(lr=5e-3, warmup_steps=5, total_steps=120,
                          schedule="wsd")
    if state is None:
        state = init_train_state(jax.random.PRNGKey(seed), cfg, step_cfg)
    step = jax.jit(make_train_step(cfg, opt, step_cfg))
    data = SyntheticLMData(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                      global_batch=8, seed=1))
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return losses, state


def test_loss_decreases():
    losses, _ = _run(60)
    assert np.mean(losses[-10:]) < 0.75 * np.mean(losses[:5]), (
        losses[:5], losses[-10:])


def test_microbatching_matches_flat():
    """grad accumulation over 2 microbatches ~= flat batch step (same data,
    same update up to numerics)."""
    l1, _ = _run(3, micro=1)
    l2, _ = _run(3, micro=2)
    np.testing.assert_allclose(l1, l2, rtol=2e-2)


def test_checkpoint_restart_resumes(tmp_path):
    from repro.checkpoint import load_checkpoint, save_checkpoint
    losses_a, state = _run(5)
    save_checkpoint(str(tmp_path), 5, state)
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step, _ = load_checkpoint(str(tmp_path), like)
    assert step == 5
    cont_from_restore, _ = _run(3, state=jax.tree.map(
        lambda a: a, restored))
    cont_direct, _ = _run(3, state=state)
    np.testing.assert_allclose(cont_from_restore, cont_direct, rtol=1e-5)
