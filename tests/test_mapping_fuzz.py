"""Property-based mapping-legality fuzz: random layers x random archs ->
every mapping returned by the baselines and the MIP satisfies the
buffer-capacity (eq. 9) and spatial-legality (C^X) constraints.

Runs under ``hypothesis`` when available; otherwise a seeded-random
strategy shim (the tier-1 fallback pattern from
``tests/test_factorization.py``) so the suite collects on a bare
environment. The assertions re-derive eq. 9 and the spatial checks
directly from the mapping — independently of ``validate``'s bookkeeping —
and also require ``validate`` itself to come back clean.
"""

import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(fn, "_max_examples", 25)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

from repro.core import workload as wl
from repro.core.arch import OPERANDS, default_arch
from repro.core.baselines import greedy_mapping, heuristic_search
from repro.core.mapping import validate

#: Small arch grid spanning the knobs that move the constraints: core
#: count, macro geometry (spatial legality), buffer capacities (eq. 9) and
#: the double-buffering policy (the (1 + psi^DM) multiplier).
ARCHS = (
    default_arch(),
    default_arch(n_cores=2, macro_rows=64, macro_cols=16, gbuf_kb=2.0,
                 lbuf_kb=8.0, name="fuzz-tiny"),
    default_arch(double_buffered=False, name="fuzz-single-buf"),
    default_arch(n_cores=4, macro_rows=256, macro_cols=64, lbuf_kb=16.0,
                 reg_bytes=512, name="fuzz-wide"),
)

DIM_CHOICES = (1, 3, 8, 24, 100, 128, 360, 1000)


def _layer(kind: int, a: int, b: int, c: int) -> wl.Layer:
    if kind == 0:
        return wl.gemm("fz.gemm", a, b, c)
    return wl.conv("fz.conv", 1, a, c, min(b, 28), min(b, 28), 3, 3)


def assert_legal(mp, layer, arch):
    """Independent re-derivation of the legality contract."""
    assert validate(mp, layer, arch) == [], validate(mp, layer, arch)
    # (2) factor products reconstruct every loop bound
    for d in wl.DIMS:
        prod = math.prod(f for dd, f in mp.temporal if dd == d)
        for ax in arch.spatial:
            prod *= mp.spatial_extent(ax.name, d)
        assert prod == layer.bound(d), (d, prod, layer.bound(d))
    # C^X spatial legality: axis dim membership + physical lane budget
    for ax in arch.spatial:
        assert mp.spatial_extent(ax.name) <= ax.size
        for d, _f in mp.spatial.get(ax.name, ()):
            assert d in ax.dims, (ax.name, d)
    # eq. (9): (1 + psi^DM) x stored bytes within (aggregated) capacity,
    # summed across operands at shared levels, per operand otherwise
    for m in range(arch.n_levels):
        cap = mp.eff_capacity(arch, m)
        if cap is None:
            continue
        sizes = {}
        for lam in OPERANDS:
            if m not in mp.used_levels(lam) or not arch.serves(m, lam):
                continue
            mult = 2 if mp.is_double_buffered(lam, m, arch) else 1
            sizes[lam] = mult * mp.stored_bytes(layer, lam, arch, m)
        if arch.level(m).shared:
            assert sum(sizes.values()) <= cap + 1e-6
        else:
            for s in sizes.values():
                assert s <= cap + 1e-6
    # weights physically terminate in the macro (in-situ compute) whenever
    # any temporal slot exists
    if mp.n_slots():
        assert mp.deepest_used("W") <= arch.macro_level


@given(st.integers(0, 1),
       st.sampled_from(DIM_CHOICES), st.sampled_from(DIM_CHOICES),
       st.sampled_from(DIM_CHOICES), st.integers(0, len(ARCHS) - 1),
       st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_fuzz_greedy_and_heuristic_legal(kind, a, b, c, ai, seed):
    layer = _layer(kind, a, b, c)
    arch = ARCHS[ai]
    assert_legal(greedy_mapping(layer, arch), layer, arch)
    res = heuristic_search(layer, arch, budget=40, seed=seed)
    assert_legal(res.mapping, layer, arch)
    # the accurate re-score the search reports must be the evaluator's
    from repro.core.latency import evaluate
    assert res.eval_latency == pytest.approx(
        evaluate(res.mapping, layer, arch).total_cycles)


@given(st.integers(0, 1),
       st.sampled_from(DIM_CHOICES), st.sampled_from(DIM_CHOICES),
       st.sampled_from(DIM_CHOICES), st.integers(0, len(ARCHS) - 1),
       st.booleans())
@settings(max_examples=4, deadline=None)
def test_fuzz_mip_legal(kind, a, b, c, ai, ws):
    """The time-capped MIP (plain and weight-stationary) never returns an
    infeasible mapping — the warm-start contract, fuzzed."""
    from repro.core.formulation import FormulationConfig, optimize_layer
    layer = _layer(kind, a, b, c)
    arch = ARCHS[ai]
    cfg = FormulationConfig(time_limit_s=1.0, weight_stationary=ws)
    res = optimize_layer(layer, arch, cfg)
    assert res.mapping is not None, res.status
    assert_legal(res.mapping, layer, arch)
