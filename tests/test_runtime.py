"""Optimizer schedules, fault-tolerance policies, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.compression import (compress_grads_with_feedback,
                                       init_residuals)
from repro.runtime.fault_tolerance import (ElasticPlan, HeartbeatMonitor,
                                           RetryPolicy, StragglerPolicy,
                                           plan_elastic_mesh)
from repro.train.optimizer import (AdamWState, OptimizerConfig, adamw_update,
                                   init_adamw, schedule_lr)


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          schedule="wsd", wsd_stable_frac=0.8,
                          min_lr_frac=0.1)
    lrs = [float(schedule_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6          # warmed up
    assert abs(lrs[50] - 1.0) < 1e-6          # stable plateau (WSD)
    assert lrs[99] < 0.3                      # fast decay at the end
    cfg_cos = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                              schedule="cosine")
    assert float(schedule_lr(cfg_cos, jnp.asarray(50))) < 0.95  # no plateau


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_adamw(params)
    cfg = OptimizerConfig(lr=0.2, warmup_steps=0, total_steps=200,
                          weight_decay=0.0, schedule="constant")
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_grad_clip_reported():
    params = {"w": jnp.ones((4,))}
    state = init_adamw(params)
    cfg = OptimizerConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
    _, _, metrics = adamw_update(cfg, params, {"w": 100 * jnp.ones((4,))},
                                 state)
    assert float(metrics["grad_norm"]) > 100


def test_compression_error_feedback_converges():
    """With error feedback, the accumulated decompressed signal tracks the
    accumulated true gradient (bias-free compression)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((64,)) * 0.01)
    grads = {"g": g_true}
    residuals = init_residuals(grads)
    acc_true = np.zeros(64)
    acc_comp = np.zeros(64)
    for _ in range(50):
        comp, residuals = compress_grads_with_feedback(grads, residuals)
        acc_true += np.asarray(g_true)
        acc_comp += np.asarray(comp["g"])
    rel = np.linalg.norm(acc_comp - acc_true) / np.linalg.norm(acc_true)
    assert rel < 0.02, rel


def test_heartbeat():
    hb = HeartbeatMonitor(n_hosts=3, deadline_s=10)
    for h in range(3):
        hb.beat(h, now=100.0)
    assert hb.dead_hosts(now=105.0) == []
    assert hb.dead_hosts(now=111.0) == [0, 1, 2]
    hb.beat(1, now=112.0)
    assert hb.dead_hosts(now=115.0) == [0, 2]


def test_straggler_eviction():
    sp = StragglerPolicy(threshold=1.5, patience=2)
    for step in range(4):
        for h in range(4):
            sp.record(h, 1.0 if h != 3 else 3.0)
    assert sp.evictions() == [3]


def test_elastic_plan():
    p = plan_elastic_mesh(512, model_axis=16)
    assert (p.data, p.model) == (32, 16)
    p = plan_elastic_mesh(240, model_axis=16)     # lost a host
    assert (p.data, p.model) == (15, 16)
    p = plan_elastic_mesh(8, model_axis=16)       # deep degradation
    assert p.model <= 8 and p.n_devices <= 8


def test_retry_backoff():
    delays = list(RetryPolicy(max_retries=4, base_s=1.0, cap_s=5.0).delays())
    assert delays == [1.0, 2.0, 4.0, 5.0]
