"""Property/differential tests for the request-level serving simulator
(`core/serving.py`) and its integration with the frontend and DSE layers.

The engine's guarantees (module docstring of ``serving.py``) are enforced
here, not just by benchmark gates:

* token conservation — every admitted request's tokens are emitted
  exactly once (seq numbers 1..output_len, in order), nobody starves;
* KV occupancy never exceeds ``kv_capacity_tokens``;
* the same seed produces a bit-identical event log;
* differential vs the serial baseline — with "reserve" admission the
  continuous-batching makespan is never worse, and strictly better when
  requests genuinely overlap (the affine cost model makes the strict
  bound exactly analyzable: each saved iteration saves ``base``).

Runs under ``hypothesis`` when available; otherwise a seeded-random
strategy shim (the tier-1 fallback pattern from
``tests/test_mapping_fuzz.py``) so the suite collects on a bare
environment.
"""

import collections
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(fn, "_max_examples", 25)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

from repro.core.serving import (AffineCostModel, Request, RequestStream,
                                ServeConfig, _SubadditiveClosure,
                                percentile, serial_baseline,
                                simulate_serving)


# --------------------------------------------------------------------------
# Shared invariant checker
# --------------------------------------------------------------------------

def _stream(seed: int, n: int = 12, rate: float = 800.0) -> RequestStream:
    return RequestStream.poisson(n, seed=seed,
                                 mean_interarrival_cycles=rate,
                                 prompt_lens=(2, 5, 9),
                                 output_lens=(1, 3, 6))


def assert_invariants(stream: RequestStream, rep, cfg: ServeConfig) -> None:
    """The properties every simulation must satisfy, derived from the
    event log — independently of the engine's own counters."""
    by_rid = {r.rid: r for r in stream.requests}
    fin = {m.rid for m in rep.finished}
    rej = set(rep.rejected)

    # No starvation: finished/rejected partition the stream exactly.
    assert fin.isdisjoint(rej)
    assert fin | rej == set(by_rid), "some request neither finished nor " \
        "was rejected (starvation or loss)"

    # Token conservation: each finished request emitted exactly
    # output_len tokens, sequence numbers 1..output_len in order; rejected
    # requests emitted nothing.
    toks = collections.defaultdict(list)
    for _t, kind, rid, aux in rep.events:
        if kind == "token":
            toks[rid].append(aux)
    for rid in fin:
        want = list(range(1, by_rid[rid].output_len + 1))
        assert toks[rid] == want, f"rid {rid}: tokens {toks[rid]} != {want}"
    for rid in rej:
        assert rid not in toks
    assert rep.total_output_tokens == sum(by_rid[r].output_len for r in fin)

    # KV capacity: the per-iteration occupancy recorded in the event log
    # (aux of "iter" events) never exceeds capacity.
    occs = [aux for _t, kind, _rid, aux in rep.events if kind == "iter"]
    assert all(o <= cfg.kv_capacity_tokens for o in occs)
    assert rep.max_kv_occupancy <= cfg.kv_capacity_tokens
    if occs:
        assert rep.max_kv_occupancy == max(occs)

    # Per-request causality: a request's own events are time-ordered.
    times = collections.defaultdict(list)
    for t, kind, rid, _aux in rep.events:
        if kind != "iter":
            times[rid].append(t)
    for rid, ts in times.items():
        assert all(b >= a for a, b in zip(ts, ts[1:]))

    # Metrics coherence.
    for m in rep.finished:
        assert m.ttft_cycles >= 0
        assert len(m.itls) == m.output_len - 1
        assert m.finish_cycles >= m.first_token_cycles


# --------------------------------------------------------------------------
# Property/fuzz: invariants + determinism under both admission policies
# --------------------------------------------------------------------------

@settings(max_examples=40)
@given(st.integers(0, 10_000),
       st.sampled_from((15, 16, 24, 48, 512)),      # kv capacity
       st.sampled_from((1, 2, 4, 64)),              # max batch requests
       st.sampled_from((2, 9, 16, 128)),            # max batch tokens
       st.booleans())                               # optimistic?
def test_fuzz_invariants_and_determinism(seed, kv_cap, mbr, mbt, opt):
    cfg = ServeConfig(kv_capacity_tokens=kv_cap, max_batch_requests=mbr,
                      max_batch_tokens=mbt,
                      admission="optimistic" if opt else "reserve")
    stream = _stream(seed)
    rep = simulate_serving(stream, AffineCostModel(), cfg)
    assert_invariants(stream, rep, cfg)
    # Bit-identical determinism: a fresh same-seed stream through a fresh
    # engine reproduces the event log exactly (tuple equality, no
    # tolerance).
    rerun = simulate_serving(_stream(seed), AffineCostModel(), cfg)
    assert rerun.events == rep.events
    assert rerun.makespan_cycles == rep.makespan_cycles


@settings(max_examples=40)
@given(st.integers(0, 10_000), st.sampled_from((0.0, 1.0, 100.0)),
       st.sampled_from((1.0, 10.0)))
def test_fuzz_differential_batched_never_worse(seed, base, per_token):
    """Differential vs the serial baseline on randomized streams: with
    "reserve" admission and a subadditive cost, continuous batching never
    loses — for any base/per_token, any seed."""
    cfg = ServeConfig(kv_capacity_tokens=4096, max_batch_requests=64,
                      max_batch_tokens=1024)
    cost = AffineCostModel(base=base, per_token=per_token)
    stream = _stream(seed)
    rep = simulate_serving(stream, cost, cfg)
    ser = serial_baseline(stream, cost, cfg)
    assert_invariants(stream, rep, cfg)
    assert rep.makespan_cycles <= ser.makespan_cycles + 1e-9
    # Serial really is serial.
    assert ser.max_concurrency <= 1
    assert ser.n_merged_iterations == 0


@settings(max_examples=40)
@given(st.integers(0, 10_000), st.integers(2, 8))
def test_fuzz_differential_strict_when_overlapping(seed, n):
    """With >= 2 requests overlapping (all arrive at t=0), ample capacity
    and a strictly subadditive cost (base > 0), batching is *strictly*
    better: the batched run uses fewer iterations than serial's
    sum(output_len), and each iteration saved saves >= base cycles."""
    rng = random.Random(seed)
    rows = [(0.0, rng.randint(1, 9), rng.randint(1, 6)) for _ in range(n)]
    stream = RequestStream.from_trace(rows)
    cfg = ServeConfig(kv_capacity_tokens=4096, max_batch_requests=64,
                      max_batch_tokens=1024)
    cost = AffineCostModel(base=100.0, per_token=10.0)
    rep = simulate_serving(stream, cost, cfg)
    ser = serial_baseline(stream, cost, cfg)
    assert_invariants(stream, rep, cfg)
    assert rep.n_merged_iterations >= 1
    # Everything admitted in iteration 1, so batched iterations =
    # max(output_len) < sum(output_len) = serial iterations; both runs
    # charge per_token identically per emitted/prefilled token, so the
    # gap is exactly base * (iterations saved).
    assert rep.n_iterations == max(o for _a, _p, o in rows)
    assert ser.n_iterations == sum(o for _a, _p, o in rows)
    saved = ser.n_iterations - rep.n_iterations
    assert saved >= 1
    assert rep.makespan_cycles == pytest.approx(
        ser.makespan_cycles - cost.base * saved)


# --------------------------------------------------------------------------
# Admission, preemption and rejection paths
# --------------------------------------------------------------------------

def test_reserve_capacity_gates_admission():
    """Capacity that fits exactly one worst-case request => the engine
    degenerates to serial, with zero preemptions, by admission alone."""
    rows = [(0.0, 8, 6)] * 5
    stream = RequestStream.from_trace(rows)
    cfg = ServeConfig(kv_capacity_tokens=14, max_batch_requests=64,
                      max_batch_tokens=64)
    rep = simulate_serving(stream, AffineCostModel(), cfg)
    assert_invariants(stream, rep, cfg)
    assert rep.max_concurrency == 1
    assert rep.n_preemptions == 0
    assert len(rep.finished) == 5


def test_optimistic_preemption_requeue_and_finish():
    """Tight capacity under "optimistic" admission: over-admission forces
    preemptions, yet every request still finishes with its exact token
    count and occupancy never exceeds capacity."""
    rows = [(0.0, 8, 6)] * 20
    stream = RequestStream.from_trace(rows)
    cfg = ServeConfig(kv_capacity_tokens=48, max_batch_requests=64,
                      max_batch_tokens=256, admission="optimistic")
    rep = simulate_serving(stream, AffineCostModel(), cfg)
    assert_invariants(stream, rep, cfg)
    assert rep.n_preemptions >= 1
    assert len(rep.finished) == 20
    assert rep.max_kv_occupancy <= 48
    # Preemption is visible in the log and in per-request metrics.
    assert any(kind == "preempt" for _t, kind, _r, _a in rep.events)
    assert sum(m.n_preemptions for m in rep.finished) == rep.n_preemptions


def test_infeasible_requests_rejected_up_front():
    cfg = ServeConfig(kv_capacity_tokens=16, max_batch_requests=4,
                      max_batch_tokens=8)
    rows = [(0.0, 4, 2),      # fits
            (1.0, 12, 8),     # prompt+output=20 > kv 16  -> reject
            (2.0, 9, 2),      # prefill 9 > max_batch_tokens 8 -> reject
            (3.0, 8, 8)]      # fits exactly
    stream = RequestStream.from_trace(rows)
    rep = simulate_serving(stream, AffineCostModel(), cfg)
    assert_invariants(stream, rep, cfg)
    assert set(rep.rejected) == {1, 2}
    assert {m.rid for m in rep.finished} == {0, 3}
    # Under "optimistic" the worst re-prefill covers prompt+generated, so
    # the last request (8+8-1=15 tokens > 8) becomes infeasible too.
    opt = ServeConfig(kv_capacity_tokens=16, max_batch_requests=4,
                      max_batch_tokens=8, admission="optimistic")
    rep_o = simulate_serving(stream, AffineCostModel(), opt)
    assert set(rep_o.rejected) == {1, 2, 3}


def test_validation_errors():
    with pytest.raises(ValueError):
        Request(0, 0.0, 0, 1)
    with pytest.raises(ValueError):
        Request(0, 0.0, 1, 0)
    with pytest.raises(ValueError):
        RequestStream((Request(0, 5.0, 1, 1), Request(1, 2.0, 1, 1)))
    with pytest.raises(ValueError):
        ServeConfig(admission="greedy")
    with pytest.raises(ValueError):
        ServeConfig(kv_capacity_tokens=0)
    with pytest.raises(ValueError):
        AffineCostModel(base=-1.0)
    with pytest.raises(ValueError):
        _SubadditiveClosure(lambda m: float(m), 0)


# --------------------------------------------------------------------------
# Cost models: subadditive closure, affine, percentile
# --------------------------------------------------------------------------

@settings(max_examples=25)
@given(st.integers(0, 10_000))
def test_subadditive_closure_is_monotone_and_subadditive(seed):
    """Random (deliberately non-monotone, super-additive) raw anchor costs:
    the closure must still come out monotone and subadditive, and never
    exceed the raw anchor value."""
    rng = random.Random(seed)
    raw = {}

    def raw_fn(m):
        raw[m] = rng.uniform(1.0, 1000.0)
        return raw[m]

    cl = _SubadditiveClosure(raw_fn, 64)
    assert set(raw) == {1, 2, 4, 8, 16, 32, 64}
    f = [cl.cycles(m) for m in range(65)]
    assert f[0] == 0.0
    for m in range(1, 65):
        assert f[m] >= f[m - 1] - 1e-12                    # monotone
        for j in range(1, m):
            assert f[m] <= f[j] + f[m - j] + 1e-9          # subadditive
    for a, r in raw.items():
        assert f[a] <= r + 1e-12                           # never above raw
    with pytest.raises(ValueError):
        cl.cycles(65)


def test_affine_cost_model():
    c = AffineCostModel(base=100.0, per_token=10.0, freq_ghz=2.0)
    assert c.cycles(0) == 0.0
    assert c.cycles(1) == 110.0
    assert c.cycles(7) == 170.0
    assert c.seconds(1) == pytest.approx(110.0 / 2e9)


def test_percentile_nearest_rank():
    vals = list(range(1, 101))
    random.Random(0).shuffle(vals)
    assert percentile(vals, 50) == 50
    assert percentile(vals, 99) == 99
    assert percentile(vals, 100) == 100
    assert percentile(vals, 0) == 1
    assert percentile([7.0], 99) == 7.0
    assert percentile([], 50) == 0.0


def test_poisson_stream_deterministic_and_trace_parsing(tmp_path):
    a = RequestStream.poisson(16, seed=3, mean_interarrival_cycles=100.0)
    b = RequestStream.poisson(16, seed=3, mean_interarrival_cycles=100.0)
    c = RequestStream.poisson(16, seed=4, mean_interarrival_cycles=100.0)
    assert a.requests == b.requests
    assert a.requests != c.requests
    arr = [r.arrival_cycles for r in a.requests]
    assert arr == sorted(arr)

    p = tmp_path / "trace.txt"
    p.write_text("# arrival prompt output\n10.0, 4, 2\n5.0 8 1\n\n")
    s = RequestStream.from_trace(str(p))
    assert [(r.arrival_cycles, r.prompt_len, r.output_len)
            for r in s.requests] == [(5.0, 8, 1), (10.0, 4, 2)]


# --------------------------------------------------------------------------
# Frontend integration: mixed batch composition -> exact m_tokens
# --------------------------------------------------------------------------

def test_serving_iteration_lowers_to_exact_m_tokens():
    """Pinned regression: a mixed prefill/decode batch of known
    composition — two prefills (5 and 7 prompt tokens) + three decode
    streams — lowers to exactly m_tokens = 15 on every weight GEMM,
    through `ShapeSpec.serving_iteration` -> `frontend.extract_workload`."""
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.core.frontend import extract_workload

    spec = ShapeSpec.serving_iteration((5, 7), 3, context_len=64)
    assert spec.m_tokens == 15
    assert spec.kind == "decode"

    cfg = get_config("minicpm-2b").reduced()
    work = extract_workload(cfg, spec)
    got = {l.name.split(".")[-1]: (l.bound("N"), l.bound("K"),
                                   l.bound("C"), c)
           for l, c in zip(work.layers, work.counts)}
    assert got == {
        "wq": (15, 64, 64, 2), "wo": (15, 64, 64, 2),
        "wk": (15, 64, 64, 2), "wv": (15, 64, 64, 2),
        "ffn_up": (15, 256, 64, 2), "ffn_down": (15, 64, 128, 2),
        "lm_head": (15, 2048, 64, 1),
    }

    # SSM family: projections carry M = m_tokens, and the per-token SSD
    # ops' instance counts scale linearly in m (one scan step per token).
    mcfg = get_config("mamba2-1.3b").reduced()
    for m in (15, 4):
        mspec = ShapeSpec.serving_iteration((), m, context_len=64)
        mwork = extract_workload(mcfg, mspec)
        counts = {l.name.split(".")[-1]: c
                  for l, c in zip(mwork.layers, mwork.counts)}
        assert all(l.bound("N") in (m, 1, 16)
                   for l in mwork.layers)
        proj = {l.name.split(".")[-1]: l.bound("N") for l in mwork.layers}
        assert proj["in_proj"] == m and proj["out_proj"] == m
        assert counts["ssd_state_upd"] % m == 0
        assert counts["ssd_state_upd"] // m == \
            counts["ssd_readout"] // m  # same per-token replication
    # and the per-token ratio is identical across m values
    r15 = extract_workload(mcfg, ShapeSpec.serving_iteration((), 15))
    r4 = extract_workload(mcfg, ShapeSpec.serving_iteration((), 4))
    c15 = dict(zip((l.name for l in r15.layers), r15.counts))
    c4 = dict(zip((l.name for l in r4.layers), r4.counts))
    ssd = "mamba2-1.3b.blk.ssd_state_upd"
    assert c15[ssd] * 4 == c4[ssd] * 15

    with pytest.raises(ValueError):
        ShapeSpec.serving_iteration((), 0)


def test_extract_all_accepts_mixed_names_and_specs():
    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.core.frontend import extract_all

    cfg = get_config("minicpm-2b").reduced()
    spec = ShapeSpec.serving_iteration((3,), 2, context_len=64)
    out = extract_all(cfg, ["decode_32k", spec])
    assert "decode_32k" in out
    assert spec.name in out
    assert out[spec.name].layers[0].bound("N") == 5
    with pytest.raises(KeyError):
        extract_all(cfg, ["decode_32k", "no_such_scenario"])


# --------------------------------------------------------------------------
# Real-stack integration: NetworkCostModel differential
# --------------------------------------------------------------------------

def test_network_cost_model_differential_real_stack():
    """Iteration costs from the real stack (reduced minicpm, greedy
    mapper): the closure is monotone+subadditive, batching a second token
    is strictly cheaper than two single-token passes, and the serving
    differential holds end to end."""
    from repro.configs import get_config
    from repro.core.arch import default_arch
    from repro.core.serving import NetworkCostModel

    cfg = get_config("minicpm-2b").reduced()
    cost = NetworkCostModel(cfg, default_arch(), max_m=32,
                            context_len=256, mode="greedy",
                            per_layer_cap_s=1.0)
    assert cost.n_solves == 6           # anchors 1,2,4,8,16,32
    assert set(cost.anchor_cycles) == {1, 2, 4, 8, 16, 32}
    f = [cost.cycles(m) for m in range(33)]
    for m in range(1, 33):
        assert f[m] >= f[m - 1] - 1e-9
        for j in range(1, m):
            assert f[m] <= f[j] + f[m - j] + 1e-6
    # The whole point of batching: merging is strictly cheaper than
    # running back to back (weights are re-streamed once, not twice).
    assert cost.cycles(2) < 2 * cost.cycles(1)

    rows = [(0.0, 8, 4), (0.0, 4, 6), (1000.0, 16, 4)]
    stream = RequestStream.from_trace(rows)
    scfg = ServeConfig(kv_capacity_tokens=256, max_batch_requests=8,
                       max_batch_tokens=32)
    rep = simulate_serving(stream, cost, scfg)
    ser = serial_baseline(stream, cost, scfg)
    assert_invariants(stream, rep, scfg)
    assert rep.n_merged_iterations >= 1
    assert rep.makespan_cycles < ser.makespan_cycles


# --------------------------------------------------------------------------
# DSE integration: goodput-vs-latency ranking divergence
# --------------------------------------------------------------------------

def test_goodput_vs_latency_ranking_differs():
    """The mechanism behind `rank_by="slo_goodput"` (and the serve_sim
    benchmark gate that references this test): two archs whose iteration
    cost curves *cross*.  Arch A has low fixed cost but poor batching
    (high per-token cost); arch B pays more per pass but amortizes across
    a merged batch.  Single-token latency ranks A first; sustained
    tokens/sec under traffic ranks B first — so the latency-ranked and
    goodput-ranked Pareto frontiers genuinely differ."""
    from repro.core.dse import DsePoint, pareto_frontier

    cost_a = AffineCostModel(base=10.0, per_token=5.0)    # latency winner
    cost_b = AffineCostModel(base=50.0, per_token=1.0)    # batching winner
    assert cost_a.cycles(1) < cost_b.cycles(1)
    assert cost_a.cycles(32) > cost_b.cycles(32)          # curves cross

    stream = RequestStream.from_trace([(0.0, 8, 8)] * 8)  # bursty overlap
    cfg = ServeConfig(kv_capacity_tokens=4096, max_batch_requests=64,
                      max_batch_tokens=1024)
    goodput = {}
    for name, cost in (("A", cost_a), ("B", cost_b)):
        rep = simulate_serving(stream, cost, cfg)
        goodput[name] = rep.goodput_tokens_per_sec(cost.freq_ghz)
    assert goodput["B"] > goodput["A"]                    # ranking flips

    def points(rank_by):
        return [DsePoint(arch_name=n, cycles=c.cycles(1), energy_pj=1.0,
                         area_bits=1024, serial_cycles=c.cycles(1),
                         goodput_tok_s=goodput[n], rank_by=rank_by)
                for n, c in (("A", cost_a), ("B", cost_b))]

    lat = [p.arch_name for p in pareto_frontier(points("latency"))]
    good = [p.arch_name for p in pareto_frontier(points("slo_goodput"))]
    assert lat == ["A"]     # B dominated: worse cycles, same energy/area
    assert good == ["B"]    # A dominated: worse goodput, same energy/area
    assert lat != good


def test_rank_by_validation():
    from repro.core.dse import ArchSpace, DsePoint, run_dse

    p = DsePoint(arch_name="x", cycles=1.0, energy_pj=1.0, area_bits=1,
                 serial_cycles=1.0, rank_by="slo_goodput")
    with pytest.raises(ValueError):
        p.objectives()          # goodput missing
    space = ArchSpace(macro=((64, 32),), n_cores=(4,), gbuf_kb=(8.0,),
                      lbuf_kb=(16.0,))
    with pytest.raises(ValueError):
        run_dse([], None, space, "greedy", rank_by="slo_goodput")
    with pytest.raises(ValueError):
        run_dse([], None, space, "greedy", rank_by="edp")


def test_arch_goodput_scenario():
    from repro.core.arch import default_arch
    from repro.core.serving import ServeScenario, arch_goodput

    scen = ServeScenario(model_ids=("minicpm-2b",), reduced=True,
                         n_requests=6, context_len=256,
                         serve=ServeConfig(kv_capacity_tokens=256,
                                           max_batch_requests=8,
                                           max_batch_tokens=32),
                         per_layer_cap_s=1.0)
    out = arch_goodput(scen, default_arch())
    assert set(out) == {"minicpm-2b", "mean"}
    assert out["mean"] == pytest.approx(out["minicpm-2b"])
    assert out["mean"] > 0


# --------------------------------------------------------------------------
# KV-cache max_seq regression (examples/serve_lm.py satellite)
# --------------------------------------------------------------------------

def test_decode_cache_sized_to_prompt_plus_gen():
    """Regression for the hardcoded ``max_seq = 64`` bug in
    examples/serve_lm.py: the decode step appends via a one-hot(length)
    scatter that *silently drops* writes past the padded cache length.
    Sizing the cache to exactly prompt + generated must keep every write
    in bounds: the final cache length equals prompt+gen and the last
    position really was written (nonzero keys)."""
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.train.steps import (StepConfig, init_train_state,
                                   make_decode_step, make_prefill_step)

    cfg = get_config("minicpm-2b").reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    batch, prompt_len, gen_len = 2, 4, 3
    max_seq = prompt_len + gen_len

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, step_cfg))
    decode = jax.jit(make_decode_step(cfg, step_cfg))
    logits, caches = prefill(state.params, {"tokens": prompt})

    def pad(t):
        if t.ndim == 5 and t.shape[2] == prompt_len:
            return jnp.pad(t, [(0, 0), (0, 0),
                               (0, max_seq - prompt_len), (0, 0), (0, 0)])
        return t
    caches = jax.tree.map(pad, caches)

    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for _ in range(gen_len):
        logits, caches = decode(state.params, {"tokens": tok}, caches)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]

    lengths = np.asarray(caches.length)
    assert int(lengths.max()) == prompt_len + gen_len <= max_seq
    assert np.all(lengths == lengths.max())
    # The last decode's KV landed at the final slot — a dropped scatter
    # (undersized cache) would leave it all-zero.
    assert np.any(np.asarray(caches.k)[:, :, max_seq - 1] != 0)
