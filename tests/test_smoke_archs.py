"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + one decode step on CPU; asserts shapes + finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer
from repro.train.optimizer import OptimizerConfig
from repro.train.steps import (StepConfig, TrainState, init_caches,
                               init_train_state, make_decode_step,
                               make_prefill_step, make_train_step)

BATCH, SEQ = 2, 16


def _batch(cfg, seq=SEQ):
    rng = np.random.default_rng(0)
    b = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (BATCH, seq)), jnp.int32),
        "labels": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (BATCH, seq)), jnp.int32),
    }
    if cfg.modality in ("audio", "vision"):
        b["frontend"] = jnp.asarray(
            rng.standard_normal((BATCH, cfg.frontend_seq, cfg.d_model)),
            jnp.float32)
    return b


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_id(request):
    return request.param


def test_train_step(arch_id):
    cfg = get_config(arch_id).reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    step = make_train_step(cfg, OptimizerConfig(warmup_steps=2,
                                                total_steps=10), step_cfg)
    batch = _batch(cfg)
    new_state, metrics = jax.jit(step)(state, batch)
    assert np.isfinite(float(metrics["loss"])), metrics
    assert float(metrics["loss"]) > 0
    # params actually changed
    p0 = jax.tree.leaves(state.params)[0]
    p1 = jax.tree.leaves(new_state.params)[0]
    assert not np.allclose(np.asarray(p0), np.asarray(p1))


def test_prefill_then_decode(arch_id):
    cfg = get_config(arch_id).reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(1), cfg, step_cfg)
    batch = _batch(cfg)
    prefill = make_prefill_step(cfg, step_cfg)
    logits, caches = jax.jit(prefill)(state.params, batch)
    assert logits.shape == (BATCH, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    decode = make_decode_step(cfg, step_cfg)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits2, caches2 = jax.jit(decode)(
        state.params, {"tokens": tok}, caches)
    assert logits2.shape == (BATCH, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_decode_matches_prefill(arch_id):
    """Teacher-forced decode must reproduce prefill logits step by step —
    the KV-cache / SSM-state path is consistent with the parallel path."""
    cfg = get_config(arch_id).reduced()
    if cfg.family == "vlm":
        pytest.skip("vlm prefix changes token positions; covered above")
    if cfg.n_experts:
        pytest.skip("capacity-based MoE drops tokens differently at "
                    "prefill vs decode batch sizes (known serving "
                    "discrepancy); finiteness covered above")
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(2), cfg, step_cfg)
    batch = _batch(cfg, seq=8)
    prefill = make_prefill_step(cfg, step_cfg)
    decode = jax.jit(make_decode_step(cfg, step_cfg))

    full_logits, _ = jax.jit(prefill)(
        state.params, batch)                      # logits at last position
    # replay: prefill on the first 4 tokens, then decode tokens 4..7
    import dataclasses
    short = dict(batch)
    short["tokens"] = batch["tokens"][:, :4]
    short.pop("labels", None)
    _, caches = jax.jit(prefill)(state.params, short)
    # grow caches to full seq for decode writes
    caches = jax.tree.map(_pad_cache_to(cfg, 8), caches)
    logits = None
    for t in range(4, 8):
        tok = batch["tokens"][:, t:t + 1]
        logits, caches = decode(state.params, {"tokens": tok}, caches)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=2e-2, atol=2e-2)


def _pad_cache_to(cfg, max_seq):
    def pad(t):
        # KV caches have a sequence axis == axis 2 (layers, B, S, KV, hd)
        if t.ndim == 5 and t.shape[2] < max_seq and \
                t.shape[2] not in (cfg.ssm_state, 16):
            pad_n = max_seq - t.shape[2]
            return jnp.pad(t, [(0, 0), (0, 0), (0, pad_n), (0, 0), (0, 0)])
        return t
    return pad
