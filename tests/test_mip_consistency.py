"""THE core reproduction property: the MIP's internal latency equals the
analytical evaluator exactly on any pinned feasible mapping — the Table III
recursion and eqs. 2–13 are encoded faithfully."""

import random

import pytest

from repro.core.arch import default_arch
from repro.core.baselines import _sample_mapping, greedy_mapping
from repro.core.factorization import factorize_layer_dims
from repro.core.formulation import FormulationConfig, mip_latency_of
from repro.core.latency import evaluate
from repro.core.workload import DIMS, conv, gemm

ARCH = default_arch()
LAYERS = [gemm("g", 64, 128, 256), conv("c", 1, 64, 64, 14, 14, 3, 3)]


@pytest.mark.parametrize("layer", LAYERS, ids=lambda l: l.name)
def test_mip_equals_evaluator_on_pinned_mappings(layer):
    rng = random.Random(7)
    factors = factorize_layer_dims({d: layer.bound(d) for d in DIMS})
    checked = 0
    while checked < 3:
        mp = _sample_mapping(layer, ARCH, rng, factors)
        if mp is None:
            continue
        ev = evaluate(mp, layer, ARCH).total_cycles
        mip = mip_latency_of(layer, ARCH, mp,
                             FormulationConfig(time_limit_s=60))
        assert mip == mip, "pinned encoding must be feasible"
        assert abs(ev - mip) / ev < 1e-6, (ev, mip)
        checked += 1


def test_mip_equals_evaluator_on_greedy(subtests=None):
    for layer in LAYERS:
        mp = greedy_mapping(layer, ARCH)
        ev = evaluate(mp, layer, ARCH).total_cycles
        mip = mip_latency_of(layer, ARCH, mp,
                             FormulationConfig(time_limit_s=60))
        assert abs(ev - mip) / ev < 1e-6, (layer.name, ev, mip)
