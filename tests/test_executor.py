"""Measured-execution backend (`core/executor.py`): lowering, dispatch,
numerics and the rank statistic, on tiny reduced workloads in Pallas
interpret mode (greedy solve mode — no MIP wall-clock in tier-1)."""

import math

import pytest

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import workload as wl
from repro.core.arch import default_arch
from repro.core.executor import (EXEC_BLOCK_CAP, ExecOp, execute_plan,
                                 lower_plan, spearman)
from repro.core.frontend import extract_workload
from repro.core.network import optimize_network

ARCH = default_arch()
PREFILL = ShapeSpec("t_prefill", seq_len=64, global_batch=1, kind="prefill")
DECODE = ShapeSpec("t_decode", seq_len=64, global_batch=4, kind="decode")


def _net(cfg, spec):
    work = extract_workload(cfg, spec)
    return optimize_network(list(work.layers), ARCH, "greedy",
                            counts=list(work.counts), use_cache=False)


@pytest.fixture(scope="module")
def dense_prefill():
    cfg = get_config("minicpm-2b").reduced()
    return cfg, PREFILL, _net(cfg, PREFILL)


@pytest.fixture(scope="module")
def ssm_prefill():
    cfg = get_config("mamba2-1.3b").reduced()
    return cfg, PREFILL, _net(cfg, PREFILL)


# ---------------------------------------------------------------------------
# Rank statistic
# ---------------------------------------------------------------------------

def test_spearman_basics():
    assert spearman([1, 2, 3, 4], [10, 20, 30, 40]) == pytest.approx(1.0)
    assert spearman([1, 2, 3, 4], [40, 30, 20, 10]) == pytest.approx(-1.0)
    assert spearman([1, 2], [2, 1]) is None            # < 3 points
    assert spearman([1, 1, 1], [1, 2, 3]) is None      # constant side
    # monotone but nonlinear is still rank-1.0
    assert spearman([1, 2, 3, 4], [1, 10, 100, 1000]) == pytest.approx(1.0)


def test_spearman_ties_average_ranks():
    r = spearman([1, 2, 2, 3], [1, 2, 3, 4])
    assert r is not None and 0.8 < r < 1.0


# ---------------------------------------------------------------------------
# Op-kind tagging (frontend -> executor contract)
# ---------------------------------------------------------------------------

def test_frontend_layers_carry_op_kinds(dense_prefill, ssm_prefill):
    cfg, spec, _ = dense_prefill
    work = extract_workload(cfg, spec)
    kinds = {l.name.rpartition(".")[2]: l.op for l in work.layers}
    assert kinds["wq"] == wl.OP_ATTENTION
    assert kinds["wo"] == wl.OP_ATTENTION
    assert kinds["ffn_up"] == wl.OP_GEMM
    assert kinds["lm_head"] == wl.OP_GEMM
    cfg, spec, _ = ssm_prefill
    work = extract_workload(cfg, spec)
    kinds = {l.name.rpartition(".")[2]: l.op for l in work.layers}
    assert kinds["ssd_scores"] == wl.OP_SSD
    assert kinds["in_proj"] == wl.OP_GEMM


def test_layer_op_is_not_structural_identity():
    """Op tags route execution only: structurally identical layers dedup
    to one solve regardless of tag (cache keys ignore ``op``)."""
    from repro.core.cache import layer_cache_key
    a = wl.gemm("a", 64, 128, 256)
    b = wl.gemm("b", 64, 128, 256, op=wl.OP_ATTENTION)
    assert layer_cache_key(a) == layer_cache_key(b)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def test_lower_plan_dense_prefill(dense_prefill):
    cfg, spec, net = dense_prefill
    plan = lower_plan(cfg, spec, net, ARCH)
    kernels = [op.kernel for op in plan.ops]
    assert kernels.count("flash_attention") == 1
    assert "ssd_scan" not in kernels
    # one matmul op per workload layer (no layer dropped or duplicated)
    mm_idx = [i for op in plan.ops if op.kernel == "matmul_int8"
              for i in op.layer_indices]
    assert sorted(mm_idx) == list(range(len(net.layers)))
    # every matmul op carries its record's cycles and mapping-derived,
    # MXU-aligned blocks under the execution cap
    for op in plan.ops:
        if op.kernel != "matmul_int8":
            continue
        lr = net.layers[op.layer_indices[0]]
        assert op.predicted_cycles == lr.record["cycles"]
        s = op.spec
        assert s["bm"] % 8 == 0 and s["bk"] % 128 == 0 and s["bn"] % 128 == 0
        assert max(s["bm"], s["bk"], s["bn"]) <= max(EXEC_BLOCK_CAP, 128)
    # prefill attention: causal square over the block's token dim
    fo = next(op for op in plan.ops if op.kernel == "flash_attention")
    assert fo.spec["causal"] and fo.spec["lq"] == fo.spec["lk"] == 64
    assert fo.predicted_cycles is None   # score stage is not a CIM layer


def test_lower_plan_decode_attention_uses_kv_cache(dense_prefill):
    cfg, _, _ = dense_prefill
    net = _net(cfg, DECODE)
    plan = lower_plan(cfg, DECODE, net, ARCH)
    fo = next(op for op in plan.ops if op.kernel == "flash_attention")
    assert not fo.spec["causal"]
    assert fo.spec["lq"] == 1                      # one step per sequence
    assert fo.spec["b"] == DECODE.global_batch     # sequences batch
    assert fo.spec["lk"] == DECODE.seq_len         # the cache

def test_lower_plan_fuses_ssd_intra_pair(ssm_prefill):
    cfg, spec, net = ssm_prefill
    plan = lower_plan(cfg, spec, net, ARCH)
    ssd = [op for op in plan.ops if op.kernel == "ssd_scan"]
    assert len(ssd) == 1
    (op,) = ssd
    i, j = op.layer_indices
    assert net.layers[i].layer.name.endswith("ssd_scores")
    assert net.layers[j].layer.name.endswith("ssd_y_intra")
    assert op.predicted_cycles == pytest.approx(
        net.layers[i].record["cycles"] + net.layers[j].record["cycles"])
    assert op.spec["n"] == cfg.ssm_state
    assert op.spec["p"] == cfg.ssm_head_dim
    # the remaining SSD state GEMMs dispatch to the matmul kernel
    names = {op2.name.rpartition(".")[2] for op2 in plan.ops
             if op2.kernel == "matmul_int8"}
    assert {"ssd_s_chunk", "ssd_y_inter"} <= names


def test_lower_plan_segments_follow_schedule(dense_prefill):
    cfg, spec, net = dense_prefill
    plan = lower_plan(cfg, spec, net, ARCH)
    ids = net.schedule.stage_segment_ids()
    assert len(ids) == len(net.layers)
    assert ids == sorted(ids)                      # segments are contiguous
    for op in plan.ops:
        assert op.segment == ids[op.layer_indices[0]]
    assert plan.n_segments == len(net.schedule.segments)


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------

def test_execute_plan_numerics_and_memoization(ssm_prefill):
    cfg, spec, net = ssm_prefill
    plan = lower_plan(cfg, spec, net, ARCH)
    rep = execute_plan(plan, repeats=1)
    assert rep.numerics_ok
    assert rep.max_rel_err < 1e-3
    assert rep.n_checked <= rep.n_ops              # structural memoization
    for op in plan.ops:
        assert op.measured_s is not None and op.measured_s > 0
        assert op.numerics_ok
    assert rep.measured_total_s == pytest.approx(
        sum(op.count * op.measured_s for op in plan.ops))
    pts = rep.rank_points()
    assert all(p > 0 and m > 0 for p, m in pts)
    assert len(pts) == len({op.key for op in plan.ops
                            if op.predicted_cycles is not None})


def test_execute_plan_deterministic_numerics(dense_prefill):
    """Same seed -> identical operands -> identical rel errors."""
    cfg, spec, net = dense_prefill
    p1 = lower_plan(cfg, spec, net, ARCH)
    p2 = lower_plan(cfg, spec, net, ARCH)
    execute_plan(p1, repeats=1, seed=3)
    execute_plan(p2, repeats=1, seed=3)
    for a, b in zip(p1.ops, p2.ops):
        assert a.rel_err == b.rel_err


def test_exec_op_key_structural():
    a = ExecOp("x", "matmul_int8", {"m": 8, "k": 128, "n": 128, "bm": 8,
                                    "bk": 128, "bn": 128}, 1, (0,))
    b = ExecOp("y", "matmul_int8", {"n": 128, "k": 128, "m": 8, "bn": 128,
                                    "bk": 128, "bm": 8}, 7, (3,))
    assert a.key == b.key                          # names/counts don't split


def test_gemm_mkn_roundtrip():
    from repro.core.executor import _gemm_mkn
    m, k, n = _gemm_mkn(wl.gemm("g", 5, 7, 11))
    assert (m, k, n) == (5, 11, 7)
    assert math.prod((m, k, n)) == wl.gemm("g", 5, 7, 11).macs
