"""MIREDO TPU bridge: MIP-selected Pallas blocks respect VMEM (eq. 9 with
double-buffering), MXU alignment, and beat naive choices on HBM traffic."""

import math

import pytest

from repro.core.tpu_bridge import (LANE, SUBLANE, VMEM_BYTES, _candidates,
                                   _round_up, select_flash_blocks,
                                   select_matmul_blocks)


def traffic(m, k, n, bm, bn):
    return m * k * (n / bn) + k * n * (m / bm) + 4 * m * n


@pytest.mark.parametrize("m,k,n", [
    (65536, 2304, 360),        # minicpm ffn shard (n has no aligned divisor)
    (65536, 6144, 1024),       # internlm2 ffn shard
    (4096, 4096, 4096),
])
def test_matmul_blocks_valid(m, k, n):
    c = select_matmul_blocks(m, k, n)
    # MXU legality is unconditional; divisibility holds whenever an aligned
    # divisor exists, else the block covers the padded dim.
    assert c.bm % SUBLANE == 0
    assert c.bk % LANE == 0
    assert c.bn % LANE == 0
    for dim, blk, align in ((m, c.bm, SUBLANE), (k, c.bk, LANE),
                            (n, c.bn, LANE)):
        assert dim % blk == 0 or blk <= _round_up(dim, align)
    mult = 2 if c.double_buffered else 1
    assert mult * c.vmem_bytes <= VMEM_BYTES, (c,)


def test_blocks_beat_smallest():
    """The MIP pick must not be worse than the minimal 128-cube on the
    modeled HBM traffic."""
    m, k, n = 65536, 6144, 1024
    c = select_matmul_blocks(m, k, n)
    assert traffic(m, k, n, c.bm, c.bn) <= traffic(m, k, n, 128, 128) + 1


def test_candidates_always_aligned():
    """Regression: dim % align != 0 used to fall back to the raw dim,
    producing MXU-illegal block shapes (e.g. bn=100 with LANE=128)."""
    for dim, align in ((100, 128), (360, 128), (100, 8), (2304, 128),
                       (1, 128), (4096, 128), (5000, 128)):
        cands = _candidates(dim, align=align, cap=2048)
        assert cands, (dim, align)
        for c in cands:
            assert c % align == 0, (dim, align, c)
            assert c <= max(align, _round_up(min(dim, 2048), align))


def test_candidates_pad_and_clamp():
    assert _candidates(100, align=128, cap=2048) == [128]   # pad up
    assert _candidates(104, align=8, cap=2048) == [104]     # already aligned
    # no aligned divisor: full aligned ladder up to the padded dim
    assert _candidates(360, align=128, cap=2048) == [128, 256, 384]
    # clamped to aligned values <= cap
    assert _candidates(5000, align=128, cap=2048) == \
        [128, 256, 512, 1024, 2048]
    big = _candidates(3000, align=128, cap=2048)
    assert all(c <= 2048 and c % 128 == 0 for c in big)


def test_matmul_blocks_unaligned_dims_stay_legal():
    c = select_matmul_blocks(100, 100, 100)
    assert c.bm % SUBLANE == 0 and c.bk % LANE == 0 and c.bn % LANE == 0
    assert c.bk == 128 and c.bn == 128                      # padded to MXU
    mult = 2 if c.double_buffered else 1
    assert mult * c.vmem_bytes <= VMEM_BYTES


def test_flash_blocks_fit():
    bq, bk = select_flash_blocks(32768, 32768, 128)
    assert 32768 % bq == 0 and 32768 % bk == 0
    ws = (bq * 128 + 2 * bk * 128) * 2 + bq * 128 * 4 + bq * bk * 4
    assert 2 * ws <= VMEM_BYTES
