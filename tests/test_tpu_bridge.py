"""MIREDO TPU bridge: MIP-selected Pallas blocks respect VMEM (eq. 9 with
double-buffering), MXU alignment, and beat naive choices on HBM traffic."""

import pytest

from repro.core.tpu_bridge import (LANE, SUBLANE, VMEM_BYTES,
                                   select_flash_blocks,
                                   select_matmul_blocks)


def traffic(m, k, n, bm, bn):
    return m * k * (n / bn) + k * n * (m / bm) + 4 * m * n


@pytest.mark.parametrize("m,k,n", [
    (65536, 2304, 360),        # minicpm ffn shard
    (65536, 6144, 1024),       # internlm2 ffn shard
    (4096, 4096, 4096),
])
def test_matmul_blocks_valid(m, k, n):
    c = select_matmul_blocks(m, k, n)
    assert m % c.bm == 0 and k % c.bk == 0 and n % c.bn == 0
    assert c.bk % LANE == 0 or c.bk == k
    assert c.bm % SUBLANE == 0 or c.bm == m
    mult = 2 if c.double_buffered else 1
    assert mult * c.vmem_bytes <= VMEM_BYTES, (c,)


def test_blocks_beat_smallest():
    """The MIP pick must not be worse than the minimal 128-cube on the
    modeled HBM traffic."""
    m, k, n = 65536, 6144, 1024
    c = select_matmul_blocks(m, k, n)
    assert traffic(m, k, n, c.bm, c.bn) <= traffic(m, k, n, 128, 128) + 1


def test_flash_blocks_fit():
    bq, bk = select_flash_blocks(32768, 32768, 128)
    assert 32768 % bq == 0 and 32768 % bk == 0
    ws = (bq * 128 + 2 * bk * 128) * 2 + bq * 128 * 4 + bq * bk * 4
    assert 2 * ws <= VMEM_BYTES
