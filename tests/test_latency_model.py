"""Analytical latency model: structural properties + simulator agreement
(the paper's Fig. 4(a) discipline as a test)."""

import math
import random

import pytest

from repro.core.arch import INPUT, OUTPUT, WEIGHT, default_arch
from repro.core.baselines import _sample_mapping, greedy_mapping
from repro.core.energy import evaluate_edp
from repro.core.factorization import factorize_layer_dims
from repro.core.latency import evaluate, idealized_cycles
from repro.core.mapping import validate
from repro.core.simulator import simulate
from repro.core.workload import DIMS, conv, gemm, resnet18

ARCH = default_arch()


@pytest.mark.parametrize("layer", resnet18(), ids=lambda l: l.name)
def test_greedy_always_feasible(layer):
    mp = greedy_mapping(layer, ARCH)
    assert validate(mp, layer, ARCH) == []
    rep = evaluate(mp, layer, ARCH)
    assert rep.total_cycles > 0
    assert 0 < rep.spatial_util <= 1
    assert 0 < rep.temporal_util <= 1


def test_latency_lower_bound():
    """Total latency >= serial MVM count * L_MVM (compute bound)."""
    layer = gemm("g", 64, 128, 256)
    mp = greedy_mapping(layer, ARCH)
    rep = evaluate(mp, layer, ARCH)
    iters = math.prod(f for _, f in mp.temporal)
    assert rep.total_cycles >= iters * ARCH.l_mvm_cycles


def test_idealized_is_optimistic():
    """The perfect-overlap model (paper limitation ❶) never exceeds the
    accurate model."""
    rng = random.Random(0)
    layer = conv("c", 1, 64, 64, 14, 14, 3, 3)
    factors = factorize_layer_dims({d: layer.bound(d) for d in DIMS})
    checked = 0
    while checked < 10:
        mp = _sample_mapping(layer, ARCH, rng, factors)
        if mp is None:
            continue
        checked += 1
        assert idealized_cycles(mp, layer, ARCH) <= \
            evaluate(mp, layer, ARCH).total_cycles + 1e-6


def test_simulator_agreement():
    """Mean analytical-model accuracy vs the event simulator (paper: 95.5%;
    we gate at a conservative 80% for small random mapping samples)."""
    rng = random.Random(1)
    layer = conv("c", 1, 64, 64, 14, 14, 3, 3)
    factors = factorize_layer_dims({d: layer.bound(d) for d in DIMS})
    accs = []
    while len(accs) < 8:
        mp = _sample_mapping(layer, ARCH, rng, factors)
        if mp is None:
            continue
        iters = math.prod(f for _, f in mp.temporal)
        if iters > 60_000:
            continue
        model = evaluate(mp, layer, ARCH).total_cycles
        sim = simulate(mp, layer, ARCH).total_cycles
        accs.append(1 - abs(model - sim) / max(sim, 1))
    assert sum(accs) / len(accs) > 0.8, accs


def test_mode_switch_costs_show_up():
    """Weight reloads into the macro must cost more than the raw transfer
    (Fig. 2(a) mode-switch stalls)."""
    layer = gemm("g", 32, 64, 128)
    mp = greedy_mapping(layer, ARCH)
    import dataclasses
    base = evaluate(mp, layer, ARCH).total_cycles
    quiet = dataclasses.replace(ARCH, mode_switch_cycles=0)
    assert evaluate(mp, layer, quiet).total_cycles <= base


def test_differential_random_layer_sweep():
    """Differential sweep: `latency.evaluate` vs the event-simulator replay
    on *randomized* single layers (GEMMs across the LM-shape range plus
    random convs), gated at the Fig. 4(a) 0.8 tolerance —
    `test_simulator_agreement` pins one conv, this sweeps the shapes the
    measured-execution backend (`core/executor.py`) ranks against."""
    rng = random.Random(7)
    accs = []
    tried = 0
    while len(accs) < 12 and tried < 300:
        tried += 1
        if rng.random() < 0.6:
            layer = gemm("d.gemm", rng.choice([1, 8, 32, 100, 256]),
                         rng.choice([16, 64, 360, 1024]),
                         rng.choice([16, 64, 200]))
        else:
            hw = rng.choice([7, 14])
            layer = conv("d.conv", 1, rng.choice([16, 64]),
                         rng.choice([16, 64]), hw, hw, 3, 3)
        if rng.random() < 0.4:
            mp = greedy_mapping(layer, ARCH)
        else:
            factors = factorize_layer_dims(
                {d: layer.bound(d) for d in DIMS})
            mp = _sample_mapping(layer, ARCH, rng, factors)
            if mp is None:
                continue
        iters = math.prod(f for _, f in mp.temporal)
        if iters > 60_000:
            continue
        model = evaluate(mp, layer, ARCH).total_cycles
        sim = simulate(mp, layer, ARCH).total_cycles
        accs.append(1 - abs(model - sim) / max(sim, 1))
    assert len(accs) >= 10, "sweep failed to draw enough replayable points"
    assert sum(accs) / len(accs) > 0.8, accs


def test_energy_positive_and_layered():
    layer = conv("c", 1, 64, 64, 14, 14, 3, 3)
    mp = greedy_mapping(layer, ARCH)
    edp = evaluate_edp(mp, layer, ARCH)
    assert edp.energy.total_pj > 0
    assert edp.energy.mac_pj == layer.macs * ARCH.mac_energy_pj
    assert edp.edp > 0
