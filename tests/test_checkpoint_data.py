"""Checkpoint atomicity/retention/restore + data-pipeline determinism."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, load_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpoint import latest_step
from repro.data.pipeline import DataConfig, SyntheticLMData


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t, {"cursor": 5})
    like = jax.tree.map(jnp.zeros_like, t)
    restored, step, extra = load_checkpoint(str(tmp_path), like)
    assert step == 5 and extra == {"cursor": 5}
    for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_retention_and_latest(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, t, keep=2)
    steps = sorted(os.listdir(str(tmp_path)))
    assert steps == ["step_00000004", "step_00000005"]
    assert latest_step(str(tmp_path)) == 5


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _tree())
    assert not [d for d in os.listdir(str(tmp_path))
                if d.startswith("tmp.")]


def test_manager_restore_or_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2)
    t = _tree()
    assert mgr.maybe_save(1, t) is None
    assert mgr.maybe_save(2, t) is not None
    restored, step, _ = mgr.restore_or_init(jax.tree.map(jnp.zeros_like, t))
    assert step == 2
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(t["a"]))


def test_data_determinism_and_restart():
    cfg = DataConfig(vocab_size=977, seq_len=32, global_batch=8, seed=3)
    d1 = SyntheticLMData(cfg)
    d2 = SyntheticLMData(cfg)
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different steps differ
    assert not np.array_equal(d1.batch(18)["tokens"], b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_data_host_sharding():
    cfg = DataConfig(vocab_size=977, seq_len=16, global_batch=8, seed=0)
    hosts = [SyntheticLMData(cfg, host_id=h, n_hosts=4) for h in range(4)]
    batches = [h.batch(3)["tokens"] for h in hosts]
    assert all(b.shape == (2, 16) for b in batches)
    # shards differ across hosts (independent slices)
    assert not np.array_equal(batches[0], batches[1])
