"""Sharding-rule divisibility: every parameter spec must evenly divide its
tensor on the production mesh shapes, for every assigned architecture —
checked abstractly via eval_shape (no allocation), both mesh variants."""

import jax
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.sharding.rules import make_plan
from repro.train.steps import StepConfig, init_train_state

MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


class FakeMesh:
    """Duck-typed mesh: rules only read .axis_names / .shape."""
    def __init__(self, shape: dict):
        self.axis_names = tuple(shape)
        self.shape = dict(shape)
        self.devices = np.empty(tuple(shape.values()), dtype=object)


def _check(cfg, plan):
    shapes = jax.eval_shape(
        lambda k: init_train_state(k, cfg, StepConfig()),
        jax.random.PRNGKey(0))

    bad = []

    def check(path, leaf):
        spec = plan.param_spec(
            tuple(str(getattr(p, "key", getattr(p, "idx", p)))
                  for p in path), leaf)
        for dim, entry in zip(leaf.shape, list(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = 1
            for a in axes:
                n *= plan.mesh.shape[a]
            if dim % n:
                bad.append(("/".join(str(p) for p in path), leaf.shape,
                            spec))
        return leaf

    jax.tree_util.tree_map_with_path(check, shapes)
    return bad


@pytest.mark.parametrize("mesh_name", list(MESH_SHAPES))
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_param_specs_divide(arch_id, mesh_name):
    cfg = get_config(arch_id)
    mesh = FakeMesh(MESH_SHAPES[mesh_name])
    plan = make_plan(mesh, cfg, SHAPES["train_4k"])
    bad = _check(cfg, plan)
    assert not bad, bad[:5]


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_plan_flags_sensible(arch_id):
    cfg = get_config(arch_id)
    mesh = FakeMesh(MESH_SHAPES["single"])
    plan = make_plan(mesh, cfg, SHAPES["train_4k"])
    if cfg.n_heads:
        assert plan.attn_tp == (cfg.n_heads % 16 == 0)
    if cfg.n_experts:
        assert plan.moe_ep == (cfg.moe_sharding != "tp"
                               and cfg.n_experts % 16 == 0)
    # batch=1 long-context must flip to sequence sharding
    plan_long = make_plan(mesh, cfg, SHAPES["long_500k"])
    assert plan_long.shard_seq
