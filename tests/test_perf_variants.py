"""Optimization variants must be numerically equivalent to their baselines
(the §Perf discipline: keep the speedup, prove correctness)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as attn_mod
import repro.models.moe as moe_mod
from repro.models.attention import (dot_attention, dot_attention_chunked,
                                    dequantize_kv, quantize_kv)
from repro.models.moe import init_moe, moe


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("lq,lk,bk", [(4096, 4096, 1024),
                                      (2048, 2048, 512)])
def test_chunked_attention_matches_naive(causal, lq, lk, bk):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, lq, 2, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, lk, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, lk, 2, 32)), jnp.float32)
    ref = dot_attention(q, k, v, causal=causal)
    out = dot_attention_chunked(q, k, v, causal=causal, block_k=bk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_kv_quantization_roundtrip():
    rng = np.random.default_rng(1)
    k = jnp.asarray(rng.standard_normal((2, 8, 4, 64)), jnp.float32)
    q, s = quantize_kv(k)
    back = dequantize_kv(q, s, jnp.float32)
    err = np.max(np.abs(np.asarray(back) - np.asarray(k)))
    assert err < float(jnp.max(jnp.abs(k))) / 50


def test_scatter_moe_matches_einsum():
    """With generous capacity (no drops) the two dispatch implementations
    are numerically identical."""
    d, ff, e, k = 32, 64, 8, 2
    params = init_moe(jax.random.PRNGKey(0), d, ff, e, 0, True)
    x = jnp.asarray(np.random.default_rng(2).standard_normal((2, 16, d)),
                    jnp.float32)
    old = moe_mod.MOE_DISPATCH
    try:
        moe_mod.MOE_DISPATCH = "einsum"
        out_e, aux_e = moe(params, x, n_experts=e, top_k=k, gated=True,
                           capacity_factor=8.0)
        moe_mod.MOE_DISPATCH = "scatter"
        out_s, aux_s = moe(params, x, n_experts=e, top_k=k, gated=True,
                           capacity_factor=8.0)
    finally:
        moe_mod.MOE_DISPATCH = old
    np.testing.assert_allclose(np.asarray(out_e), np.asarray(out_s),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_s), rtol=1e-5)


def test_decode_with_quantized_cache():
    """int8 KV decode stays close to the bf16 path on a reduced model."""
    from repro.configs import get_config
    from repro.train.steps import (StepConfig, init_train_state,
                                   make_decode_step, make_prefill_step)
    cfg = get_config("glm4-9b").reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(3), cfg, step_cfg)
    toks = jnp.asarray(np.random.default_rng(4).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    prefill = make_prefill_step(cfg, step_cfg)
    decode = make_decode_step(cfg, step_cfg)
    old = attn_mod.KV_QUANT
    try:
        attn_mod.KV_QUANT = False
        logits, caches = jax.jit(prefill)(state.params, {"tokens": toks})
        nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        pad = lambda t: jnp.pad(
            t, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)]) \
            if t.ndim == 5 and t.shape[2] == 8 else t
        caches = jax.tree.map(pad, caches)
        ref, _ = jax.jit(decode)(state.params, {"tokens": nxt}, caches)

        attn_mod.KV_QUANT = True
        logits_q, caches_q = jax.jit(prefill)(state.params,
                                              {"tokens": toks})
        caches_q = jax.tree.map(pad, caches_q)
        out_q, _ = jax.jit(decode)(state.params, {"tokens": nxt}, caches_q)
    finally:
        attn_mod.KV_QUANT = old
    # same argmax and close logits
    assert jnp.argmax(ref, -1).tolist() == jnp.argmax(out_q, -1).tolist()
