"""Integration: the multi-pod dry-run machinery end-to-end for one cell
(subprocess — the 512-device XLA flag must precede jax init)."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_one_cell(tmp_path, mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "glm4-9b", "--shape", "decode_32k",
         "--mesh", mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    tag = f"glm4-9b__decode_32k__{mesh}.json"
    rec = json.load(open(tmp_path / tag))
    assert rec["status"] == "ok", rec
    assert rec["flops_per_device"] > 0
    assert rec["memory"]["argument_bytes"] > 0
    # roofline terms derivable
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch.roofline import roofline_terms
    t = roofline_terms(rec)
    assert t["status"] == "ok"
    assert t["dominant"] in ("compute", "memory", "collective")
