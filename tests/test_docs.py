"""Docs can't rot silently: every command documented in docs/tutorial.md
and README.md must resolve to a real, parseable repo script
(docs/check_docs.py provides the checker; CI's ``docs`` job additionally
runs each argparse CLI with ``--help``)."""

import importlib.util
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_spec = importlib.util.spec_from_file_location(
    "check_docs", os.path.join(REPO, "docs", "check_docs.py"))
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_extract_commands_from_bash_fences():
    md = ("intro\n"
          "```bash\n"
          "# a comment\n"
          "PYTHONPATH=src python x.py \\\n"
          "  --flag value\n"
          "\n"
          "python -m benchmarks.run --quick\n"
          "```\n"
          "```python\nprint('not a command')\n```\n")
    cmds = check_docs.extract_commands(md)
    assert len(cmds) == 2
    assert cmds[0].split() == ["PYTHONPATH=src", "python", "x.py",
                               "--flag", "value"]
    assert cmds[1] == "python -m benchmarks.run --quick"


def test_resolve_target_classification():
    rt = check_docs.resolve_target
    assert rt("PYTHONPATH=src python -m benchmarks.run --quick") == \
        ("benchmarks/run.py", True)
    assert rt("python examples/quickstart.py") == \
        ("examples/quickstart.py", False)
    assert rt("A=1 B=2 python benchmarks/dse_pareto.py --reduced") == \
        ("benchmarks/dse_pareto.py", False)
    # external tools are skipped, not failed
    assert rt("PYTHONPATH=src python -m pytest -x -q") == (None, True)
    assert rt("pip install numpy") == (None, False)
    assert rt("ls reports/") == (None, False)


def test_every_documented_command_resolves_and_parses():
    failures = check_docs.check(run_help=False, verbose=False)
    assert failures == [], "\n".join(failures)


def test_readme_links_the_docs():
    with open(os.path.join(REPO, "README.md")) as f:
        readme = f.read()
    for doc in ("docs/tutorial.md", "docs/api.md"):
        assert doc in readme, f"README must link {doc}"
        assert os.path.exists(os.path.join(REPO, doc))
