"""Model frontend (core/frontend.py + core/lm_workloads.py): per-family
analytic MAC checks, GQA/MoE/SSD lowering rules, scenario M-dim semantics,
and a full registry sweep."""

import dataclasses

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, ShapeSpec, applicable_shapes
from repro.core.frontend import extract_all, extract_workload
from repro.core.network import dedup_layers

DECODE = SHAPES["decode_32k"]
PREFILL = SHAPES["prefill_32k"]


def _layers_named(work, suffix):
    out = [(l, c) for l, c in zip(work.layers, work.counts)
           if l.name.endswith(suffix)]
    assert out, (suffix, [l.name for l in work.layers])
    return out


# ---------------------------------------------------------------------------
# Dense: closed-form MAC accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ["glm4-9b", "starcoder2-7b"])
def test_dense_decode_macs_match_param_count(arch_id):
    """A decode step does exactly one MAC per weight-matrix parameter per
    token: total extracted MACs == batch x matmul-param count (so FLOPs are
    the classic 2x active params per token). Embedding gather contributes
    no MACs and is excluded on both sides."""
    cfg = get_config(arch_id)
    work = extract_workload(cfg, DECODE)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    attn = d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d
    ffn = d * cfg.d_ff * ((2 if cfg.gated_mlp else 1) + 1)
    matmul_params = cfg.n_layers * (attn + ffn) + cfg.padded_vocab() * d
    assert work.total_macs == DECODE.global_batch * matmul_params


def test_prefill_vs_decode_differ_only_in_m():
    """Decode-vs-prefill GEMMs share all weight dims (K, C) and differ only
    in the token dim M — the property that makes whole-zoo solves cheap."""
    cfg = get_config("glm4-9b")
    pre = extract_workload(cfg, PREFILL)
    dec = extract_workload(cfg, DECODE)
    pre_by_suffix = {l.name.split(".")[-1]: l for l in pre.layers}
    for l in dec.layers:
        p = pre_by_suffix[l.name.split(".")[-1]]
        assert (l.bound("K"), l.bound("C")) == (p.bound("K"), p.bound("C"))
        assert l.bound("N") == DECODE.global_batch
        # prefill only materializes last-position logits for the LM head
        assert p.bound("N") == \
            (1 if l.name.endswith(".lm_head") else PREFILL.seq_len)


def test_lm_head_m_per_scenario():
    """Train: logits at every position; prefill: last position only;
    decode: one position per sequence, batched into M."""
    cfg = get_config("glm4-9b")
    heads = {s: _layers_named(w, ".lm_head")[0]
             for s, w in extract_all(cfg).items()}
    assert heads["train_4k"][0].bound("N") == SHAPES["train_4k"].seq_len
    assert heads["prefill_32k"][0].bound("N") == 1
    assert heads["decode_32k"][0].bound("N") == DECODE.global_batch


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def test_gqa_kv_projections_use_n_kv_heads():
    cfg = get_config("glm4-9b")          # extreme GQA: kv=2 of 32 heads
    work = extract_workload(cfg, DECODE)
    hd = cfg.resolved_head_dim
    (wk, _), = _layers_named(work, ".wk")
    (wv, _), = _layers_named(work, ".wv")
    (wq, _), = _layers_named(work, ".wq")
    assert wk.bound("K") == wv.bound("K") == cfg.n_kv_heads * hd == 2 * hd
    assert wq.bound("K") == cfg.n_heads * hd
    # K and V projections are structurally identical -> one dedup solve
    assert dedup_layers([wk, wv])[1][0] == dedup_layers([wk, wv])[1][1]


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _routed_macs(work):
    return sum(l.macs * c for l, c in zip(work.layers, work.counts)
               if ".exp." in l.name or l.name.endswith(
                   (".exp.ffn_up", ".exp.ffn_down")))


def test_moe_routed_macs_scale_with_top_k():
    cfg = get_config("qwen2-moe-a2.7b")
    base = _routed_macs(extract_workload(cfg, PREFILL))
    doubled = _routed_macs(extract_workload(
        dataclasses.replace(cfg, top_k=2 * cfg.top_k), PREFILL))
    assert doubled / base == pytest.approx(2.0, rel=0.01)
    # ...and are independent of the expert count (same active compute)
    spread = _routed_macs(extract_workload(
        dataclasses.replace(cfg, n_experts=2 * cfg.n_experts), PREFILL))
    assert spread / base == pytest.approx(1.0, rel=0.01)


def test_moe_shared_and_dense_residual_paths():
    # qwen: 4 shared experts see every token
    qwen = get_config("qwen2-moe-a2.7b")
    work = extract_workload(qwen, PREFILL)
    shared = _layers_named(work, ".shared.ffn_up")
    (l, c), = shared
    assert l.bound("N") == PREFILL.seq_len
    assert c == qwen.n_layers * PREFILL.instance_count * \
        qwen.n_shared_experts
    # arctic: dense-residual MLP in parallel with the routed experts
    arctic = get_config("arctic-480b")
    res = _layers_named(extract_workload(arctic, PREFILL), ".res.ffn_up")
    (l, _), = res
    assert l.bound("N") == PREFILL.seq_len and l.bound("C") == arctic.d_model


def test_moe_decode_expert_rows_floor_at_one():
    """A decode microbatch routed over many experts must never emit a
    zero-row GEMM (arctic: 128 tokens x top-2 over 128 experts -> 2)."""
    cfg = get_config("arctic-480b")
    work = extract_workload(cfg, DECODE)
    (l, c), = _layers_named(work, ".exp.ffn_up")
    assert l.bound("N") >= 1
    assert c == cfg.n_layers * cfg.n_experts


# ---------------------------------------------------------------------------
# SSD / hybrid
# ---------------------------------------------------------------------------

def test_ssd_block_decomposition_prefill():
    cfg = get_config("mamba2-1.3b")
    work = extract_workload(cfg, PREFILL)
    d_inner = cfg.ssm_expand * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    d_proj = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + nh
    (inp, _), = _layers_named(work, ".in_proj")
    assert (inp.bound("K"), inp.bound("C")) == (d_proj, cfg.d_model)
    (sc, c_sc), = _layers_named(work, ".ssd_scores")
    assert (sc.bound("N"), sc.bound("K"), sc.bound("C")) == \
        (256, 256, cfg.ssm_state)                  # Q x Q x N duality form
    n_chunks = PREFILL.seq_len // 256
    assert c_sc == cfg.n_layers * PREFILL.instance_count * n_chunks * nh


def test_ssd_decode_is_rank1_state_update():
    cfg = get_config("mamba2-1.3b")
    work = extract_workload(cfg, DECODE)
    (upd, c), = _layers_named(work, ".ssd_state_upd")
    assert (upd.bound("N"), upd.bound("K"), upd.bound("C")) == \
        (cfg.ssm_state, cfg.ssm_head_dim, 1)
    nh = cfg.ssm_expand * cfg.d_model // cfg.ssm_head_dim
    assert c == cfg.n_layers * DECODE.global_batch * nh


def test_hybrid_shared_attention_multiplicity():
    """Zamba2's attention block is parameter-shared but *executed* every
    ``attn_every`` mamba blocks — its count is applications, not layers."""
    cfg = get_config("zamba2-1.2b")
    work = extract_workload(cfg, PREFILL)
    (_, c), = _layers_named(work, "shared.wq")
    assert c == (cfg.n_layers // cfg.attn_every) * PREFILL.instance_count
    assert any(".in_proj" in l.name for l in work.layers)


# ---------------------------------------------------------------------------
# Enc-dec / VLM scenario semantics
# ---------------------------------------------------------------------------

def test_encdec_cross_attention_kv_cached_at_decode():
    cfg = get_config("seamless-m4t-large-v2")
    pre = extract_workload(cfg, PREFILL)
    dec = extract_workload(cfg, DECODE)
    # prefill: cross K/V project the encoder memory (frontend_seq rows)
    (xk, _), = _layers_named(pre, "xattn.wk")
    assert xk.bound("N") == cfg.frontend_seq
    # decode: encoder not re-run, cross K/V served from cache
    names = [l.name for l in dec.layers]
    assert not any(n.endswith(("xattn.wk", "xattn.wv")) for n in names)
    assert not any(".enc." in n for n in names)
    assert any(n.endswith("xattn.wq") for n in names)


def test_vlm_prefill_prepends_patch_tokens():
    cfg = get_config("pixtral-12b")
    pre = extract_workload(cfg, PREFILL)
    dec = extract_workload(cfg, DECODE)
    (wq_p, _), = _layers_named(pre, ".wq")
    (wq_d, _), = _layers_named(dec, ".wq")
    assert wq_p.bound("N") == PREFILL.seq_len + cfg.frontend_seq
    assert wq_d.bound("N") == DECODE.global_batch


# ---------------------------------------------------------------------------
# Registry sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_registry_sweep_extracts_valid_workloads(arch_id):
    """Every config extracts a non-empty, positive-dims, all-GEMM workload
    for every one of its applicable ShapeSpecs."""
    cfg = get_config(arch_id)
    shapes = {n for n, s in applicable_shapes(cfg).items() if s is not None}
    works = extract_all(cfg)
    assert set(works) == shapes
    for sname, work in works.items():
        assert len(work) > 0, (arch_id, sname)
        assert work.total_macs > 0
        for l, c in zip(work.layers, work.counts):
            assert c >= 1
            assert l.is_gemm, l.name
            for d in ("N", "K", "C"):
                assert l.bound(d) >= 1, (l.name, d)
        assert any(l.name.endswith(".lm_head") for l in work.layers)


def test_registry_sweep_dedup_beats_extraction_count():
    """Pooled across the zoo, structural dedup must need fewer solves than
    extracted layers (the acceptance property of the lm benchmark)."""
    pool = []
    for aid in ARCH_IDS:
        for work in extract_all(get_config(aid),
                                ("prefill_32k", "decode_32k")).values():
            pool += list(work.layers)
    unique, _ = dedup_layers(pool)
    assert 0 < len(unique) < len(pool)


def test_reduced_configs_extract_too():
    """The CI smoke path: reduced configs stay extractable everywhere."""
    for aid in ARCH_IDS:
        cfg = get_config(aid).reduced()
        for work in extract_all(cfg).values():
            assert len(work) > 0 and work.total_macs > 0


def test_custom_serving_spec():
    """serve_lm.py-style ad-hoc ShapeSpec (decode batch 4)."""
    spec = ShapeSpec("serve", seq_len=1, global_batch=4, kind="decode")
    work = extract_workload(get_config("glm4-9b").reduced(), spec)
    assert all(l.bound("N") == 4 for l in work.layers
               if not l.name.endswith(("ssd_state_upd", "ssd_readout")))
