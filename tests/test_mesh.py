"""Property/differential tests for the multi-chip mesh layer
(`core/mesh.py`, `scheduler.schedule_mesh`, DESIGN.md §Mesh optimization).

Properties (hypothesis when available, else the seeded shim from
``tests/test_mapping_fuzz.py``):

  * **N=1 identity** — ``optimize_network(mesh=MeshArch(chip, 1))`` is the
    single-chip path bit for bit: totals, per-layer records AND the
    schedule.
  * **Link-bandwidth monotonicity** — every per-layer mesh record's cycles
    are monotone non-increasing in the link bandwidth (the min over shard
    choices of monotone per-choice curves).
  * **Residency capacity** — no pipelined mesh segment ever over-commits a
    chip's macro bytes or its core budget.
  * **MIP >= greedy** — the (chip, core) placement MIP never schedules
    worse than the greedy water-filling placement (both judged by the
    scheduled end-to-end cycles, the metric segments are billed with).

Differential: the mesh schedule's analytical segment model against the
event replay (`simulator.simulate_segment` network mode with inter-chip
xfer), gated at the Fig. 4(a) 0.8 mean-agreement floor
(`scheduler.cross_check_mesh` — the same tolerance `scheduler.cross_check`
uses on the single-chip path).

Pinned regressions: `sharding.rules.mesh_tp_choices` fallbacks (attention
heads not divisible, MoE ``E % n != 0``) resolve to valid chip-replicated
placements instead of raising, and the CACHE_VERSION-6 key separation for
meshes differing only in link bandwidth.
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(fn, "_max_examples", 25)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

from repro.core import workload as wl
from repro.core.arch import MeshLink, default_arch
from repro.core.cache import (ResultCache, arch_cache_key, layer_cache_key,
                              solve_record_key)
from repro.core.formulation import FormulationConfig
from repro.core.mesh import (REPLICATE, SHARD_CHOICES, SPLIT_K, SPLIT_N,
                             MeshArch, make_mesh, optimize_mesh_network,
                             residency_feasible, shard_choices,
                             shard_sub_layer)
from repro.core.network import optimize_network
from repro.core.scheduler import (chip_macro_bytes, cross_check_mesh,
                                  schedule_mesh)

#: Tiny chip (the fuzz grid's) so greedy solves and schedules stay cheap.
CHIP = default_arch(n_cores=2, macro_rows=64, macro_cols=16, gbuf_kb=2.0,
                    lbuf_kb=8.0, name="mesh-tiny")

#: Dims divisible by 2 and 4 so both TP splits stay available.
M_CHOICES = (4, 8, 16, 24)
KC_CHOICES = (16, 32, 48, 96)


def _workload(seed: int, n_layers: int):
    rng = random.Random(seed)
    layers = [wl.gemm(f"mz{i}", rng.choice(M_CHOICES),
                      rng.choice(KC_CHOICES), rng.choice(KC_CHOICES))
              for i in range(n_layers)]
    counts = [rng.choice((1, 1, 2, 3)) for _ in layers]
    return layers, counts


def _opt(layers, counts, mesh, **kw):
    return optimize_network(layers, mesh=mesh, mode="greedy",
                            counts=counts, use_cache=False, **kw)


# ---------------------------------------------------------------------------
# Property: N=1 mesh == single chip, bit for bit
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_n1_mesh_is_single_chip(seed, n_layers):
    layers, counts = _workload(seed, n_layers)
    single = optimize_network(layers, CHIP, "greedy", counts=counts,
                              use_cache=False)
    meshed = _opt(layers, counts, make_mesh(CHIP, 1))
    assert meshed.totals == single.totals
    assert meshed.scheduled == single.scheduled
    assert meshed.arch_name == single.arch_name == CHIP.name
    for a, b in zip(meshed.layers, single.layers):
        assert a.record == b.record
    sa, sb = meshed.schedule, single.schedule
    assert sa.scheduled_cycles == sb.scheduled_cycles
    assert [seg.mode for seg in sa.segments] == \
        [seg.mode for seg in sb.segments]


def test_schedule_mesh_n1_delegates():
    layers, counts = _workload(7, 3)
    net = optimize_network(layers, CHIP, "greedy", counts=counts,
                           use_cache=False, schedule=False)
    direct = schedule_mesh(net.layers, make_mesh(CHIP, 1))
    single = optimize_network(layers, CHIP, "greedy", counts=counts,
                              use_cache=False).schedule
    assert direct.scheduled_cycles == single.scheduled_cycles
    assert direct.arch_name == CHIP.name


# ---------------------------------------------------------------------------
# Property: per-layer mesh cycles monotone non-increasing in link bandwidth
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(st.integers(0, 10_000), st.integers(1, 3),
       st.sampled_from((2, 4)), st.sampled_from(("ring", "grid")))
def test_cycles_monotone_in_link_bandwidth(seed, n_layers, n_chips, topo):
    layers, counts = _workload(seed, n_layers)
    prev = None
    for bits in (32, 64, 256, 1024):
        mesh = make_mesh(CHIP, n_chips, topology=topo,
                         link=MeshLink(bandwidth_bits=bits))
        net = _opt(layers, counts, mesh, schedule=False)
        cycles = [lr.record["cycles"] for lr in net.layers]
        if prev is not None:
            for lo, hi, lr in zip(cycles, prev, net.layers):
                assert lo <= hi + 1e-9, \
                    (bits, lr.layer.name, lr.record["shard"], lo, hi)
        prev = cycles


# ---------------------------------------------------------------------------
# Property: packed segments respect per-chip residency + core budgets
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(st.integers(0, 10_000), st.integers(2, 5), st.sampled_from((2, 4)))
def test_chip_residency_never_exceeded(seed, n_layers, n_chips):
    layers, counts = _workload(seed, n_layers)
    mesh = make_mesh(CHIP, n_chips)
    net = _opt(layers, counts, mesh)
    cap = chip_macro_bytes(CHIP)
    n_cores = 2
    for seg in net.schedule.segments:
        if seg.mode != "pipelined":
            continue
        used_b = [0] * n_chips
        used_c = [0] * n_chips
        for stp in seg.stages:
            if stp.span_all:
                for g in range(n_chips):
                    used_b[g] += stp.load_bytes
                    used_c[g] += stp.cores
            else:
                assert 0 <= stp.chip < n_chips, stp
                used_b[stp.chip] += stp.load_bytes
                used_c[stp.chip] += stp.cores
        assert all(b <= cap for b in used_b), (used_b, cap)
        assert all(c <= n_cores for c in used_c), used_c


# ---------------------------------------------------------------------------
# Property: placement MIP never worse than greedy water-filling
# ---------------------------------------------------------------------------

@settings(max_examples=6)
@given(st.integers(0, 10_000), st.integers(2, 5), st.sampled_from((2, 4)))
def test_placement_mip_never_worse_than_greedy(seed, n_layers, n_chips):
    pytest.importorskip("scipy")
    layers, counts = _workload(seed, n_layers)
    mesh = make_mesh(CHIP, n_chips)
    net = _opt(layers, counts, mesh, schedule=False)
    mip = schedule_mesh(net.layers, mesh, use_mip=True)
    greedy = schedule_mesh(net.layers, mesh, use_mip=False)
    assert mip.scheduled_cycles <= greedy.scheduled_cycles + 1e-6
    assert mip.scheduled_cycles <= mip.serial_cycles + 1e-9


# ---------------------------------------------------------------------------
# Differential: analytical mesh segments vs event replay (Fig. 4(a) gate)
# ---------------------------------------------------------------------------

def test_mesh_sim_agreement():
    layers = [wl.gemm("s0", 8, 16, 32), wl.gemm("s1", 8, 32, 16),
              wl.gemm("s2", 8, 16, 16), wl.gemm("s3", 8, 8, 32)]
    counts = [1, 1, 2, 1]
    checked = 0
    for n_chips in (2, 4):
        mesh = make_mesh(CHIP, n_chips)
        net = _opt(layers, counts, mesh)
        acc, n = cross_check_mesh(net.schedule, mesh)
        checked += n
        assert acc >= 0.8, (n_chips, acc)    # the fig4a tolerance
    assert checked >= 1, "no pipelined mesh segment was replayed"


# ---------------------------------------------------------------------------
# Pinned: sharding-rule fallbacks resolve to valid placements
# ---------------------------------------------------------------------------

def test_rules_constants_identical():
    from repro.sharding import rules
    assert (rules.m_REPLICATE, rules.m_SPLIT_N, rules.m_SPLIT_K) == \
        SHARD_CHOICES == (REPLICATE, SPLIT_N, SPLIT_K)


def test_mesh_tp_choices_pinned():
    from repro.sharding.rules import mesh_tp_choices
    # plain divisibility: both splits offered
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=96) == \
        (REPLICATE, SPLIT_N, SPLIT_K)
    # only one dim divides
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=50) == \
        (REPLICATE, SPLIT_N)
    assert mesh_tp_choices(4, out_channels=50, reduce_dim=96) == \
        (REPLICATE, SPLIT_K)
    # 1 chip: no TP
    assert mesh_tp_choices(1, out_channels=96, reduce_dim=96) == (REPLICATE,)
    # attention heads not divisible -> replicate-only fallback (attn_tp)
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=96,
                           n_heads=6) == (REPLICATE,)
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=96,
                           n_heads=8) == (REPLICATE, SPLIT_N, SPLIT_K)
    # MoE E % n == 0 -> EP as replicated instances, no intra-GEMM split
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=96,
                           n_experts=8) == (REPLICATE,)
    # MoE E % n != 0 -> TP inside experts by plain divisibility
    assert mesh_tp_choices(4, out_channels=96, reduce_dim=96,
                           n_experts=6) == (REPLICATE, SPLIT_N, SPLIT_K)


def test_fallback_yields_valid_replicated_record():
    # indivisible dims: the mesh path must produce a valid chip-replicated
    # record instead of raising
    layer = wl.gemm("odd", 8, 50, 50)      # 50 % 4 != 0 on both split dims
    mesh = make_mesh(CHIP, 4)
    assert shard_choices(layer, mesh) == (REPLICATE,)
    assert shard_choices(layer, mesh, n_heads=6) == (REPLICATE,)
    net = _opt([layer], [1], mesh)
    rec = net.layers[0].record
    assert rec["shard"]["choice"] == REPLICATE
    assert rec["shard"]["n_active"] == 1
    assert rec["comm_cycles"] == 0.0
    assert rec["cycles"] == rec["chip_cycles"]
    # sub layer of a replicate shard IS the layer
    assert shard_sub_layer(layer, REPLICATE, 4) is layer


# ---------------------------------------------------------------------------
# Pinned: cache key separation (CACHE_VERSION 6 mesh fields)
# ---------------------------------------------------------------------------

def test_cache_key_separation_link_bandwidth(tmp_path):
    layer = wl.gemm("ck", 8, 32, 32)
    cfg = FormulationConfig(time_limit_s=1.0)
    mesh_a = make_mesh(CHIP, 2, link=MeshLink(bandwidth_bits=128))
    mesh_b = make_mesh(CHIP, 2, link=MeshLink(bandwidth_bits=256))
    # two meshes differing ONLY in link bandwidth never share records
    assert arch_cache_key(mesh_a) != arch_cache_key(mesh_b)
    ka = solve_record_key("greedy", layer, mesh_a, cfg)
    kb = solve_record_key("greedy", layer, mesh_b, cfg)
    assert ka != kb
    # ... and the mesh key is not the chip key either
    assert arch_cache_key(mesh_a) != arch_cache_key(CHIP)
    # deterministic: same structural mesh (name differs) -> same key
    mesh_a2 = make_mesh(CHIP, 2, link=MeshLink(bandwidth_bits=128),
                        name="other-name")
    assert solve_record_key("greedy", layer, mesh_a2, cfg) == ka
    # ResultCache isolation end to end
    cache = ResultCache(tmp_path)
    cache.put(ka, {"cycles": 1.0})
    assert cache.get(ka) == {"cycles": 1.0}
    assert cache.get(kb) is None


def test_mesh_record_caching_roundtrip(tmp_path):
    layers, counts = _workload(3, 2)
    mesh = make_mesh(CHIP, 2)
    cache = ResultCache(tmp_path)
    r1 = optimize_mesh_network(layers, mesh, "greedy", counts=counts,
                               cache=cache, schedule=False)
    assert r1.n_solved == r1.n_unique
    r2 = optimize_mesh_network(layers, mesh, "greedy", counts=counts,
                               cache=cache, schedule=False)
    assert r2.cache_hits == r2.n_unique and r2.n_solved == 0
    assert r2.totals == r1.totals
    for a, b in zip(r2.layers, r1.layers):
        assert a.record == b.record


# ---------------------------------------------------------------------------
# Geometry + feasibility sanity
# ---------------------------------------------------------------------------

def test_mesh_geometry():
    ring = make_mesh(CHIP, 4, topology="ring")
    assert ring.chip_distance(0, 3) == 1          # wraparound
    assert ring.chip_distance(0, 2) == 2
    assert ring.bcast_hops() == 2
    grid = make_mesh(CHIP, 4, topology="grid")
    assert grid.grid_dims() == (2, 2)
    assert grid.chip_distance(0, 3) == 2          # manhattan
    assert grid.bcast_hops() == 2
    with pytest.raises(AssertionError):
        MeshArch(chip=CHIP, n_chips=0).validate()
    with pytest.raises(AssertionError):
        MeshArch(chip=CHIP, n_chips=2, topology="torus").validate()


def test_residency_feasibility_scaling():
    # weights sized to overflow 1 chip and fit 2
    cap = chip_macro_bytes(CHIP)
    k = 32
    n_layers = cap // (k * k) + 1
    layers = [wl.gemm(f"rf{i}", 4, k, k) for i in range(n_layers)]
    assert not residency_feasible(layers, None, make_mesh(CHIP, 1))
    assert residency_feasible(layers, None, make_mesh(CHIP, 2))
