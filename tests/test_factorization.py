"""Property tests for Flexible Factorization (paper Alg. 1).

Runs under ``hypothesis`` when available; otherwise falls back to a small
seeded-random strategy shim so the tier-1 suite collects and the invariants
still get exercised on a bare environment (no extra deps required).
"""

import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    _N_EXAMPLES = 60

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def floats(lo, hi):
            return _Strategy(lambda rng: rng.uniform(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elem.draw(rng)
                for _ in range(rng.randint(min_size, max_size))])

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                for _ in range(_N_EXAMPLES):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**_kwargs):
        return lambda fn: fn

from repro.core.factorization import (flex_score, flexible_factorization,
                                      prime_factors, sub_multiset_products)


@given(st.integers(2, 100_000))
@settings(max_examples=200, deadline=None)
def test_prime_factors_product(n):
    fs = prime_factors(n)
    assert math.prod(fs) == n
    assert all(p >= 2 for p in fs)


@given(st.integers(2, 50_000), st.floats(0.0, 1.0), st.integers(1, 6))
@settings(max_examples=100, deadline=None)
def test_flexfact_invariants(n, alpha, k_min):
    f = flexible_factorization(n, alpha, k_min)
    assert math.prod(f) == n
    assert len(f) >= min(k_min, len(prime_factors(n)))
    # merging can only reduce factor count vs the prime pool
    assert len(f) <= len(prime_factors(n))


def test_flexfact_trivial():
    assert flexible_factorization(1) == []
    assert flexible_factorization(7) == [7]
    assert flexible_factorization(8, k_min=3) == [2, 2, 2]


def test_merging_reduces_flex_score():
    # FlexScore must not increase when factors merge (fewer partitions)
    full = (2, 2, 2, 2, 2)
    merged = (4, 2, 2, 2)
    assert flex_score(merged) <= flex_score(full)


@given(st.lists(st.sampled_from([2, 3, 4, 5, 7, 8]), min_size=0,
                max_size=6))
@settings(max_examples=100, deadline=None)
def test_sub_multiset_products(factors):
    prods = sub_multiset_products(factors)
    assert 1 in prods
    assert math.prod(factors) in prods
    total = math.prod(factors)
    for p in prods:
        assert total % p == 0


def test_flexscore_large_bound_fast():
    """32768 = 2^15 — the partition count must come from the memoized DP,
    not 3^15 enumeration (paper's motivation: search-space control)."""
    f = flexible_factorization(32768, alpha=0.15, k_min=3)
    assert math.prod(f) == 32768
    assert len(f) <= 6
