"""End-to-end behaviour of the paper's system: the full MIREDO pipeline
(factorize -> MIP -> decode -> evaluate) beats both baselines on a GEMM
layer, and the public config/registry surface is complete."""

import pytest

from repro.configs import ARCH_IDS, SHAPES, all_configs, applicable_shapes
from repro.core import default_arch, gemm
from repro.core.baselines import greedy_mapping, heuristic_search
from repro.core.formulation import FormulationConfig, optimize_layer
from repro.core.latency import evaluate
from repro.core.mapping import validate


def test_miredo_end_to_end_beats_baselines():
    arch = default_arch()
    layer = gemm("sys", 64, 128, 256)
    greedy = evaluate(greedy_mapping(layer, arch), layer, arch).total_cycles
    res = optimize_layer(layer, arch, FormulationConfig(time_limit_s=60))
    assert res.mapping is not None
    assert validate(res.mapping, layer, arch) == []
    # never worse than the incumbent by construction
    assert res.eval_latency <= greedy * 1.001
    # the idealized-model heuristic should not beat the MIP on accuracy
    heur = heuristic_search(layer, arch, budget=500, seed=0)
    assert res.eval_latency <= heur.eval_latency * 1.05


def test_registry_complete():
    cfgs = all_configs()
    assert len(cfgs) == 10
    for arch_id in ARCH_IDS:
        cfg = cfgs[arch_id]
        assert cfg.param_count() > 1e8, arch_id
        assert cfg.active_param_count() <= cfg.param_count()
        app = applicable_shapes(cfg)
        assert set(app) == set(SHAPES)
        if cfg.family in ("ssm", "hybrid"):
            assert app["long_500k"] is not None
        else:
            assert app["long_500k"] is None
        # reduced configs stay in-family
        red = cfg.reduced()
        assert red.family == cfg.family
        assert red.d_model <= 64


def test_param_counts_match_public_figures():
    """Sanity: computed parameter counts are within 25% of the models'
    published sizes (config fidelity check)."""
    expected = {
        "internlm2-20b": 20e9, "glm4-9b": 9.4e9, "starcoder2-7b": 7.2e9,
        "minicpm-2b": 2.4e9, "qwen2-moe-a2.7b": 14.3e9,
        "arctic-480b": 482e9, "mamba2-1.3b": 1.3e9,
        "pixtral-12b": 12e9, "zamba2-1.2b": 1.2e9,
    }
    cfgs = all_configs()
    for arch_id, target in expected.items():
        got = cfgs[arch_id].param_count()
        assert 0.7 * target < got < 1.35 * target, \
            (arch_id, got, target)
