"""Racing solver portfolio (core/portfolio.py) + the ISSUE-10 solver-budget
contract fixes: ladder deadline sharing, scipy-status mapping (SUSPECT),
portfolio determinism / cache-key separation / shared-incumbent guarantees.
"""

import copy
import dataclasses
import math
import time

import pytest

from repro.core.arch import default_arch
from repro.core.cache import solve_layer, solve_record_key
from repro.core.formulation import (BIG_M_FLOOR, FormulationConfig,
                                    MiredoResult, optimize_layer)
from repro.core.mapping import validate
from repro.core.mip.model import Solution, Status, status_of
from repro.core.network import optimize_network
from repro.core.portfolio import (Portfolio, PortfolioMember,
                                  default_portfolio, race)
from repro.core.workload import conv, gemm

ARCH = default_arch()

#: A portfolio whose members all terminate on optimality/infeasibility in
#: milliseconds on tiny layers (coarse rungs only) — deterministic by
#: construction, so reruns must be bit-identical.
FAST_PF = Portfolio(members=(
    PortfolioMember(name="c1", rung=1),
    PortfolioMember(name="c2", rung=2),
    PortfolioMember(name="c1g", rung=1, seed="greedy"),
))


# ---------------------------------------------------------------------------
# scipy status mapping (Status.SUSPECT)
# ---------------------------------------------------------------------------

def test_status_mapping_table():
    """The full raw-status x has-solution table, explicitly: status 4
    (numerical trouble) with an assignment must surface as SUSPECT, not
    silently pass as FEASIBLE (the pre-fix behavior)."""
    assert status_of(0, True) is Status.OPTIMAL
    assert status_of(0, False) is Status.OPTIMAL
    assert status_of(1, True) is Status.FEASIBLE
    assert status_of(1, False) is Status.ERROR
    assert status_of(2, False) is Status.INFEASIBLE
    assert status_of(3, False) is Status.UNBOUNDED
    assert status_of(4, True) is Status.SUSPECT
    assert status_of(4, False) is Status.ERROR
    # unknown future scipy codes behave like status 4
    assert status_of(99, True) is Status.SUSPECT
    assert status_of(99, False) is Status.ERROR


def test_suspect_usable_but_not_ok():
    """`ok` keeps its conservative meaning (scheduler/mesh consume it
    without re-validating); `usable` additionally admits SUSPECT so the
    validate-then-fallback caller can inspect the assignment."""
    sus = Solution(status=Status.SUSPECT, objective=1.0, values=[1.0],
                   model=None, raw_status=4)
    assert not sus.ok and sus.usable
    ok = Solution(status=Status.OPTIMAL, objective=1.0, values=[1.0],
                  model=None, raw_status=0)
    assert ok.ok and ok.usable
    err = Solution(status=Status.ERROR, objective=math.nan, values=None,
                   model=None, raw_status=1)
    assert not err.ok and not err.usable


# ---------------------------------------------------------------------------
# Budget contract (the ladder overshoot bugfix)
# ---------------------------------------------------------------------------

def test_forced_ladder_stays_within_budget():
    """A combo_cap that overflows the finest rung forces the ladder to
    coarsen mid-solve. Pre-fix, every rung re-floored its budget at
    ``max(min(5, limit), remaining)`` so this solve could take
    ``time_limit_s + ~10 s``; post-fix all rungs share one deadline.
    (epsilon covers process scheduling plus HiGHS's internal clock-check
    granularity.)"""
    layer = conv("ladder", 1, 64, 64, 28, 28, 3, 3)
    cfg = FormulationConfig(time_limit_s=5.0, combo_cap=800)
    t0 = time.monotonic()
    res = optimize_layer(layer, ARCH, cfg)
    wall = time.monotonic() - t0
    assert res.mapping is not None
    assert not validate(res.mapping, layer, ARCH)
    assert res.solve_seconds <= 5.0 + 1.0, res.solve_seconds
    assert wall <= 5.0 + 1.0, wall


def test_portfolio_race_stays_within_budget():
    layer = gemm("pfbudget", 64, 128, 32)
    cfg = FormulationConfig(time_limit_s=3.0)
    t0 = time.monotonic()
    out = race(layer, ARCH, cfg, default_portfolio())
    wall = time.monotonic() - t0
    assert out.result.solve_seconds <= 3.0 + 1.0
    assert wall <= 3.0 + 1.0
    # per-member slices are charged inside the same deadline
    assert sum(m.solve_seconds for m in out.members) <= 3.0 + 1.0


def test_expired_deadline_returns_incumbent_fallback():
    """A zero budget must still return the (validated) incumbent — never
    None, never a crash, and nearly instantly."""
    layer = gemm("zb", 32, 64, 64)
    cfg = FormulationConfig(time_limit_s=0.0)
    res = optimize_layer(layer, ARCH, cfg)
    assert res.mapping is not None
    assert not validate(res.mapping, layer, ARCH)
    assert res.status is Status.ERROR
    assert res.eval_latency == res.incumbent_latency
    assert not res.improved


# ---------------------------------------------------------------------------
# Portfolio determinism
# ---------------------------------------------------------------------------

#: Per-member fields that are legitimately timing-dependent (wall clock,
#: and HiGHS diagnostics that depend on where the clock stopped it: gap,
#: node count, dual bound — also NaN for fallback members, and NaN never
#: compares equal). The determinism contract is everything else: winner,
#: mapping, cycles, status.
_TIMING_FIELDS = ("solve_seconds", "mip_gap", "mip_node_count",
                  "mip_dual_bound")


def _strip_times(outcome_json: dict) -> dict:
    out = copy.deepcopy(outcome_json)
    for m in out["members"]:
        for f in _TIMING_FIELDS:
            m.pop(f, None)
    return out


def test_race_rerun_bit_identical():
    layer = gemm("det", 8, 16, 16)
    cfg = FormulationConfig(time_limit_s=5.0)
    a = race(layer, ARCH, cfg, FAST_PF)
    b = race(layer, ARCH, cfg, FAST_PF)
    assert a.winner == b.winner
    assert a.result.eval_latency == b.result.eval_latency
    assert a.result.mapping == b.result.mapping
    assert a.result.status is b.result.status
    assert _strip_times(a.to_json()) == _strip_times(b.to_json())
    # every member terminated deterministically (not on the wall clock)
    assert all(m.status in ("OPTIMAL", "INFEASIBLE") for m in a.members)


def test_race_winner_prefers_earliest_member_on_tie():
    """(eval_latency, member_index) ordering: duplicating the winning
    member cannot move the win to the later copy."""
    layer = gemm("tie", 8, 16, 16)
    cfg = FormulationConfig(time_limit_s=5.0)
    pf = Portfolio(members=(PortfolioMember(name="a", rung=1),
                            PortfolioMember(name="b", rung=1)))
    out = race(layer, ARCH, cfg, pf)
    assert out.members[0].eval_latency == out.members[1].eval_latency
    assert out.winner == 0


def _strip_record_times(rec: dict) -> dict:
    rec = copy.deepcopy(rec)
    rec.pop("solve_s")
    for f in _TIMING_FIELDS:
        rec.pop(f, None)
    for m in rec.get("portfolio", {}).get("members", ()):
        for f in _TIMING_FIELDS:
            m.pop(f, None)
    return rec


def test_network_portfolio_identical_across_worker_counts():
    """The race runs inside ONE worker process per layer, so the winning
    record must not depend on how many workers fan the layers out."""
    layers = [gemm("w0", 8, 16, 16), gemm("w1", 16, 32, 8),
              gemm("w2", 8, 8, 32)]
    kw = dict(mode="miredo", cfg=FormulationConfig(time_limit_s=4.0),
              use_cache=False, schedule=False, portfolio=FAST_PF)
    r1 = optimize_network(layers, ARCH, workers=1, **kw)
    r2 = optimize_network(layers, ARCH, workers=2, **kw)
    for a, b in zip(r1.layers, r2.layers):
        assert _strip_record_times(a.record) == _strip_record_times(b.record)


# ---------------------------------------------------------------------------
# Shared incumbents / never-worse guarantees
# ---------------------------------------------------------------------------

def test_race_seeded_with_single_solve_never_worse():
    """The incumbent-sharing mechanism: a race seeded with the single
    solve's mapping can never return a worse eval_latency than it (the
    seed joins every member's pool and the fallback)."""
    layer = gemm("seeded", 256, 512, 64)
    cfg = FormulationConfig(time_limit_s=2.0)
    single = optimize_layer(layer, ARCH, cfg)
    out = race(layer, ARCH, cfg, default_portfolio(),
               warm_start=single.mapping)
    assert out.result.eval_latency <= single.eval_latency


def test_member_sees_earlier_members_ub():
    """A later member whose own seed is weak still races with the running
    shared UB: its outcome can never be worse than what an earlier member
    already found (the shared incumbent backstops its fallback)."""
    layer = gemm("shared", 8, 16, 16)
    cfg = FormulationConfig(time_limit_s=5.0)
    out = race(layer, ARCH, cfg, FAST_PF)
    best_so_far = math.inf
    for m in out.members:
        if m.status == "SKIPPED":
            continue
        assert m.eval_latency <= best_so_far or m.eval_latency == math.inf
        best_so_far = min(best_so_far, m.eval_latency)


def test_portfolio_result_never_worse_than_incumbent():
    out = race(gemm("nw", 64, 128, 32), ARCH,
               FormulationConfig(time_limit_s=1.0), default_portfolio())
    assert out.result.eval_latency <= out.result.incumbent_latency


# ---------------------------------------------------------------------------
# Cache-key separation
# ---------------------------------------------------------------------------

def test_cache_key_separates_portfolio_configs():
    layer = gemm("key", 32, 64, 64)
    cfg = FormulationConfig(time_limit_s=5.0)
    k_none = solve_record_key("miredo", layer, ARCH, cfg)
    k_def = solve_record_key("miredo", layer, ARCH, cfg,
                             portfolio=default_portfolio())
    k_fast = solve_record_key("miredo", layer, ARCH, cfg, portfolio=FAST_PF)
    assert len({k_none, k_def, k_fast}) == 3
    # stable: the same grid digests to the same key
    assert k_def == solve_record_key("miredo", layer, ARCH, cfg,
                                     portfolio=default_portfolio())
    # member order is result-affecting (slices, shared-UB flow, ties)
    rev = Portfolio(members=tuple(reversed(default_portfolio().members)))
    assert solve_record_key("miredo", layer, ARCH, cfg, portfolio=rev) != \
        k_def
    # baseline modes never run the MIP: the portfolio must not fork keys
    assert solve_record_key("greedy", layer, ARCH, cfg,
                            portfolio=default_portfolio()) == \
        solve_record_key("greedy", layer, ARCH, cfg)


def test_solve_layer_portfolio_record_fields():
    rec = solve_layer(gemm("rec", 8, 16, 16), ARCH, "miredo",
                      FormulationConfig(time_limit_s=4.0),
                      portfolio=FAST_PF)
    assert rec["status"] in ("OPTIMAL", "FEASIBLE", "INFEASIBLE", "ERROR")
    assert math.isfinite(rec["incumbent_cycles"])
    assert isinstance(rec["improved"], bool)
    pf = rec["portfolio"]
    assert pf["winner"] == 0 and len(pf["members"]) == len(FAST_PF.members)
    assert rec["improved"] == (rec["cycles"] < rec["incumbent_cycles"])
    # baseline modes ignore the portfolio and carry no solver diagnostics
    base = solve_layer(gemm("rec", 8, 16, 16), ARCH, "greedy",
                       FormulationConfig(), portfolio=FAST_PF)
    assert "portfolio" not in base and "incumbent_cycles" not in base


# ---------------------------------------------------------------------------
# MiredoResult.improved
# ---------------------------------------------------------------------------

def test_improved_property():
    base = dict(mapping=None, status=Status.OPTIMAL, objective=0.0,
                mip_latency=1.0, solve_seconds=0.0, n_vars=0, n_rows=0,
                mip_gap=0.0)
    assert MiredoResult(eval_latency=90.0, incumbent_latency=100.0,
                        **base).improved
    assert not MiredoResult(eval_latency=100.0, incumbent_latency=100.0,
                            **base).improved
    # unknown incumbent -> never claims improvement
    assert not MiredoResult(eval_latency=90.0, **base).improved
