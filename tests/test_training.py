"""Training frontend (`core/training.py`): backward-pass lowering,
optimizer-step pricing, written-residency scheduling and the mesh
gradient path.

Pinned closed-form regressions (dense backward exactly doubles the
forward GEMM MACs; MoE wGrad only for the experts actually hit; LM-head
dGrad M semantics) plus a property fuzz: every dGrad/wGrad mapping the
MIP returns re-validates against eq. 9 with the transposed dims. Runs
under ``hypothesis`` when available, else the seeded-random shim (the
tier-1 fallback pattern from ``tests/test_mapping_fuzz.py``).
"""

import math
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                   # seeded fallback
    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(lambda rng: rng.randint(lo, hi))

        @staticmethod
        def sampled_from(seq):
            return _Strategy(lambda rng: rng.choice(list(seq)))

    st = _Strategies()

    def given(*strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)
                n = getattr(fn, "_max_examples", 25)
                for _ in range(n):
                    fn(*args, *(s.draw(rng) for s in strategies), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(max_examples=25, **_kwargs):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core import workload as wl
from repro.core.arch import OPERANDS, default_arch
from repro.core.cache import layer_cache_key
from repro.core.frontend import extract_workload
from repro.core.mapping import validate
from repro.core.mesh import make_mesh
from repro.core.network import optimize_network
from repro.core.scheduler import weight_residency
from repro.core.training import (backward_dataflow_diffs, backward_gemms,
                                 cycle_splits, dataflow_signature,
                                 optimizer_update_cost, phase_of,
                                 routed_hit_experts, trainable_params,
                                 update_bytes_per_param)

ARCH = default_arch()
SPEC = ShapeSpec("t_train", 64, 2, "train")


def _pairs(work):
    return list(zip(work.layers, work.counts))


def _phase(work, phase):
    return [(l, c) for l, c in _pairs(work) if phase_of(l) == phase]


def _macs(pairs):
    return sum(l.macs * c for l, c in pairs)


# ---------------------------------------------------------------------------
# Closed-form backward regressions
# ---------------------------------------------------------------------------

def test_dense_backward_exactly_doubles_forward():
    """Dense model: dGrad + wGrad each mirror their forward GEMM's MACs,
    and the embedding path contributes zero MACs on both sides — so the
    backward total is exactly 2x the forward GEMM total."""
    work = extract_workload(get_config("minicpm-2b").reduced(), SPEC)
    fwd, dgrad, wgrad = (_phase(work, p) for p in ("fwd", "dgrad", "wgrad"))
    assert _macs(dgrad) == _macs(fwd)
    assert _macs(wgrad) == _macs(fwd)
    assert _macs(dgrad) + _macs(wgrad) == 2 * _macs(fwd)


def test_moe_wgrad_only_for_hit_experts():
    """Routed experts: dGrad mirrors the forward multiplicities, but the
    wGrad count scales to min(E, m*top_k) — an expert no token landed on
    accumulates no weight gradient."""
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    spec = ShapeSpec("t_tiny", 2, 1, "train")   # 2 tokens x top_k=2 < E=8
    n_hit = routed_hit_experts(cfg, spec.m_tokens)
    assert 0 < n_hit < cfg.n_experts
    work = extract_workload(cfg, spec)
    by_name = {}
    for l, c in _pairs(work):
        by_name.setdefault(l.name, 0)
        by_name[l.name] += c
    for leaf in ("ffn_up", "ffn_down"):
        base = f"{cfg.name}.blk.exp.{leaf}"
        fwd_c = by_name[base]
        assert by_name[f"{base}.dgrad"] == fwd_c
        assert by_name[f"{base}.wgrad"] == fwd_c // cfg.n_experts * n_hit
        # shared experts are always applied: full backward
        shared = f"{cfg.name}.blk.shared.{leaf}"
        assert by_name[f"{shared}.wgrad"] == by_name[shared]
    # and the aggregate closed form: 2x forward minus the un-hit share
    fwd = _phase(work, "fwd")
    routed = _macs([(l, c) for l, c in fwd if ".exp." in l.name])
    missed = routed * (cfg.n_experts - n_hit) // cfg.n_experts
    assert _macs(_phase(work, "dgrad")) + _macs(_phase(work, "wgrad")) \
        == 2 * _macs(fwd) - missed


def test_lm_head_train_dgrad_m_semantics():
    """Training computes the loss at every position, so the LM head's
    forward GEMM carries M = seq — and its dGrad keeps M = seq while
    swapping the vocab to the reduction dim (dX = dY . W^T)."""
    cfg = get_config("minicpm-2b").reduced()
    work = extract_workload(cfg, SPEC)
    by_name = {l.name: l for l, _ in _pairs(work)}
    head = by_name[f"{cfg.name}.lm_head"]
    dg = by_name[f"{cfg.name}.lm_head.dgrad"]
    wg = by_name[f"{cfg.name}.lm_head.wgrad"]
    V, D, m = cfg.padded_vocab(), cfg.d_model, SPEC.seq_len
    assert (head.bound("N"), head.bound("K"), head.bound("C")) == (m, V, D)
    assert (dg.bound("N"), dg.bound("K"), dg.bound("C")) == (m, D, V)
    assert (wg.bound("N"), wg.bound("K"), wg.bound("C")) == (D, V, m)


def test_backward_stream_structure():
    """Reversed order, one dGrad + one wGrad per forward GEMM, written
    stationary operands marked, SSD (activation-activation) backward ops
    tagged dGrad on both sides."""
    for aid in ("minicpm-2b", "mamba2-1.3b"):
        cfg = get_config(aid).reduced()
        work = extract_workload(cfg, SPEC)
        fwd = _phase(work, "fwd")
        bwd = [(l, c) for l, c in _pairs(work) if phase_of(l) != "fwd"]
        assert len(bwd) == 2 * len(fwd)
        # reversed forward order: backward pairs walk the net back to front
        assert [l.name.rsplit(".", 1)[0] for l, _ in bwd[::2]] \
            == [l.name for l, _ in reversed(fwd)]
        for (f, _fc), (dg, _), (wg, _) in zip(reversed(fwd), bwd[::2],
                                              bwd[1::2]):
            assert dg.name == f.name + ".dgrad"
            assert wg.name == f.name + ".wgrad"
            assert dg.macs == wg.macs == f.macs
            assert wg.weight_written
            if f.op == wl.OP_SSD:     # no weight anywhere in the pair
                assert dg.op == wg.op == wl.OP_DGRAD
                assert dg.weight_written
            else:
                assert dg.op == wl.OP_DGRAD and not dg.weight_written
                assert wg.op == wl.OP_WGRAD


def test_backward_requires_train_kind():
    work = extract_workload(get_config("minicpm-2b").reduced(), SPEC)
    with pytest.raises(AssertionError):
        backward_gemms(_pairs(work), get_config("minicpm-2b").reduced(),
                       ShapeSpec("d", 64, 2, "decode"))


# ---------------------------------------------------------------------------
# Optimizer-step pricing
# ---------------------------------------------------------------------------

def test_optimizer_update_closed_form():
    """Once per step: n_params counts each distinct weight set once
    (count // inst), bytes = 21/param (fp32 grad read + 2 Adam moments
    read+write + INT8 weight image write), cycles = bytes over the DRAM
    bus, energy = bytes x (DRAM + GBuf) access energy."""
    cfg = get_config("minicpm-2b").reduced()
    work = extract_workload(cfg, SPEC)
    up = optimizer_update_cost(_pairs(work), ARCH,
                               inst=SPEC.instance_count)
    d, h, kv = cfg.d_model, cfg.n_heads * cfg.resolved_head_dim, \
        cfg.n_kv_heads * cfg.resolved_head_dim
    per_layer = (d * h + h * d + 2 * d * kv                  # q, o, k, v
                 + d * 2 * cfg.d_ff + cfg.d_ff * d)          # up(+gate), down
    expected = cfg.n_layers * per_layer + cfg.padded_vocab() * d
    assert up.n_params == expected
    assert update_bytes_per_param() == 21
    assert up.dram_bytes == 21 * expected
    assert up.cycles == math.ceil(
        up.dram_bytes / ARCH.level(0).bytes_per_cycle())
    e_hop = ARCH.level(0).access_energy_pj_per_byte \
        + ARCH.level(1).access_energy_pj_per_byte
    assert up.energy_pj == pytest.approx(up.dram_bytes * e_hop)
    assert up.comm_cycles == 0.0 and up.total_cycles == up.cycles


def test_update_is_batch_invariant_and_skips_gradless_ops():
    """Doubling the batch doubles the GEMM counts but not the parameter
    count (weights are shared across instances), and backward / SSD
    activation-activation layers carry no optimizer state."""
    cfg = get_config("mamba2-1.3b").reduced()
    for b in (1, 4):
        spec = ShapeSpec("t", 64, b, "train")
        work = extract_workload(cfg, spec)
        n = trainable_params(_pairs(work), inst=spec.instance_count)
        if b == 1:
            n1 = n
        assert n == n1
    fwd_only = _phase(extract_workload(cfg, SPEC), "fwd")
    weightless = [(l, c) for l, c in fwd_only if l.op == wl.OP_SSD]
    assert weightless, "reduced mamba2 must lower SSD duality matmuls"
    assert trainable_params(fwd_only, inst=SPEC.instance_count) == n1


def test_mesh_update_adds_gradient_collective():
    from repro.core.latency import ring_allreduce_cycles
    from repro.core.training import GRAD_BYTES
    cfg = get_config("minicpm-2b").reduced()
    pairs = _pairs(extract_workload(cfg, SPEC))
    mesh = make_mesh(ARCH, 2)
    up1 = optimizer_update_cost(pairs, make_mesh(ARCH, 1),
                                inst=SPEC.instance_count)
    up2 = optimizer_update_cost(pairs, mesh, inst=SPEC.instance_count)
    assert up1.comm_cycles == 0.0          # 1-chip mesh = single chip
    assert up2.comm_cycles == ring_allreduce_cycles(
        up2.n_params * GRAD_BYTES, mesh.link, 2) > 0
    assert up2.comm_energy_pj > 0
    assert (up1.n_params, up1.cycles) == (up2.n_params, up2.cycles)


# ---------------------------------------------------------------------------
# Written residency + cache identity
# ---------------------------------------------------------------------------

def test_written_layers_never_weight_resident():
    """A produced stationary operand cannot be preloaded: residency is
    denied for weight_written layers regardless of the mapping."""
    from repro.core.baselines import greedy_mapping
    fwd = wl.gemm("t.fc", 64, 64, 64)
    wg = wl.gemm("t.fc.wgrad", 64, 64, 64, op=wl.OP_WGRAD,
                 weight_written=True)
    for layer, expect in ((fwd, True), (wg, False)):
        mp = greedy_mapping(layer, ARCH)
        resident, fill = weight_residency(mp, layer, ARCH)
        if expect:
            assert resident and fill > 0.0
        else:
            assert (resident, fill) == (False, 0.0)
    # same bounds, different structural identity: a wGrad record must
    # never serve a forward layer (or vice versa) — the v7 cache field
    assert layer_cache_key(fwd) != layer_cache_key(wg)


def test_training_schedule_and_mesh_n1_identity():
    """End to end (fast greedy mode): scheduled <= serial holds with
    written-residency segments in the stream, and the 1-chip mesh
    training run is bit-identical to the single-chip path."""
    cfg = get_config("minicpm-2b").reduced()
    work = extract_workload(cfg, ShapeSpec("t_small", 16, 2, "train"))
    single = optimize_network(list(work.layers), ARCH, "greedy",
                              counts=list(work.counts), workers=1)
    s = single.scheduled
    assert s["cycles"] <= s["serial_cycles"]
    meshed = optimize_network(list(work.layers), mesh=make_mesh(ARCH, 1),
                              mode="greedy", counts=list(work.counts),
                              workers=1)
    assert meshed.totals == single.totals
    assert meshed.scheduled == single.scheduled
    splits = cycle_splits(single)
    assert all(v > 0 for v in splits.values())
    diffs = backward_dataflow_diffs(single)
    assert len(diffs) == sum(1 for l in work.layers
                             if l.op == wl.OP_WGRAD)


# ---------------------------------------------------------------------------
# Property fuzz: MIP mappings for backward layers re-validate vs eq. 9
# ---------------------------------------------------------------------------

def _assert_legal(mp, layer, arch):
    """Independent re-derivation of the legality contract (the
    test_mapping_fuzz.py checks, applied to transposed backward dims)."""
    assert validate(mp, layer, arch) == [], validate(mp, layer, arch)
    for d in wl.DIMS:
        prod = math.prod(f for dd, f in mp.temporal if dd == d)
        for ax in arch.spatial:
            prod *= mp.spatial_extent(ax.name, d)
        assert prod == layer.bound(d), (d, prod, layer.bound(d))
    for ax in arch.spatial:
        assert mp.spatial_extent(ax.name) <= ax.size
        for d, _f in mp.spatial.get(ax.name, ()):
            assert d in ax.dims, (ax.name, d)
    # eq. (9): (1 + psi^DM) x stored bytes within (aggregated) capacity
    for m in range(arch.n_levels):
        cap = mp.eff_capacity(arch, m)
        if cap is None:
            continue
        sizes = {}
        for lam in OPERANDS:
            if m not in mp.used_levels(lam) or not arch.serves(m, lam):
                continue
            mult = 2 if mp.is_double_buffered(lam, m, arch) else 1
            sizes[lam] = mult * mp.stored_bytes(layer, lam, arch, m)
        if arch.level(m).shared:
            assert sum(sizes.values()) <= cap + 1e-6
        else:
            for sz in sizes.values():
                assert sz <= cap + 1e-6
    if mp.n_slots():
        assert mp.deepest_used("W") <= arch.macro_level


DIM_CHOICES = (3, 8, 24, 64, 128, 360)


@given(st.sampled_from(DIM_CHOICES), st.sampled_from(DIM_CHOICES),
       st.sampled_from(DIM_CHOICES))
@settings(max_examples=4, deadline=None)
def test_fuzz_backward_mip_mappings_legal(m, n_out, k_red):
    """Every dGrad/wGrad mapping the MIP returns satisfies eq. 9 and the
    spatial-legality contract with the *transposed* dims, and its
    role-space signature is derivable (the benchmark headline's input)."""
    from repro.core.formulation import FormulationConfig, optimize_layer
    from repro.core.cache import mapping_to_json
    cfg = get_config("minicpm-2b").reduced()     # dense: no MoE scaling
    fwd = wl.gemm("fz.fc", m, n_out, k_red)
    bwd = backward_gemms([(fwd, 1)], cfg,
                         ShapeSpec("fz", m, 1, "train"))
    assert [dict(l.dims) for l, _ in bwd] == [
        {"N": m, "K": k_red, "C": n_out},       # dGrad: dX = dY . W^T
        {"N": k_red, "K": n_out, "C": m},       # wGrad: dW = X^T . dY
    ]
    fcfg = FormulationConfig(time_limit_s=1.0)
    for layer, _c in bwd:
        res = optimize_layer(layer, ARCH, fcfg)
        assert res.mapping is not None, res.status
        _assert_legal(res.mapping, layer, ARCH)
        sig = dataflow_signature(mapping_to_json(res.mapping), layer.op)
        roles = {r for _ax, rs in sig[0] for r in rs} | set(sig[1])
        assert roles <= {"M", "N", "K"}
