"""Co-design DSE (core/dse.py): Pareto dominance, screening safety
(never prunes the exhaustive-MIP frontier), area proxy, arch-aware cache
keys, and the end-to-end result structure."""

from repro.core.arch import (arch_fingerprint, area_proxy, default_arch,
                             n_macros)
from repro.core.cache import ResultCache, arch_cache_key, solve_record_key
from repro.core.dse import (ArchSpace, DsePoint, _screen_subset, dominates,
                            pareto_frontier, run_dse, screen_arch,
                            screen_prune)
from repro.core.formulation import FormulationConfig
from repro.core.workload import gemm

TINY = gemm("tiny", 32, 64, 64)


# ---------------------------------------------------------------------------
# Pareto dominance on a hand-built 3-point frontier
# ---------------------------------------------------------------------------

def test_pareto_dominance_three_point_frontier():
    a = DsePoint("a", cycles=10, energy_pj=10, area_bits=100)
    b = DsePoint("b", cycles=5, energy_pj=20, area_bits=200)
    c = DsePoint("c", cycles=20, energy_pj=5, area_bits=300)
    d = DsePoint("d", cycles=11, energy_pj=11, area_bits=100)  # dom. by a
    e = DsePoint("e", cycles=10, energy_pj=10, area_bits=100)  # ties a
    assert dominates(a, d) and not dominates(d, a)
    assert not dominates(a, a)                   # never self-dominates
    assert not dominates(a, b) and not dominates(b, a)   # trade-off
    assert not dominates(a, c) and not dominates(c, a)
    front = pareto_frontier([a, b, c, d, e])
    assert [p.arch_name for p in front] == ["a", "b", "c"]
    assert pareto_frontier([]) == []
    assert [p.arch_name for p in pareto_frontier([d])] == ["d"]


def test_dominance_requires_all_objectives():
    # better latency+energy but LARGER area never dominates
    small = DsePoint("small", cycles=100, energy_pj=100, area_bits=10)
    big = DsePoint("big", cycles=1, energy_pj=1, area_bits=20)
    assert not dominates(big, small)
    assert {p.arch_name for p in pareto_frontier([small, big])} == \
        {"small", "big"}


# ---------------------------------------------------------------------------
# Screening prune rules
# ---------------------------------------------------------------------------

def test_screen_prune_decisive_dominance_only():
    pts = [DsePoint("good", 100, 100, 10, "screen"),
           DsePoint("bad", 200, 200, 10, "screen"),      # 2x worse: pruned
           DsePoint("close", 110, 110, 10, "screen"),    # within slack: kept
           DsePoint("trade", 50, 1000, 10, "screen"),    # latency win: kept
           DsePoint("bigfast", 10, 10, 20, "screen")]    # larger area:
    keep, drop = screen_prune(pts, slack=0.25)           # prunes nobody
    assert {p.arch_name for p in drop} == {"bad"}
    assert {p.arch_name for p in keep} == \
        {"good", "close", "trade", "bigfast"}


def test_screen_prune_collapses_exact_ties_to_most_capable():
    archs = {"small": default_arch(gbuf_kb=2.0, name="small"),
             "big": default_arch(gbuf_kb=8.0, name="big")}
    tie = [DsePoint("small", 10, 10, 5, "screen"),
           DsePoint("big", 10, 10, 5, "screen")]
    keep, drop = screen_prune(tie, archs=archs)
    assert [p.arch_name for p in keep] == ["big"]        # more capability
    keep2, _ = screen_prune(tie)                         # no archs: first
    assert [p.arch_name for p in keep2] == ["small"]


# ---------------------------------------------------------------------------
# Area proxy + arch space
# ---------------------------------------------------------------------------

def test_area_proxy_counts_macros_not_buffers():
    base = default_arch()
    assert n_macros(base) == 8                           # one macro per core
    assert area_proxy(base) == 8 * 128 * 32 * 8          # x CELL_BITS
    assert area_proxy(default_arch(lbuf_kb=1024.0)) == area_proxy(base)
    assert area_proxy(default_arch(gbuf_kb=64.0)) == area_proxy(base)
    assert area_proxy(default_arch(n_cores=16)) == 2 * area_proxy(base)
    assert area_proxy(default_arch(macro_rows=256)) == 2 * area_proxy(base)


def test_arch_space_enumerates_unique_validated_archs():
    sp = ArchSpace(macro=((64, 32), (128, 32)), n_cores=(2, 4),
                   lbuf_kb=(16.0,), double_buffered=(True, False))
    archs = sp.enumerate()
    assert sp.size == len(archs) == 8
    assert len({a.name for a in archs}) == 8
    assert len({arch_fingerprint(a) for a in archs}) == 8
    db_off = [a for a in archs if a.name.endswith("-sb")]
    assert db_off and all(not a.level(2).double_bufferable for a in db_off)


# ---------------------------------------------------------------------------
# Arch-aware cache keys
# ---------------------------------------------------------------------------

def test_arch_cache_key_separates_lbuf_capacity():
    """Two archs differing ONLY in LBuf capacity must not share cache
    entries — a stale-mapping hazard for the DSE sweep."""
    a = default_arch(lbuf_kb=256.0)
    b = default_arch(lbuf_kb=16.0)
    assert arch_cache_key(a) != arch_cache_key(b)
    cfg = FormulationConfig()
    assert solve_record_key("miredo", TINY, a, cfg) != \
        solve_record_key("miredo", TINY, b, cfg)


def test_arch_cache_key_is_structural():
    # renames don't separate...
    assert arch_cache_key(default_arch(name="x")) == \
        arch_cache_key(default_arch(name="y"))
    # ...every real knob does
    base = default_arch()
    for kw in (dict(n_cores=4), dict(macro_rows=64), dict(macro_cols=64),
               dict(gbuf_kb=2.0), dict(gbuf_bus_bits=128),
               dict(dram_bus_bits=128), dict(reg_bytes=1024),
               dict(double_buffered=False)):
        assert arch_cache_key(default_arch(**kw)) != arch_cache_key(base), kw


# ---------------------------------------------------------------------------
# Screening subset + screen_arch
# ---------------------------------------------------------------------------

def test_screen_subset_covers_heavy_layers():
    big = gemm("big", 512, 512, 512)
    mid = gemm("mid", 128, 128, 128)
    tiny = gemm("t", 4, 4, 4)
    sub = _screen_subset([big, mid, tiny, big], [1, 1, 1, 3])
    names = [l.name for l, _ in sub]
    assert names[0] == "big"                     # heaviest first
    mult = dict((l.name, c) for l, c in sub)
    assert mult["big"] == 4                      # multiplicity pooled
    # tiny layer is below the coverage cut
    assert "t" not in names


def test_screen_arch_returns_screen_fidelity_point():
    arch = default_arch()
    sub = _screen_subset([TINY], [2])
    p = screen_arch(sub, arch, samples=8)
    assert p.fidelity == "screen" and p.arch_name == arch.name
    assert p.cycles > 0 and p.energy_pj > 0
    assert p.area_bits == area_proxy(arch)


# ---------------------------------------------------------------------------
# End-to-end: structure (cheap mode) + MIP screening guarantee
# ---------------------------------------------------------------------------

def test_run_dse_greedy_end_to_end():
    layers = [gemm("a", 32, 64, 64), gemm("b", 128, 2048, 64)]
    space = ArchSpace(macro=((64, 32), (128, 32)), n_cores=(2,),
                      lbuf_kb=(256.0, 2.0), prefix="t")
    res = run_dse(layers, [2, 1], space, "greedy", screen_samples=8,
                  use_cache=False, workers=1)
    assert set(res.archs) == {a.name for a in space.enumerate()}
    assert set(res.screen_points) == set(res.archs)      # whole grid scored
    assert set(res.points) == set(res.survivors)         # MIP pass survivors
    assert set(res.survivors) | set(res.pruned) == set(res.archs)
    assert res.frontier                                  # non-empty
    areas = [p.area_bits for p in res.frontier]
    assert areas == sorted(areas)                        # ascending area
    assert all(p.fidelity == "mip" for p in res.frontier)
    assert set(res.validation) == {p.arch_name for p in res.frontier}
    assert all(errs == [] for errs in res.validation.values())
    best = res.best_under_area(min(areas))
    assert best is not None and best.area_bits == min(areas)
    assert res.best_under_area(0) is None


def test_screening_never_prunes_the_mip_frontier(tmp_path):
    """The multi-fidelity guarantee, pinned against exhaustive MIP on a
    tiny grid: every arch on the exhaustive frontier survives screening,
    while >= 50% of the grid is pruned. The shared cache makes the second
    run reuse the first run's solves, so the comparison is exact."""
    layers = [gemm("ffn", 64, 256, 128), gemm("proj", 32, 64, 64),
              gemm("head", 128, 2048, 64)]
    counts = [4, 2, 1]
    space = ArchSpace(macro=((64, 32), (128, 32)), n_cores=(2,),
                      lbuf_kb=(256.0, 2.0), prefix="t")
    cache = ResultCache(str(tmp_path))
    ex = run_dse(layers, counts, space, "miredo", screen=False,
                 per_layer_cap_s=2.0, cache=cache)
    assert len(ex.points) == 4 and not ex.pruned
    sc = run_dse(layers, counts, space, "miredo", screen=True,
                 per_layer_cap_s=2.0, cache=cache)
    assert sc.prune_fraction >= 0.5
    front = {p.arch_name for p in ex.frontier}
    assert front <= set(sc.survivors), \
        f"screening dropped frontier archs: {front - set(sc.survivors)}"
    # identical solves (cache) => identical frontier on the survivors
    assert [p.arch_name for p in sc.frontier] == \
        [p.arch_name for p in ex.frontier]
    for name in front:
        assert sc.points[name] == ex.points[name]
    assert all(errs == [] for errs in sc.validation.values())
