"""Keep docs/tutorial.md runnable: every ```bash command block must parse.

For each fenced ```bash block in the checked docs, every command line that
invokes a repo script (``python path/to/script.py`` or
``python -m pkg.module``) is verified three ways:

  1. the referenced file exists in the repo,
  2. it parses (`ast.parse`),
  3. if it is an argparse CLI (declares ``argparse``), it is executed with
     ``--help`` (original args dropped, ``PYTHONPATH=src``) and must exit 0
     — so a renamed flag, moved script or import-time crash in a documented
     command fails CI instead of rotting silently.

External tools (pytest, pip, ...) are reported but not executed. Run from
the repo root:

    python docs/check_docs.py [--no-exec]

Exit code 0 = every documented command is intact. Used by the ``docs`` CI
job and, in ``--no-exec`` form, by ``tests/test_docs.py``.
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("docs/tutorial.md", "README.md")

_FENCE = re.compile(r"```bash\n(.*?)```", re.S)


def extract_commands(md_text: str) -> list[str]:
    """Command lines from every ```bash fence: continuations joined,
    comments and blank lines dropped."""
    cmds: list[str] = []
    for block in _FENCE.findall(md_text):
        logical = ""
        for raw in block.splitlines():
            line = raw.rstrip()
            if line.endswith("\\"):
                logical += line[:-1] + " "
                continue
            logical += line
            logical = logical.strip()
            if logical and not logical.startswith("#"):
                cmds.append(logical)
            logical = ""
    return cmds


def resolve_target(cmd: str) -> tuple[str | None, bool]:
    """(repo-relative path of the python script the command runs, or None
    for external/non-python commands; whether it is run via ``-m``)."""
    toks = cmd.split()
    # strip leading VAR=value environment assignments
    while toks and re.fullmatch(r"[A-Za-z_][A-Za-z0-9_]*=\S*", toks[0]):
        toks.pop(0)
    if not toks or not re.fullmatch(r"python[0-9.]*", toks[0]):
        return None, False
    args = [t for t in toks[1:] if not t.startswith("-")] or [""]
    if "-m" in toks:
        mod = toks[toks.index("-m") + 1]
        path = mod.replace(".", "/") + ".py"
        return (path, True) if os.path.exists(os.path.join(REPO, path)) \
            else (None, True)      # external module (pytest, pip, ...)
    if args[0].endswith(".py"):
        return args[0], False
    return None, False


def is_argparse_cli(path: str) -> bool:
    with open(os.path.join(REPO, path)) as f:
        return "argparse" in f.read()


def check(docs: tuple[str, ...] = DOCS, run_help: bool = True,
          verbose: bool = True) -> list[str]:
    """Return a list of failure descriptions (empty = all good)."""
    failures: list[str] = []
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get(
                   "PYTHONPATH", ""))
    for doc in docs:
        doc_path = os.path.join(REPO, doc)
        if not os.path.exists(doc_path):
            failures.append(f"{doc}: missing")
            continue
        with open(doc_path) as f:
            cmds = extract_commands(f.read())
        if verbose:
            print(f"[{doc}] {len(cmds)} documented commands")
        for cmd in cmds:
            target, via_m = resolve_target(cmd)
            if target is None:
                if verbose:
                    print(f"  skip (external): {cmd}")
                continue
            full = os.path.join(REPO, target)
            if not os.path.exists(full):
                failures.append(f"{doc}: `{cmd}` -> {target} does not exist")
                continue
            try:
                with open(full) as src:
                    ast.parse(src.read(), filename=target)
            except SyntaxError as e:
                failures.append(f"{doc}: {target} does not parse: {e}")
                continue
            if run_help and is_argparse_cli(target):
                argv = [sys.executable] + \
                    (["-m", target[:-3].replace("/", ".")] if via_m
                     else [full]) + ["--help"]
                r = subprocess.run(argv, cwd=REPO, env=env,
                                   capture_output=True, timeout=120)
                if r.returncode != 0:
                    failures.append(
                        f"{doc}: `{' '.join(argv[1:])}` exited "
                        f"{r.returncode}: {r.stderr.decode()[-300:]}")
                elif verbose:
                    print(f"  ok (--help): {cmd}")
            elif verbose:
                print(f"  ok (compiles): {cmd}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--no-exec", action="store_true",
                    help="skip the --help subprocess runs (existence + "
                         "compile checks only)")
    args = ap.parse_args(argv)
    failures = check(run_help=not args.no_exec)
    for f in failures:
        print(f"FAIL: {f}", file=sys.stderr)
    print(f"{'OK' if not failures else 'BROKEN'}: "
          f"{len(failures)} failures across {len(DOCS)} docs")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
