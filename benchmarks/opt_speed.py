"""Optimizer throughput benchmark (the ``optspeed`` job): scalar vs
batched analytical model, plus the persistent-cache DSE speedup.

Three measurements, one JSON row (``reports/benchmarks/opt_speed.json``):

  1. **mappings/sec** on sampler pools (one GEMM, one conv): the historical
     per-candidate scalar loop (``mapping.validate`` +
     ``energy.evaluate_edp``) against the batched scorer
     (`latency_batched.score_mappings`) on each available backend. Before
     timing, the batched scores are checked for *exact* equality with the
     scalar loop on every feasible row (infeasible rows must come back
     ``inf``) — a speedup that changes answers is a bug, not a result.
  2. the same race on a **feasible-only** pool, isolating evaluation
     throughput from the sampler's ~90% capacity-infeasible candidates
     (which the scalar loop rejects cheaply in ``validate``).
  3. optionally (``--dse``): a cold then warm ``dse --reduced`` run against
     a fresh persistent cache directory — the warm run must reproduce the
     cold frontier byte-for-byte and beat its wall clock by
     ``DSE_MIN_SPEEDUP``x (the ISSUE-6 acceptance number).

The throughput gate (used by the CI optspeed-smoke job) requires the best
batched/scalar ratio across pools to reach ``MIN_RATIO`` — timings use
best-of-``REPEATS`` to shrug off scheduler noise on small CI boxes.

    PYTHONPATH=src python benchmarks/opt_speed.py --quick
    PYTHONPATH=src python benchmarks/opt_speed.py --dse
"""

from __future__ import annotations

import argparse
import math
import os
import random
import sys
import time

if __package__ in (None, ""):      # `python benchmarks/opt_speed.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import md_table, write_report
from repro.core import latency_batched as lb
from repro.core import workload as wl
from repro.core.arch import default_arch
from repro.core.baselines import sample_mapping_raw
from repro.core.energy import evaluate_edp
from repro.core.factorization import factorize_layer_dims
from repro.core.mapping import validate

#: Throughput gate: best batched/scalar ratio across pools/backends. 1.0
#: ("no slower than the loop it replaced") — measured margins are
#: 1.2-1.3x on the feasible-only pool, but a single shared CI core is
#: noisy, so the gate asserts parity and the JSON records the margin.
MIN_RATIO = 1.0
#: Cold/warm wall-clock ratio the persistent-cache DSE rerun must reach.
DSE_MIN_SPEEDUP = 5.0
#: Best-of-N timing repeats.
REPEATS = 3

#: ``--portfolio`` mode: per-layer budget for both the single-solve
#: baseline pass and the racing-portfolio pass (equal total budget — the
#: ISSUE-10 gate condition). 3 s sits where the fine model misses its
#: first integer point on the hard reduced-zoo layers but the coarse
#: portfolio member's slice still lands one.
PORTFOLIO_BUDGET_S = 3.0
#: Wall-clock tolerance on the per-layer budget contract (process
#: scheduling + one formulation build that straddles the deadline).
PORTFOLIO_EPS_S = 0.75
#: Reduced LM zoo for the portfolio gate: two decode workloads with
#: structurally diverse GEMMs (attention/FFN/head + Mamba SSD).
PORTFOLIO_MODELS = ("minicpm-2b", "mamba2-1.3b")
PORTFOLIO_SCENARIOS = ("decode_32k",)


def _pools(quick: bool) -> list[tuple[str, object, int]]:
    """(name, layer, pool size): one GEMM and one conv, sized so the jax
    backend crosses its auto-dispatch threshold even in quick mode."""
    n = 512 if quick else 2000
    return [
        ("gemm", wl.gemm("g", 32, 512, 512), n),
        ("conv", wl.conv("c", 1, 64, 64, 28, 28, 3, 3), n),
    ]


def _sample_pool(layer, arch, n: int, seed: int = 0) -> list:
    rng = random.Random(seed)
    factors = factorize_layer_dims({d: layer.bound(d) for d in wl.DIMS})
    return [sample_mapping_raw(layer, arch, rng, factors)
            for _ in range(n)]


def _scalar_scores(pool, layer, arch) -> list[tuple[float, float, float]]:
    """The historical per-candidate loop: validate, then full EDP."""
    out = []
    for mp in pool:
        if validate(mp, layer, arch):
            out.append((math.inf, math.inf, math.inf))
        else:
            e = evaluate_edp(mp, layer, arch)
            out.append((e.latency.total_cycles, e.energy.total_pj, e.edp))
    return out


def _best_of(fn, repeats: int = REPEATS) -> float:
    best = math.inf
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _check_agreement(pool, layer, arch, name: str) -> int:
    """Exact scalar/batched equality on every row; returns feasible count."""
    ref = _scalar_scores(pool, layer, arch)
    for backend in ("numpy",) + (("jax",) if lb.HAVE_JAX else ()):
        sc = lb.score_mappings(pool, layer, arch, backend=backend)
        for i, (cyc, pj, edp) in enumerate(ref):
            got = (float(sc.cycles[i]), float(sc.energy_pj[i]),
                   float(sc.edp[i]))
            if got != (cyc, pj, edp):
                raise RuntimeError(
                    f"[optspeed] {name}/{backend} row {i}: batched {got} "
                    f"!= scalar {(cyc, pj, edp)}")
    return sum(r[0] != math.inf for r in ref)


def _race(pool, layer, arch) -> dict[str, float]:
    """Best-of-N wall seconds per contender on one pool."""
    need = ("feasible", "latency", "energy")
    out = {"scalar": _best_of(lambda: _scalar_scores(pool, layer, arch)),
           "batched-numpy": _best_of(lambda: lb.score_mappings(
               pool, layer, arch, need=need, backend="numpy"))}
    if lb.HAVE_JAX:
        # warm the jit cache before timing: compile time is a one-off
        lb.score_mappings(pool, layer, arch, need=need, backend="jax")
        out["batched-jax"] = _best_of(lambda: lb.score_mappings(
            pool, layer, arch, need=need, backend="jax"))
    return out


def _dse_cold_warm(cache_dir: str) -> dict:
    """Cold vs warm ``dse --reduced`` against one persistent cache dir."""
    from benchmarks import dse_pareto

    def frontier(payload):
        return [(p["arch"], p["cycles"], p["energy_pj"], p["area_bits"])
                for p in payload["frontier"]]

    prev = os.environ.get("MIREDO_CACHE")
    os.environ["MIREDO_CACHE"] = cache_dir
    try:
        t0 = time.perf_counter()
        cold = dse_pareto.run(reduced=True)
        cold_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        warm = dse_pareto.run(reduced=True)
        warm_s = time.perf_counter() - t0
    finally:
        if prev is None:
            os.environ.pop("MIREDO_CACHE", None)
        else:
            os.environ["MIREDO_CACHE"] = prev
    if frontier(cold) != frontier(warm):
        raise RuntimeError(
            f"[optspeed] warm DSE rerun changed the frontier:\n"
            f"cold: {frontier(cold)}\nwarm: {frontier(warm)}")
    speedup = cold_s / max(warm_s, 1e-9)
    if speedup < DSE_MIN_SPEEDUP:
        raise RuntimeError(
            f"[optspeed] persistent-cache DSE rerun only {speedup:.1f}x "
            f"faster (acceptance: >={DSE_MIN_SPEEDUP:g}x)")
    return {"cold_s": round(cold_s, 2), "warm_s": round(warm_s, 2),
            "speedup": round(speedup, 1),
            "frontier_identical": True,
            "frontier_archs": [p["arch"] for p in cold["frontier"]]}


def _portfolio_layers():
    """Unique layers of the reduced portfolio zoo, first-seen order."""
    from repro.configs import get_config
    from repro.core.frontend import extract_all
    from repro.core.network import dedup_layers

    pool = []
    for aid in PORTFOLIO_MODELS:
        cfg = get_config(aid).reduced()
        for work in extract_all(cfg, PORTFOLIO_SCENARIOS).values():
            pool.extend(work.layers)
    unique, _ = dedup_layers(pool)
    return unique


def _portfolio_bench(budget_s: float = PORTFOLIO_BUDGET_S) -> dict:
    """``--portfolio``: incumbent-unimproved rate, single solve vs racing
    portfolio at equal per-layer budget (the ISSUE-10 tentpole gate).

    Per unique reduced-zoo layer:

      * **before** — one single-parameterization ``optimize_layer`` at
        ``budget_s``;
      * **after** — ``portfolio.race`` of the default K=3 grid at the same
        ``budget_s``, seeded with the before-pass mapping (the portfolio's
        incumbent-sharing mechanism), which makes "never worse than the
        single solve" hold *by construction*;
      * the race runs twice with identical seeds as a determinism probe.

    Gates (RuntimeError on violation):

      1. the unimproved rate (fraction of layers where the returned
         mapping is not strictly better than the *native* greedy/heuristic
         incumbent) strictly drops from before to after;
      2. no layer's after-latency exceeds its before-latency;
      3. every solve's wall clock stays within ``budget_s`` +
         ``PORTFOLIO_EPS_S`` (the post-ladder-fix budget contract);
      4. for layers whose winning member terminated deterministically
         (OPTIMAL / INFEASIBLE — not at the wall-clock wire), both race
         passes return bit-identical (winner, latency, mapping). Members
         cut off by the clock are deterministic only up to machine load —
         the *selection rule* is a pure function of member results either
         way (DESIGN.md §Solver portfolio).
    """
    from repro.core.cache import mapping_to_json
    from repro.core.formulation import FormulationConfig, optimize_layer
    from repro.core.portfolio import default_portfolio, race

    arch = default_arch()
    fc = FormulationConfig(time_limit_s=budget_s)
    pf = default_portfolio()
    unique = _portfolio_layers()
    print(f"[optspeed/portfolio] {len(unique)} unique layers, "
          f"{budget_s:g}s/layer, grid "
          f"{[m.name for m in pf.members]} (digest {pf.digest()})")

    rows, layers_json = [], []
    n_before = n_after = 0
    budget_violations, worse, nondet = [], [], []
    for ul in unique:
        before = optimize_layer(ul, arch, fc)
        out = race(ul, arch, fc, pf, warm_start=before.mapping)
        out2 = race(ul, arch, fc, pf, warm_start=before.mapping)
        after = out.result
        n_before += before.improved
        n_after += after.improved
        if after.eval_latency > before.eval_latency:
            worse.append(ul.name)
        for tag, s in (("single", before.solve_seconds),
                       ("portfolio", after.solve_seconds),
                       ("portfolio-rerun", out2.result.solve_seconds)):
            if s > budget_s + PORTFOLIO_EPS_S:
                budget_violations.append(f"{ul.name}/{tag}: {s:.2f}s")
        w1, w2 = out.members[out.winner], out2.members[out2.winner]
        det_eligible = {w1.status, w2.status} <= {"OPTIMAL", "INFEASIBLE"}
        det_same = (out.winner == out2.winner and
                    out.result.eval_latency == out2.result.eval_latency and
                    mapping_to_json(out.result.mapping) ==
                    mapping_to_json(out2.result.mapping))
        if det_eligible and not det_same:
            nondet.append(ul.name)
        rows.append([ul.name, f"{before.incumbent_latency:.0f}",
                     f"{before.eval_latency:.0f}", int(before.improved),
                     f"{after.eval_latency:.0f}", int(after.improved),
                     out.members[out.winner].name])
        layers_json.append({
            "layer": ul.name,
            "incumbent_cycles": before.incumbent_latency,
            "before_cycles": before.eval_latency,
            "before_improved": before.improved,
            "before_s": round(before.solve_seconds, 2),
            "after_cycles": after.eval_latency,
            "after_improved": after.improved,
            "after_s": round(after.solve_seconds, 2),
            "winner": out.winner,
            "winner_name": out.members[out.winner].name,
            "deterministic_rerun": det_same,
            "members": out.to_json()["members"],
        })

    n = len(unique)
    rate_before = 1.0 - n_before / n
    rate_after = 1.0 - n_after / n
    print(md_table(["layer", "incumbent", "single", "imp",
                    "portfolio", "imp", "winner"], rows))
    print(f"[optspeed/portfolio] incumbent-unimproved rate: "
          f"{rate_before:.3f} -> {rate_after:.3f} "
          f"(gate: strict drop at equal {budget_s:g}s/layer budget)")
    if worse:
        raise RuntimeError(
            f"[optspeed/portfolio] portfolio worse than single solve on: "
            f"{worse}")
    if budget_violations:
        raise RuntimeError(
            f"[optspeed/portfolio] budget contract violated "
            f"(> {budget_s:g}+{PORTFOLIO_EPS_S:g}s): {budget_violations}")
    if nondet:
        raise RuntimeError(
            f"[optspeed/portfolio] deterministically-terminated winners "
            f"changed between identical-seed reruns on: {nondet}")
    if not rate_after < rate_before:
        raise RuntimeError(
            f"[optspeed/portfolio] incumbent-unimproved rate did not "
            f"strictly drop: {rate_before:.3f} -> {rate_after:.3f}")
    return {"budget_s": budget_s, "eps_s": PORTFOLIO_EPS_S,
            "models": list(PORTFOLIO_MODELS),
            "scenarios": list(PORTFOLIO_SCENARIOS),
            "grid": [m.name for m in pf.members],
            "digest": pf.digest(),
            "n_layers": n,
            "rate_before": round(rate_before, 4),
            "rate_after": round(rate_after, 4),
            "layers": layers_json}


def run(budget_s: float = 0.0, quick: bool = False, dse: bool = False,
        portfolio: bool = False, cache_dir: str | None = None) -> dict:
    """``budget_s`` is accepted for harness uniformity; the pools are
    fixed-size so the job's cost is set by ``quick`` and ``dse``.
    ``portfolio=True`` runs ONLY the solver-portfolio gate
    (`_portfolio_bench`) — its zoo is already the reduced one, so
    ``--reduced``/``--quick`` change nothing for it."""
    if portfolio:
        payload = {"portfolio": _portfolio_bench()}
        write_report("opt_speed_portfolio", payload)
        return payload
    arch = default_arch()
    rows, pools_json = [], {}
    best_ratio, best_where = 0.0, ""
    for name, layer, n in _pools(quick):
        pool = _sample_pool(layer, arch, n)
        feas = _check_agreement(pool[: min(n, 256)], layer, arch, name)
        # feasible-only variant: evaluation throughput without the
        # sampler's capacity-infeasible majority
        fpool = [mp for mp in pool if not validate(mp, layer, arch)]
        for tag, p in ((name, pool), (f"{name}-feasible", fpool)):
            if not p:
                continue
            t = _race(p, layer, arch)
            entry = {"pool": len(p), "scalar_s": round(t["scalar"], 4)}
            for k, v in t.items():
                if k == "scalar":
                    continue
                ratio = t["scalar"] / v
                entry[k.replace("-", "_") + "_s"] = round(v, 4)
                entry[k.replace("-", "_") + "_ratio"] = round(ratio, 3)
                if ratio > best_ratio:
                    best_ratio, best_where = ratio, f"{tag}/{k}"
                rows.append([tag, k, len(p),
                             round(len(p) / t["scalar"]),
                             round(len(p) / v), f"{ratio:.2f}x"])
            pools_json[tag] = entry
        print(f"[optspeed] {name}: agreement exact on "
              f"{min(n, 256)} rows ({feas} feasible)")

    print(md_table(["pool", "backend", "n", "scalar maps/s",
                    "batched maps/s", "ratio"], rows))
    print(f"[optspeed] best batched/scalar ratio {best_ratio:.2f}x "
          f"({best_where}); gate >={MIN_RATIO:g}x")
    if best_ratio < MIN_RATIO:
        raise RuntimeError(
            f"[optspeed] batched scorer slower than scalar everywhere "
            f"(best {best_ratio:.2f}x < {MIN_RATIO:g}x)")

    payload = {"have_jax": lb.HAVE_JAX, "quick": quick,
               "agreement": "exact", "pools": pools_json,
               "best_ratio": round(best_ratio, 3),
               "best_ratio_pool": best_where}
    if dse:
        import tempfile
        cd = cache_dir or tempfile.mkdtemp(prefix="optspeed-cache-")
        print(f"[optspeed] cold/warm dse --reduced, cache {cd}")
        payload["dse"] = _dse_cold_warm(cd)
        print(f"[optspeed] dse cold {payload['dse']['cold_s']}s -> warm "
              f"{payload['dse']['warm_s']}s "
              f"({payload['dse']['speedup']}x, frontier identical)")
    write_report("opt_speed", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="smaller pools (CI smoke size)")
    ap.add_argument("--dse", action="store_true",
                    help="also time cold vs warm dse --reduced against a "
                         "persistent cache (minutes, not seconds)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent cache dir for --dse (default: fresh "
                         "temp dir, i.e. a true cold start)")
    ap.add_argument("--portfolio", action="store_true",
                    help="run only the racing-solver-portfolio gate: "
                         "incumbent-unimproved rate before vs after on "
                         "the reduced LM zoo at equal per-layer budget")
    args = ap.parse_args(argv)
    run(quick=args.quick, dse=args.dse, portfolio=args.portfolio,
        cache_dir=args.cache_dir)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
