"""MIREDO TPU bridge (beyond paper): MIP-selected Pallas block shapes for
the assigned architectures' dominant GEMMs; VMEM fit + traffic estimates,
compared against naive maximal blocks."""

from __future__ import annotations

from benchmarks.common import md_table, write_report
from repro.configs import ARCH_IDS, get_config
from repro.core.tpu_bridge import (VMEM_BYTES, select_flash_blocks,
                                   select_matmul_blocks)


def dominant_gemm(cfg) -> tuple[int, int, int]:
    """Per-device FFN up-projection GEMM under the production sharding
    (TP=16 on d_ff, tokens/device for train_4k)."""
    tokens = 256 * 4096 // 16           # per data-rank
    ff = (cfg.moe_d_ff or cfg.d_ff or cfg.ssm_expand * cfg.d_model)
    return tokens, cfg.d_model, max(ff // 16, 128)


def run() -> dict:
    rows = []
    for arch_id in ARCH_IDS:
        cfg = get_config(arch_id)
        m, k, n = dominant_gemm(cfg)
        choice = select_matmul_blocks(m, k, n)
        fit = "OK" if (2 - (not choice.double_buffered)) * \
            choice.vmem_bytes <= VMEM_BYTES else "OVER"
        rows.append([arch_id, f"{m}x{k}x{n}",
                     f"({choice.bm},{choice.bk},{choice.bn})",
                     "dbl" if choice.double_buffered else "single",
                     f"{choice.vmem_bytes/2**20:.1f}MiB", fit,
                     f"{choice.est_seconds*1e6:.1f}us", choice.status])
    bq, bk = select_flash_blocks(32768, 32768, 128)
    payload = {"rows": rows, "flash_blocks_32k": [bq, bk]}
    write_report("tpu_bridge", payload)
    print(md_table(["arch", "GEMM m*k*n", "blocks", "buf", "VMEM", "fit",
                    "est t", "solver"], rows))
    print(f"\nflash-attention blocks @32k/hd128 (eq.9 fit): "
          f"block_q={bq}, block_k={bk}")
    return payload


if __name__ == "__main__":
    run()
