"""LM model zoo through the MIREDO pipeline: per-model, per-scenario
aggregate EDP for every registry architecture.

The model frontend (`core/frontend.py`) lowers each ``ModelConfig`` under
each applicable ``ShapeSpec`` (train / prefill / decode / long-decode) to
its weight-GEMM workload; all (model, scenario) workloads are pooled into
ONE network-pipeline call per mode, so structurally identical GEMMs dedup
across depth, batch, scenarios *and models* to a single MIP solve with a
shared MAC-weighted wall-clock budget.

Registered as the ``lm`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.lm_models --quick
    PYTHONPATH=src python -m benchmarks.lm_models \\
        --archs minicpm-2b --reduced --scenarios prefill_32k,decode_32k
"""

from __future__ import annotations

import argparse

from benchmarks.common import md_table, write_report
from repro.configs import ARCH_IDS, get_config
from repro.core.arch import default_arch
from repro.core.cache import MIP_MODES
from repro.core.frontend import extract_all
from repro.core.network import dedup_layers, optimize_network

#: Scenario subset for ``--quick`` (full runs take every applicable cell).
QUICK_SCENARIOS = ("prefill_32k", "decode_32k")
#: Quick-mode solver knobs: per-layer cap and average seconds per unique
#: solve (the pooled zoo is ~110 unique GEMMs; 1.5 s each keeps the whole
#: job within a few minutes while the warm start guarantees feasibility).
QUICK_CAP_S = 3.0
QUICK_AVG_S = 1.5


def run(budget_s: float = 45.0, quick: bool = False,
        archs: tuple[str, ...] | None = None,
        scenarios: tuple[str, ...] | None = None,
        reduced: bool = False,
        modes: tuple[str, ...] = ("miredo", "heuristic"),
        workers: int | None = None) -> dict:
    arch = default_arch()
    arch_ids = tuple(archs) if archs else ARCH_IDS
    scen = tuple(scenarios) if scenarios else (
        QUICK_SCENARIOS if quick else None)

    works = []                       # (arch_id, ModelWorkload) in row order
    for aid in arch_ids:
        cfg = get_config(aid)
        if reduced:
            cfg = cfg.reduced()
        for work in extract_all(cfg, scen).values():
            works.append((aid, work))
    pooled = [l for _, w in works for l in w.layers]
    counts = [c for _, w in works for c in w.counts]
    # each (model, scenario) is an independent stream: the scheduler must
    # not pipeline across pooled-workload boundaries
    bounds, off = [], 0
    for _, w in works:
        bounds.append(off)
        off += len(w)
    n_unique = len(dedup_layers(pooled)[0])
    print(f"[frontend] {len(works)} (model, scenario) workloads -> "
          f"{len(pooled)} extracted layers, {n_unique} unique solves "
          f"(structural dedup x{len(pooled) / max(n_unique, 1):.2f})")

    cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
    total = QUICK_AVG_S * n_unique if quick else None
    nets = {m: optimize_network(pooled, arch, m, counts=counts,
                                per_layer_cap_s=cap, total_budget_s=total,
                                schedule_boundaries=bounds,
                                workers=workers)
            for m in modes}

    base = next((m for m in modes if m not in MIP_MODES), None)
    headers = ["model", "scenario", "layers", "unique", "MACs"] + \
        [f"{m} EDP" for m in modes] + \
        (["reduction"] if base and "miredo" in modes else [])
    rows, table = [], []
    off = 0
    for aid, work in works:
        sl = slice(off, off + len(work))
        off += len(work)
        edp = {m: sum(lr.edp * lr.count for lr in nets[m].layers[sl])
               for m in modes}
        row = {"model": aid, "scenario": work.scenario,
               "layers": len(work), "unique": work.n_unique,
               "macs": work.total_macs, "edp": edp}
        rows.append(row)
        line = [aid, work.scenario, len(work), work.n_unique,
                f"{work.total_macs:.3g}"] + \
               [f"{edp[m]:.4g}" for m in modes]
        if base and "miredo" in modes:
            line.append(f"{edp[base] / edp['miredo']:.2f}x")
        table.append(line)

    payload = {
        "rows": rows,
        "n_extracted": len(pooled), "n_unique": n_unique,
        "pipeline": {m: {"wall_s": n.wall_s, "n_unique": n.n_unique,
                         "n_solved": n.n_solved, "cache_hits": n.cache_hits}
                     for m, n in nets.items()},
    }
    write_report("lm_models", payload)
    print(md_table(headers, table))
    for m in modes:
        n = nets[m]
        print(f"[pipeline/{m}] {n.n_unique} unique, {n.n_solved} solved, "
              f"{n.cache_hits} cached, wall {n.wall_s:.0f}s")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer MIP cap (seconds)")
    ap.add_argument("--archs", default="",
                    help=f"comma list of arch ids (default: all of "
                         f"{', '.join(ARCH_IDS)})")
    ap.add_argument("--scenarios", default="",
                    help="comma list of ShapeSpec names "
                         "(default: all applicable; quick: "
                         + ",".join(QUICK_SCENARIOS) + ")")
    ap.add_argument("--reduced", action="store_true",
                    help="use the CPU smoke-test reduction of each config")
    ap.add_argument("--modes", default="miredo,heuristic")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick,
        archs=tuple(a for a in args.archs.split(",") if a) or None,
        scenarios=tuple(s for s in args.scenarios.split(",") if s) or None,
        reduced=args.reduced,
        modes=tuple(m for m in args.modes.split(",") if m),
        workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
