"""Request-level serving under traffic: continuous batching vs serial
(`core/serving.py`, DESIGN.md §Serving simulator).

Every other benchmark scores ONE forward pass.  This job models a seeded
Poisson request stream per (model, CIM arch): iteration costs come from
the real stack (`NetworkCostModel`: `ShapeSpec.serving_iteration` ->
frontend -> `optimize_network(schedule=True)` at power-of-two token
anchors), the continuous-batching engine interleaves whole-prompt
prefills with decode steps under a hard KV-cache token capacity, and each
row reports p50/p99 TTFT and ITL, sustained tokens/sec, and SLO goodput —
batched vs the serial one-request-at-a-time baseline charged through the
same cost model.

A second section re-ranks a small architecture grid by *goodput under
SLO* (`run_dse(rank_by="slo_goodput")`) and records whether that ordering
differs from the single-pass-latency ranking (it does whenever the SLO
cliff, queueing, or large-m throughput reorder archs that single-pass
latency cannot distinguish).

Registered as the ``serve`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.serve_sim --reduced
    PYTHONPATH=src python -m benchmarks.serve_sim \\
        --models minicpm-2b,mamba2-1.3b --reduced --n-requests 32

``--reduced`` is the CI acceptance path (serve-smoke): p99 >= p50 on
every percentile pair, batched goodput >= serial goodput and batched
makespan <= serial makespan on every row, at least one row must actually
merge iterations, and a deterministic rerun must reproduce the percentile
summary bit-identically.
"""

from __future__ import annotations

import argparse
import json

from benchmarks.common import md_table, write_report
from repro.configs import get_config
from repro.core.arch import default_arch
from repro.core.dse import run_dse
from repro.core.frontend import extract_workload
from repro.configs.base import ShapeSpec
from repro.core.serving import (NetworkCostModel, RequestStream,
                                ServeConfig, ServeScenario, serial_baseline,
                                simulate_serving)

#: Default (model id, ...) pair for the acceptance path: one dense, one SSM.
MODELS = ("minicpm-2b", "mamba2-1.3b")
#: Traffic shape shared by every row (cycles; the default archs run at
#: ``freq_ghz=1.0`` so 1 cycle = 1 ns).
N_REQUESTS = 24
SEED = 0
MEAN_INTERARRIVAL_CYCLES = 150_000.0
PROMPT_LENS = (8, 16, 32)
OUTPUT_LENS = (4, 8, 16)
CONTEXT_LEN = 256
#: SLOs sit above the worst per-iteration cost on the reduced zoo (a full
#: 128-token iteration is ~0.8M cycles on the slowest row) so they bind on
#: *queueing* — the serving-level failure mode — not on a single
#: iteration's latency.
SERVE_CFG = ServeConfig(kv_capacity_tokens=512, max_batch_requests=16,
                        max_batch_tokens=128,
                        slo_ttft_cycles=3_000_000.0,
                        slo_itl_cycles=1_500_000.0)
QUICK_CAP_S = 2.0


def _cim_archs() -> tuple:
    """>=2 CIM architectures per model: the paper's Table IV baseline and a
    macro/core-rich variant (more residency + parallelism headroom)."""
    return (default_arch(),
            default_arch(macro_rows=256, macro_cols=64, n_cores=16,
                         name="miredo-serve-big"))


def _dse_grid() -> list:
    """Small explicit grid for the goodput-vs-latency ranking section,
    chosen so the iteration-cost curves CROSS: big-macro/few-core archs
    win the single-token pass (residency dominates, m=1), small-macro/
    many-core archs win full batches (compute dominates, m=128) — exactly
    the regime single-pass latency ranks wrong under traffic."""
    return [default_arch(macro_rows=64, macro_cols=32, n_cores=16,
                         name="serve-m64-c16"),
            default_arch(macro_rows=256, macro_cols=64, n_cores=4,
                         name="serve-m256-c4"),
            default_arch(macro_rows=128, macro_cols=32, n_cores=8,
                         name="serve-m128-c8"),
            default_arch(macro_rows=256, macro_cols=64, n_cores=16,
                         name="serve-m256-c16")]


def _row(mid: str, arch, cost, stream, scfg) -> dict:
    rep = simulate_serving(stream, cost, scfg)
    ser = serial_baseline(stream, cost, scfg)
    f = cost.freq_ghz
    to_ms = 1.0 / (f * 1e6)   # cycles -> ms at freq_ghz
    s, ss = rep.summary(f), ser.summary(f)
    return {
        "model": mid, "arch": arch.name,
        "n_requests": len(stream.requests),
        "n_finished": s["n_finished"], "n_rejected": s["n_rejected"],
        "ttft_p50_ms": s["ttft_p50_cycles"] * to_ms,
        "ttft_p99_ms": s["ttft_p99_cycles"] * to_ms,
        "itl_p50_ms": s["itl_p50_cycles"] * to_ms,
        "itl_p99_ms": s["itl_p99_cycles"] * to_ms,
        "tokens_per_sec": s["tokens_per_sec"],
        "goodput_tokens_per_sec": s["goodput_tokens_per_sec"],
        "serial_tokens_per_sec": ss["tokens_per_sec"],
        "serial_goodput_tokens_per_sec": ss["goodput_tokens_per_sec"],
        "makespan_cycles": s["makespan_cycles"],
        "serial_makespan_cycles": ss["makespan_cycles"],
        "n_merged_iterations": s["n_merged_iterations"],
        "n_preemptions": s["n_preemptions"],
        "max_kv_occupancy": s["max_kv_occupancy"],
        "anchor_solves": cost.n_solves,
        "summary": s,
    }


def run(budget_s: float = 45.0, quick: bool = False, reduced: bool = False,
        models: tuple[str, ...] | None = None,
        n_requests: int = N_REQUESTS, seed: int = SEED,
        mode: str = "greedy", workers: int = 1) -> dict:
    quick = quick or reduced
    model_ids = tuple(models) if models else MODELS
    cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
    scfg = SERVE_CFG

    rows, table = [], []
    for mid in model_ids:
        cfg = get_config(mid)
        if reduced:
            cfg = cfg.reduced()
        for arch in _cim_archs():
            cost = NetworkCostModel(
                cfg, arch, max_m=scfg.max_batch_tokens,
                context_len=CONTEXT_LEN, mode=mode, per_layer_cap_s=cap,
                workers=workers)
            stream = RequestStream.poisson(
                n_requests, seed=seed,
                mean_interarrival_cycles=MEAN_INTERARRIVAL_CYCLES,
                prompt_lens=PROMPT_LENS, output_lens=OUTPUT_LENS)
            r = _row(mid, arch, cost, stream, scfg)
            # Determinism gate data: a full rerun (fresh stream object,
            # same seed, same cost closure) must reproduce the summary
            # bit-identically.
            rerun = simulate_serving(
                RequestStream.poisson(
                    n_requests, seed=seed,
                    mean_interarrival_cycles=MEAN_INTERARRIVAL_CYCLES,
                    prompt_lens=PROMPT_LENS, output_lens=OUTPUT_LENS),
                cost, scfg)
            r["deterministic"] = (
                json.dumps(rerun.summary(cost.freq_ghz), sort_keys=True)
                == json.dumps(r["summary"], sort_keys=True))
            rows.append(r)
            table.append([
                mid, arch.name,
                f"{r['ttft_p50_ms']:.3f}", f"{r['ttft_p99_ms']:.3f}",
                f"{r['itl_p50_ms']:.3f}", f"{r['itl_p99_ms']:.3f}",
                f"{r['tokens_per_sec']:.4g}",
                f"{r['goodput_tokens_per_sec']:.4g}",
                f"{r['serial_tokens_per_sec']:.4g}",
                r["n_merged_iterations"]])

    headers = ["model", "arch", "ttft p50 ms", "ttft p99 ms", "itl p50 ms",
               "itl p99 ms", "tok/s", "goodput tok/s", "serial tok/s",
               "merged"]
    print(md_table(headers, table))

    # -- goodput-vs-latency arch ranking (run_dse rank_by="slo_goodput") --
    dse_mid = model_ids[0]
    dse_cfg = get_config(dse_mid)
    if reduced:
        dse_cfg = dse_cfg.reduced()
    # Single-token decode pass: the classic latency objective the goodput
    # ranking is contrasted against (rank_by="latency" would order archs
    # by this workload's scheduled cycles).
    work = extract_workload(
        dse_cfg, ShapeSpec("serve_decode", CONTEXT_LEN, 1, "decode"))
    scen = ServeScenario(
        model_ids=(dse_mid,), reduced=reduced, n_requests=n_requests,
        seed=seed, mean_interarrival_cycles=MEAN_INTERARRIVAL_CYCLES,
        prompt_lens=PROMPT_LENS, output_lens=OUTPUT_LENS, serve=scfg,
        context_len=CONTEXT_LEN, cost_mode=mode, per_layer_cap_s=cap)
    dse = run_dse(list(work.layers), list(work.counts), _dse_grid(),
                  mode=mode, screen=False, use_cache=False,
                  workers=workers, per_layer_cap_s=cap,
                  rank_by="slo_goodput", serve=scen)
    pts = dse.points
    latency_order = sorted(pts, key=lambda n: (pts[n].cycles, n))
    goodput_order = sorted(pts, key=lambda n: (-pts[n].goodput_tok_s, n))
    orderings_differ = latency_order != goodput_order
    frontier_valid = all(not v for v in dse.validation.values())
    print(f"[serve/{mode}] ranking by latency:      {latency_order}")
    print(f"[serve/{mode}] ranking by slo_goodput:  {goodput_order}")
    print(f"[serve/{mode}] {len(rows)} (model, arch) rows; goodput "
          f"frontier {len(dse.frontier)} archs "
          f"({'all mappings valid' if frontier_valid else 'INVALID'}); "
          f"orderings {'differ' if orderings_differ else 'coincide'}")

    payload = {
        "mode": mode, "rows": [
            {k: v for k, v in r.items() if k != "summary"} for r in rows],
        "serve_config": {
            "kv_capacity_tokens": scfg.kv_capacity_tokens,
            "max_batch_requests": scfg.max_batch_requests,
            "max_batch_tokens": scfg.max_batch_tokens,
            "admission": scfg.admission,
            "slo_ttft_cycles": scfg.slo_ttft_cycles,
            "slo_itl_cycles": scfg.slo_itl_cycles,
        },
        "dse": {
            "model": dse_mid,
            "latency_order": latency_order,
            "goodput_order": goodput_order,
            "orderings_differ": orderings_differ,
            "frontier": [p.arch_name for p in dse.frontier],
            "latency_frontier": [p.arch_name
                                 for p in dse.frontier_by("latency")],
            "goodput_tok_s": {n: pts[n].goodput_tok_s for n in pts},
            "scheduled_cycles": {n: pts[n].cycles for n in pts},
            "frontier_valid": frontier_valid,
        },
    }
    write_report("serve_sim", payload)

    # --reduced is the CI acceptance path (serve-smoke).
    if reduced:
        for r in rows:
            tag = f"{r['model']}/{r['arch']}"
            if r["ttft_p99_ms"] < r["ttft_p50_ms"] or \
                    r["itl_p99_ms"] < r["itl_p50_ms"]:
                raise RuntimeError(f"{tag}: p99 < p50")
            if r["goodput_tokens_per_sec"] < \
                    r["serial_goodput_tokens_per_sec"]:
                raise RuntimeError(
                    f"{tag}: batched goodput {r['goodput_tokens_per_sec']} "
                    f"< serial {r['serial_goodput_tokens_per_sec']}")
            if r["makespan_cycles"] > r["serial_makespan_cycles"]:
                raise RuntimeError(
                    f"{tag}: batched makespan worse than serial")
            if not r["deterministic"]:
                raise RuntimeError(
                    f"{tag}: rerun summary not bit-identical")
        if not any(r["n_merged_iterations"] > 0 for r in rows):
            raise RuntimeError("no row merged an iteration (acceptance: "
                               "continuous batching must engage)")
        if not frontier_valid:
            raise RuntimeError("goodput frontier has invalid mappings")
        if not orderings_differ:
            # tests/test_serving.py::test_goodput_vs_latency_ranking_differs
            # documents the divergence mechanism on synthetic curves; the
            # reduced grid is expected to reproduce it for real.
            raise RuntimeError(
                "goodput ranking coincides with latency ranking on the "
                "reduced grid (expected the SLO/queueing cliff to reorder "
                "at least one arch)")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke-test reductions of the LM configs + "
                         "quick caps + acceptance gates")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer solve cap (seconds; quick mode clamps)")
    ap.add_argument("--models", default="",
                    help=f"comma list of model ids (default: "
                         f"{', '.join(MODELS)})")
    ap.add_argument("--n-requests", type=int, default=N_REQUESTS)
    ap.add_argument("--seed", type=int, default=SEED)
    ap.add_argument("--mode", default="greedy",
                    help="solve mode for the iteration-cost anchors "
                         "(greedy | miredo)")
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        models=tuple(m for m in args.models.split(",") if m) or None,
        n_requests=args.n_requests, seed=args.seed, mode=args.mode,
        workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
