"""Assemble EXPERIMENTS.md from reports/ artifacts (dry-run, roofline,
benchmarks, perf iterations). Narrative sections live in
benchmarks/experiments_narrative.md and are included verbatim.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.roofline import format_table, load_reports, roofline_terms


def dryrun_summary(report_dir="reports/dryrun") -> str:
    recs = load_reports(report_dir)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skipped" for r in recs)
    n_err = sum(r["status"] == "error" for r in recs)
    lines = [f"Cells lowered+compiled: **{n_ok} ok**, {n_skip} skipped "
             f"(assignment rule), {n_err} errors, of {len(recs)} total.",
             "",
             "| arch | shape | mesh | status | compile s | HBM GB/dev |",
             "|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r["multi_pod"])):
        mesh = "2x16x16" if r["multi_pod"] else "16x16"
        if r["status"] == "ok":
            t = roofline_terms(r)
            hbm = f"{t['hbm_gb_per_device']:.2f}"
        else:
            hbm = "-"
        note = r["status"] if r["status"] != "error" else \
            "error: " + r.get("error", "")[:60]
        lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | {note} | "
                     f"{r.get('seconds', '-')} | {hbm} |")
    return "\n".join(lines)


def bench_section() -> str:
    out = []
    path = "reports/benchmarks"
    def load(name):
        p = os.path.join(path, name + ".json")
        return json.load(open(p)) if os.path.exists(p) else None

    f4a = load("fig4a_model_accuracy")
    if f4a:
        out.append(f"**Fig 4(a) — analytical model accuracy:** "
                   f"{f4a['mean_accuracy']:.3f} mean over "
                   f"{f4a['n_points']} (layer, mapping) points "
                   f"(paper: 0.955).")
    f4b = load("fig4b_utilization_edp")
    if f4b:
        out.append(f"**Fig 4(b) — utilization/EDP trade-off "
                   f"({f4b['layer']}):** EDP reduction "
                   f"{f4b['edp_gain_vs_ws']:.2f}x vs WS, "
                   f"{f4b['edp_gain_vs_heuristic']:.2f}x vs heuristic.")
    f4c = load("fig4c_per_layer")
    if f4c:
        out.append(f"**Fig 4(c) — ResNet-18 network latency:** "
                   f"{f4c['speedup_vs_heuristic']:.2f}x vs heuristic, "
                   f"{f4c['speedup_vs_ws']:.2f}x vs WS (multiplicity-"
                   f"weighted sum over layers).")
    f5a = load("fig5a_models")
    if f5a:
        rats = ", ".join(f"{k} {v:.2f}x" for k, v in f5a["ratios"].items())
        out.append(f"**Fig 5(a) — EDP reduction across models** "
                   f"(paper: 1.6–3.2x): {rats}.")
    f5b = load("fig5bcd_hw_sweep")
    if f5b:
        rats = ", ".join(f"{k} {v:.2f}x" for k, v in f5b["ratios"].items())
        out.append(f"**Fig 5(b–d) — hardware robustness:** {rats}.")
    ff = load("tab_flexfact")
    if ff:
        out.append("**Flexible Factorization ablation** (conv4_x): see "
                   "`reports/benchmarks/tab_flexfact.json`.")
    tb = load("tpu_bridge")
    if tb:
        out.append("**TPU bridge (beyond paper):** MIP-selected Pallas "
                   "blocks per arch in `reports/benchmarks/tpu_bridge.json`"
                   f"; flash blocks @32k = {tb['flash_blocks_32k']}.")
    sched = load("sched_lm")
    if sched:
        lines = [
            f"**Network scheduler (beyond paper)** — serial-sum vs "
            f"weight-resident pipelined schedule, mode `{sched['mode']}`: "
            f"{sched['n_packed_rows']}/{len(sched['rows'])} (model, "
            f"scenario) rows packed >=1 segment; network-mode simulator "
            f"agreement {sched['mean_sim_accuracy']:.3f}.", "",
            "| model | scenario | segments | packed | serial cyc | "
            "sched cyc | speedup |",
            "|---|---|---|---|---|---|---|"]
        for r in sched["rows"]:
            lines.append(
                f"| {r['model']} | {r['scenario']} | {r['n_segments']} | "
                f"{r['n_packed']} | {r['serial_cycles']:.4g} | "
                f"{r['scheduled_cycles']:.4g} | {r['speedup']:.3f}x |")
        out.append("\n".join(lines))
    ex = load("exec_lm")
    if ex:
        rank = ex["pooled_rank_corr"]
        mode = "interpret mode" if ex["interpret"] else "compiled"
        rank_txt = f"{rank:.3f}" if rank is not None else "n/a"
        lines = [
            f"**Measured execution (beyond paper)** — optimized plans run "
            f"on the Pallas kernels ({mode}), kernels "
            f"{', '.join(ex['kernels'])}: pooled predicted-vs-measured "
            f"rank correlation {rank_txt} over {ex['n_rank_points']} "
            f"ops.", "",
            "| model | scenario | ops | pred serial cyc | measured ms | "
            "rank | max rel err | numerics |",
            "|---|---|---|---|---|---|---|---|"]
        for r in ex["rows"]:
            rr = f"{r['rank_corr']:.2f}" if r["rank_corr"] is not None \
                else "-"
            lines.append(
                f"| {r['model']} | {r['scenario']} | {r['ops']} | "
                f"{r['predicted_serial_cycles']:.4g} | "
                f"{r['measured_s'] * 1e3:.1f} | {rr} | "
                f"{r['max_rel_err']:.1e} | "
                f"{'ok' if r['numerics_ok'] else 'FAIL'} |")
        out.append("\n".join(lines))
    dse = load("dse_pareto")
    if dse:
        lines = [
            f"**Co-design DSE (beyond paper)** — workload "
            f"`{dse['workload']}`: screening pruned {dse['pruned']}/"
            f"{dse['grid']} archs ({100 * dse['prune_fraction']:.0f}%), "
            f"{len(dse['frontier'])} non-dominated survivors"
            f"{', all frontier mappings valid' if dse['frontier_validated'] else ' (INVALID mappings!)'}."
            " Frontier (ascending area):", "",
            "| arch | area bits | cycles | energy pJ | EDP |",
            "|---|---|---|---|---|"]
        for p in dse["frontier"]:
            lines.append(f"| {p['arch']} | {p['area_bits']:,} | "
                         f"{p['cycles']:.3g} | {p['energy_pj']:.3g} | "
                         f"{p['edp']:.4g} |")
        out.append("\n".join(lines))
    return "\n\n".join(out)


def perf_section() -> str:
    rows = []
    for p in sorted(glob.glob("reports/perf/*.json")):
        r = json.load(open(p))
        b, a = r.get("before"), r.get("after")
        if not a:
            rows.append(f"- `{r['cell']}` / **{r['variant']}** — FAILED "
                        f"({r['after_raw'].get('error', '')[:80]})")
            continue
        def fmt(t):
            return (f"comp {t['t_compute_s']*1e3:.1f}ms, "
                    f"mem {t['t_memory_s']*1e3:.1f}ms, "
                    f"coll {t['t_collective_s']*1e3:.1f}ms, "
                    f"HBM {t['hbm_gb_per_device']:.1f}GB, "
                    f"frac {t['roofline_fraction']:.4f}")
        before = fmt(b) if b and b.get("status") == "ok" else "n/a"
        rows.append(
            f"- `{r['cell']}` / **{r['variant']}** — {r['hypothesis']}\n"
            f"  - before: {before}\n  - after:  {fmt(a)}")
    return "\n".join(rows) if rows else "(populated by perf_hillclimb runs)"


def main():
    narrative = ""
    np_path = "benchmarks/experiments_narrative.md"
    if os.path.exists(np_path):
        narrative = open(np_path).read()
    doc = f"""# EXPERIMENTS

{narrative}

## §Dry-run (deliverable e)

{dryrun_summary()}

## §Roofline (deliverable g) — single-pod (16, 16) = 256 chips

Hardware constants: 197 TFLOP/s bf16, 819 GB/s HBM, 4x50 GB/s ICI per chip.

{format_table('reports/dryrun', multi_pod=False)}

### Multi-pod (2, 16, 16) = 512 chips — lowering/compile proof

{format_table('reports/dryrun', multi_pod=True)}

## §Paper validation (deliverables b, d)

{bench_section()}

## §Perf (hillclimb iterations)

{perf_section()}
"""
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    print("EXPERIMENTS.md written",
          f"({len(doc.splitlines())} lines)")


if __name__ == "__main__":
    main()
