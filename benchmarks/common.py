"""Shared benchmark utilities: table/report output plus thin back-compat
shims over the library-level cache (``repro.core.cache``) and network
pipeline (``repro.core.network``).

The mapping (de)serialization and the per-layer solve cache used to live
here; they are now library code so examples, tests and the network pipeline
share one cache with one key schema (the old key silently ignored most
``FormulationConfig`` fields — see cache.CACHE_VERSION)."""

from __future__ import annotations

import json
import os

from repro.core.cache import (  # noqa: F401  (re-exported API)
    ResultCache, default_cache_dir, mapping_from_json, mapping_to_json,
    solve_cached)

CACHE_DIR = default_cache_dir()
REPORT_DIR = os.environ.get("MIREDO_REPORTS", "reports/benchmarks")


def write_report(name: str, payload) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def md_table(headers: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(
            f"{x:.3g}" if isinstance(x, float) else str(x) for x in r) +
            " |")
    return "\n".join(out)
