"""Shared benchmark utilities: mapping (de)serialization, per-layer result
caching (MIP solves are expensive — reruns are incremental), table output."""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time

from repro.core import workload as wl
from repro.core.arch import CimArch, OPERANDS, default_arch
from repro.core.baselines import greedy_mapping, heuristic_search
from repro.core.energy import evaluate_edp
from repro.core.formulation import FormulationConfig, optimize_layer
from repro.core.latency import evaluate
from repro.core.mapping import Mapping

CACHE_DIR = os.environ.get("MIREDO_CACHE", "reports/cache")
REPORT_DIR = os.environ.get("MIREDO_REPORTS", "reports/benchmarks")


def mapping_to_json(m: Mapping) -> dict:
    return {
        "spatial": {k: list(map(list, v)) for k, v in m.spatial.items()},
        "temporal": list(map(list, m.temporal)),
        "level_of": {k: list(v) for k, v in m.level_of.items()},
        "double_buf": sorted(map(list, m.double_buf)),
    }


def mapping_from_json(d: dict) -> Mapping:
    return Mapping(
        spatial={k: tuple(tuple(x) for x in v)
                 for k, v in d["spatial"].items()},
        temporal=tuple(tuple(x) for x in d["temporal"]),
        level_of={k: tuple(v) for k, v in d["level_of"].items()},
        double_buf=frozenset((a, b) for a, b in d["double_buf"]))


def _arch_key(arch: CimArch) -> str:
    parts = [arch.name]
    for lv in arch.levels:
        parts.append(f"{lv.name}:{lv.capacity_bytes}:{lv.bus_bits}")
    for ax in arch.spatial:
        parts.append(f"{ax.name}:{ax.size}")
    parts.append(f"{arch.l_mvm_cycles}:{arch.mode_switch_cycles}")
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:12]


def _layer_key(layer: wl.Layer) -> str:
    dims = ",".join(f"{d}={layer.bound(d)}" for d in wl.DIMS)
    return hashlib.sha1(f"{dims}|s{layer.stride}".encode()).hexdigest()[:12]


def solve_cached(layer: wl.Layer, arch: CimArch, mode: str,
                 cfg: FormulationConfig | None = None,
                 budget_s: float = 60.0) -> dict:
    """mode: 'miredo' | 'ws' | 'heuristic' | 'greedy' | 'random'.
    Returns {mapping, cycles, edp, energy_pj, solve_s, status}."""
    cfg = cfg or FormulationConfig(time_limit_s=budget_s)
    os.makedirs(CACHE_DIR, exist_ok=True)
    key = f"{mode}__{_layer_key(layer)}__{_arch_key(arch)}" \
          f"__t{int(cfg.time_limit_s)}_a{cfg.alpha}_k{cfg.k_min}"
    path = os.path.join(CACHE_DIR, key + ".json")
    if os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        return rec
    t0 = time.monotonic()
    if mode == "miredo":
        res = optimize_layer(layer, arch, cfg)
        mapping, status = res.mapping, res.status.name
    elif mode == "ws":
        c = dataclasses.replace(cfg, weight_stationary=True)
        res = optimize_layer(layer, arch, c)
        mapping, status = res.mapping, res.status.name
    elif mode == "heuristic":
        r = heuristic_search(layer, arch, budget=2000, seed=0,
                             accurate=False, k_min=cfg.k_min,
                             alpha=cfg.alpha)
        mapping, status = r.mapping, "HEURISTIC"
    elif mode == "random":
        r = heuristic_search(layer, arch, budget=2000, seed=0,
                             accurate=True, k_min=cfg.k_min, alpha=cfg.alpha)
        mapping, status = r.mapping, "RANDOM"
    elif mode == "greedy":
        mapping, status = greedy_mapping(layer, arch), "GREEDY"
    else:
        raise ValueError(mode)
    edp = evaluate_edp(mapping, layer, arch)
    rec = {
        "mode": mode,
        "layer": layer.name,
        "mapping": mapping_to_json(mapping),
        "cycles": edp.latency.total_cycles,
        "energy_pj": edp.energy.total_pj,
        "edp": edp.edp,
        "spatial_util": edp.latency.spatial_util,
        "temporal_util": edp.latency.temporal_util,
        "solve_s": round(time.monotonic() - t0, 1),
        "status": status,
    }
    with open(path, "w") as f:
        json.dump(rec, f)
    return rec


def write_report(name: str, payload) -> str:
    os.makedirs(REPORT_DIR, exist_ok=True)
    path = os.path.join(REPORT_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def md_table(headers: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "---|" * len(headers)]
    for r in rows:
        out.append("| " + " | ".join(
            f"{x:.3g}" if isinstance(x, float) else str(x) for x in r) +
            " |")
    return "\n".join(out)
