"""Training workloads: forward + backward + optimizer step through the
network pipeline (`core/training.py`, DESIGN.md §Training frontend).

Each row lowers one reduced registry model under a ``kind="train"``
scenario — every forward weight-GEMM expanded into its dGrad/wGrad pair
plus the once-per-step optimizer bill — solves the whole stream with the
per-layer MIP, and reports the per-model fwd / dGrad / wGrad / update
cycle split. The headline: the layers where the MIP-optimal *backward*
dataflow differs from the forward layer's (role-space signatures,
`training.backward_dataflow_diffs`) — the reason backward GEMMs get
their own solves instead of reusing the forward mapping transposed. A
side row runs one model on a small mesh so the FSDP gradient shard
choices (`sharding.rules.mesh_grad_choices`) engage end to end.

Registered as the ``train`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.train_lm_workloads --reduced

``--reduced`` is the CI acceptance path (train-smoke) and enforces the
training contract instead of warning:

  * backward GEMM MACs match the closed form exactly per model
    (dense/ssm: exactly 2x the forward GEMM MACs — the embedding gather
    is zero-MAC on both sides; MoE: minus the un-hit routed experts'
    wGrad share);
  * >= 1 layer in the run where the optimal wGrad dataflow differs from
    its forward layer's;
  * scheduled <= serial for every model (written-residency wGrad
    segments must not break the pipelining bound);
  * the 1-chip mesh training run reproduces the single-chip result bit
    for bit (totals AND schedule).
"""

from __future__ import annotations

import argparse

from benchmarks.common import md_table, write_report
from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.arch import default_arch
from repro.core.mesh import make_mesh
from repro.core.network import optimize_network
from repro.core.training import (backward_dataflow_diffs, cycle_splits,
                                 optimizer_update_cost, phase_of,
                                 routed_hit_experts)

#: Quick-mode per-layer MIP cap (same spirit as benchmarks/sched_lm.py).
QUICK_CAP_S = 2.0
#: One reduced model per weight-GEMM family shape: dense (tied head),
#: top-k MoE (hit-expert wGrad scaling), SSD (activation-activation
#: backward ops, no optimizer state).
REDUCED_ARCHS = ("minicpm-2b", "qwen2-moe-a2.7b", "mamba2-1.3b")
#: Mesh side row: chips for the FSDP gradient-shard demonstration.
MESH_CHIPS = 2


def train_spec(reduced: bool) -> ShapeSpec:
    """Benchmark-sized training cell: reduced runs use a CPU-sized step
    (64 tokens x 2 sequences — small enough that the MoE row exercises
    the partial-hit wGrad path at full top_k)."""
    if reduced:
        return ShapeSpec("train_red", 64, 2, "train")
    return ShapeSpec("train_1k", 1_024, 8, "train")


def closed_form_bwd_macs(cfg, spec, forward) -> int:
    """Backward MACs from the forward stream alone (independent of the
    backward-emission code path): dGrad + wGrad each mirror their forward
    GEMM's MACs, except routed MoE wGrads scale to the experts hit."""
    total = 0
    n_exp = cfg.n_experts
    n_hit = routed_hit_experts(cfg, spec.m_tokens)
    for layer, count in forward:
        total += 2 * layer.macs * count          # dGrad + wGrad
        if n_hit and ".exp." in layer.name:
            total -= layer.macs * (count - count // n_exp * n_hit)
    return total


def run(budget_s: float = 60.0, quick: bool = False, reduced: bool = False,
        mode: str = "miredo", workers: int | None = None) -> dict:
    quick = quick or reduced
    cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
    chip = default_arch()
    spec = train_spec(reduced or quick)

    rows, table, diff_rows = [], [], []
    nets = {}
    for aid in REDUCED_ARCHS:
        cfg = get_config(aid).reduced() if (reduced or quick) \
            else get_config(aid)
        from repro.core.frontend import extract_workload
        work = extract_workload(cfg, spec)
        fwd = [(l, c) for l, c in zip(work.layers, work.counts)
               if phase_of(l) == "fwd"]
        bwd_macs = sum(l.macs * c for l, c in zip(work.layers, work.counts)
                       if phase_of(l) != "fwd")
        expected = closed_form_bwd_macs(cfg, spec, fwd)

        net = optimize_network(list(work.layers), chip, mode,
                               counts=list(work.counts),
                               per_layer_cap_s=cap, workers=workers)
        nets[aid] = net
        splits = cycle_splits(net)
        update = optimizer_update_cost(fwd, chip, inst=spec.instance_count)
        diffs = backward_dataflow_diffs(net)
        n_differ = sum(d["differs"] for d in diffs)
        diff_rows += [{"model": aid, **d} for d in diffs]
        s = net.scheduled
        rows.append({
            "model": aid, "n_layers": len(work), "n_unique": work.n_unique,
            "bwd_macs": bwd_macs, "bwd_macs_closed_form": expected,
            "splits": splits,
            "update": {"n_params": update.n_params,
                       "dram_bytes": update.dram_bytes,
                       "cycles": update.cycles,
                       "energy_pj": update.energy_pj},
            "serial_cycles": s["serial_cycles"],
            "scheduled_cycles": s["cycles"],
            "step_cycles": s["cycles"] + update.total_cycles,
            "n_wgrad_pairs": len(diffs), "n_dataflow_differ": n_differ,
        })
        table.append([aid, len(work),
                      f"{splits['fwd']:.4g}", f"{splits['dgrad']:.4g}",
                      f"{splits['wgrad']:.4g}", f"{update.cycles:.4g}",
                      f"{s['cycles']:.4g}", f"{n_differ}/{len(diffs)}"])

    headers = ["model", "gemms", "fwd cyc", "dgrad cyc", "wgrad cyc",
               "update cyc", "sched cyc", "bwd dataflow differs"]
    print(md_table(headers, table))
    for d in diff_rows:
        if d["differs"]:
            print(f"[train/{mode}] {d['model']}: {d['layer']} wGrad "
                  f"dataflow differs from forward")

    # FSDP side row: one model on a small mesh — the wGrad layers route
    # through the gradient shard rules and the update gains the ring
    # all-reduce term.
    aid = REDUCED_ARCHS[0]
    cfg = get_config(aid).reduced() if (reduced or quick) \
        else get_config(aid)
    from repro.core.frontend import extract_workload
    work = extract_workload(cfg, spec)
    fwd = [(l, c) for l, c in zip(work.layers, work.counts)
           if phase_of(l) == "fwd"]
    mesh = make_mesh(chip, MESH_CHIPS)
    mnet = optimize_network(list(work.layers), mesh=mesh, mode=mode,
                            counts=list(work.counts), per_layer_cap_s=cap,
                            workers=workers)
    mupdate = optimizer_update_cost(fwd, mesh, inst=spec.instance_count)
    wgrad_shards = sorted({lr.record["shard"]["choice"]
                           for lr in mnet.layers
                           if phase_of(lr.layer) == "wgrad"})
    mesh_row = {"model": aid, "n_chips": MESH_CHIPS,
                "scheduled_cycles": mnet.scheduled["cycles"],
                "wgrad_shards": wgrad_shards,
                "update_comm_cycles": mupdate.comm_cycles}
    print(f"[train/{mode}] {aid} @ {MESH_CHIPS} chips: wGrad shards "
          f"{wgrad_shards}, grad all-reduce {mupdate.comm_cycles:.4g} cyc")

    payload = {"mode": mode, "spec": spec.name, "rows": rows,
               "dataflow_diffs": diff_rows, "mesh": mesh_row}
    write_report("train_lm_workloads", payload)

    # --reduced is the CI acceptance path (train-smoke): enforce the
    # training contract instead of warning, so regressions fail the job.
    if reduced:
        for r in rows:
            if r["bwd_macs"] != r["bwd_macs_closed_form"]:
                raise RuntimeError(
                    f"{r['model']}: backward MACs {r['bwd_macs']} != "
                    f"closed form {r['bwd_macs_closed_form']}")
            if r["scheduled_cycles"] > r["serial_cycles"]:
                raise RuntimeError(
                    f"{r['model']}: scheduled {r['scheduled_cycles']} > "
                    f"serial {r['serial_cycles']} with written-residency "
                    f"segments")
        if not any(r["n_dataflow_differ"] for r in rows):
            raise RuntimeError(
                "no layer's optimal wGrad dataflow differs from its "
                "forward layer's — the backward solves are degenerate")
        aid = REDUCED_ARCHS[0]
        mesh1 = optimize_network(
            list(work.layers), mesh=make_mesh(chip, 1), mode=mode,
            counts=list(work.counts), per_layer_cap_s=cap, workers=workers)
        single = nets[aid]
        if mesh1.totals != single.totals or \
                mesh1.scheduled != single.scheduled:
            raise RuntimeError(
                f"1-chip mesh training run is not the single chip: totals "
                f"{mesh1.totals} vs {single.totals}, scheduled "
                f"{mesh1.scheduled} vs {single.scheduled}")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="quick caps + CI acceptance gates (train-smoke)")
    ap.add_argument("--budget", type=float, default=60.0,
                    help="per-layer MIP cap (seconds; quick mode clamps)")
    ap.add_argument("--mode", default="miredo")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        mode=args.mode, workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
