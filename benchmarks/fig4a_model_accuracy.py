"""Fig. 4(a): analytical latency model accuracy vs the discrete-event
simulator, across diverse mappings of ResNet-18 layers (paper: 95.5%)."""

from __future__ import annotations

import random

from benchmarks.common import md_table, write_report
from repro.core.arch import default_arch
from repro.core.baselines import _sample_mapping, greedy_mapping
from repro.core.factorization import factorize_layer_dims
from repro.core.latency import evaluate
from repro.core.simulator import simulate
from repro.core.workload import DIMS, resnet18


def run(budget_mappings: int = 60, max_iters: int = 200_000,
        seed: int = 0) -> dict:
    arch = default_arch()
    rng = random.Random(seed)
    rows, accs = [], []
    for layer in resnet18():
        factors = factorize_layer_dims({d: layer.bound(d) for d in DIMS})
        cands = [greedy_mapping(layer, arch)]
        tries = 0
        while len(cands) < max(2, budget_mappings // 12) and tries < 400:
            tries += 1
            mp = _sample_mapping(layer, arch, rng, factors)
            if mp is not None:
                cands.append(mp)
        for mp in cands:
            import math
            iters = math.prod(f for _, f in mp.temporal)
            if iters > max_iters:
                continue
            model = evaluate(mp, layer, arch).total_cycles
            sim = simulate(mp, layer, arch,
                           max_iters=max_iters).total_cycles
            acc = 1.0 - abs(model - sim) / max(sim, 1.0)
            accs.append(acc)
            rows.append([layer.name, f"{model:.0f}", f"{sim:.0f}",
                         f"{acc:.3f}"])
    mean_acc = sum(accs) / max(len(accs), 1)
    payload = {"mean_accuracy": mean_acc, "n_points": len(accs),
               "paper_claim": 0.955, "rows": rows}
    write_report("fig4a_model_accuracy", payload)
    print(md_table(["layer", "model cycles", "sim cycles", "accuracy"],
                   rows[:20]))
    print(f"\nFig4a mean analytical-model accuracy: {mean_acc:.3f} "
          f"over {len(accs)} (layer, mapping) points "
          f"(paper reports 0.955)")
    return payload


if __name__ == "__main__":
    run()
