"""Measured execution across the LM zoo: optimized plans on the Pallas
kernels, predicted cycles vs measured wall-clock (`core/executor.py`,
DESIGN.md §Executor).

Each (model, scenario) row extracts its workload, solves it through the
network pipeline, lowers the result to an ``ExecPlan`` (GEMMs on
`kernels/matmul_int8` with mapping-derived blocks, attention score/AV on
`kernels/flash_attention`, the SSD intra-chunk pair fused on
`kernels/ssd_scan`) and executes it in Pallas interpret mode (CPU; pass
``--no-interpret`` on real hardware). Every kernel invocation is checked
against its ``ref.py`` oracle, and per-op predicted cycles are *ranked*
against measured seconds — the Fig. 4(a) discipline, now
model-vs-execution instead of model-vs-simulator.

Scenarios are execution-sized (`EXEC_SHAPES`): interpret mode emulates the
grid step-by-step in Python, so the 32k-token prediction scenarios are not
execution targets — the point is rank agreement, which small shapes
already decide.

Registered as the ``exec`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.exec_lm --reduced
    PYTHONPATH=src python -m benchmarks.exec_lm \\
        --archs minicpm-2b,mamba2-1.3b --scenarios exec_prefill

``--reduced`` is the CI acceptance path (exec-smoke): every executed
kernel output must match its reference, the pooled rank correlation must
clear ``RANK_FLOOR``, all three kernel families must have run, and every
model must have executed at least one wGrad GEMM (the ``exec_train``
scenario lowers a training step, so the backward pass — transposed-
operand block selection on `kernels/matmul_int8` — is on the CI
critical path too).
"""

from __future__ import annotations

import argparse

from benchmarks.common import md_table, write_report
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import ShapeSpec
from repro.core.arch import default_arch
from repro.core.executor import execute_plan, lower_plan, spearman
from repro.core.frontend import extract_workload
from repro.core.network import optimize_network

#: Execution-sized scenario cells (see module docstring).
EXEC_SHAPES = {
    "exec_prefill": ShapeSpec("exec_prefill", seq_len=512, global_batch=1,
                              kind="prefill"),
    "exec_decode": ShapeSpec("exec_decode", seq_len=256, global_batch=16,
                             kind="decode"),
    # one training step: the backward pass (dGrad/wGrad, transposed-
    # operand block selection) reaches matmul_int8 and the numerics oracle
    "exec_train": ShapeSpec("exec_train", seq_len=64, global_batch=1,
                            kind="train"),
}
#: Reduced-mode model subset: one attention family + one SSD family keeps
#: every kernel dispatch path on the CI critical path.
REDUCED_ARCHS = ("minicpm-2b", "mamba2-1.3b")
#: Acceptance floor on the pooled per-op Spearman (predicted cycles vs
#: measured seconds). Interpret-mode CPU timing of small ops is noisy per
#: row; pooled across rows the monotone signal is strong (~0.7 observed),
#: so 0.5 gates real regressions without flaking on timer jitter.
RANK_FLOOR = 0.5
MIN_RANK_POINTS = 8
#: Quick-mode solver knobs (same spirit as benchmarks/sched_lm.py).
QUICK_CAP_S = 2.0
QUICK_AVG_S = 1.0


def run(budget_s: float = 45.0, quick: bool = False, reduced: bool = False,
        archs: tuple[str, ...] | None = None,
        scenarios: tuple[str, ...] | None = None,
        mode: str = "miredo", repeats: int = 3, seed: int = 0,
        interpret: bool = True, workers: int | None = 1) -> dict:
    quick = quick or reduced
    arch = default_arch()
    arch_ids = tuple(archs) if archs else (
        REDUCED_ARCHS if reduced else ARCH_IDS)
    if interpret and not reduced:
        print("[exec] WARNING: interpret mode emulates every grid step in "
              "Python — full-size configs can take hours per row; use "
              "--reduced on CPU or --no-interpret on real hardware",
              flush=True)
    scen = tuple(scenarios) if scenarios else tuple(EXEC_SHAPES)
    unknown = set(scen) - set(EXEC_SHAPES)
    if unknown:
        raise KeyError(f"unknown exec scenario(s) {sorted(unknown)}; "
                       f"known: {sorted(EXEC_SHAPES)}")

    rows, table, pooled = [], [], []
    kernels_seen: set[str] = set()
    wgrad_covered: set[str] = set()   # models that executed a wGrad GEMM
    pool_seen: set = set()     # structural op keys: unique ACROSS rows too
    exec_memo: dict = {}       # shared measurements (same settings per run)
    for aid in arch_ids:
        cfg = get_config(aid)
        if reduced:
            cfg = cfg.reduced()
        for sname in scen:
            spec = EXEC_SHAPES[sname]
            work = extract_workload(cfg, spec)
            cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
            total = QUICK_AVG_S * work.n_unique if quick else None
            net = optimize_network(list(work.layers), arch, mode,
                                   counts=list(work.counts),
                                   per_layer_cap_s=cap,
                                   total_budget_s=total, workers=workers)
            plan = lower_plan(cfg, spec, net, arch)
            rep = execute_plan(plan, interpret=interpret, repeats=repeats,
                               seed=seed, memo=exec_memo)
            # pool per-op rank points, structurally unique across ALL rows
            # (reduced configs share shapes; a duplicated op would enter
            # identical predicted cycles twice and pad the gates)
            for op in plan.ops:
                if op.predicted_cycles is None or op.measured_s is None \
                        or op.key in pool_seen:
                    continue
                pool_seen.add(op.key)
                pooled.append((op.predicted_cycles, op.measured_s))
            kernels_seen |= {op.kernel for op in plan.ops}
            if any(op.name.endswith(".wgrad") for op in plan.ops):
                wgrad_covered.add(aid)
            rows.append({
                "model": aid, "scenario": sname, "ops": rep.n_ops,
                "unique": rep.n_unique,
                "predicted_serial_cycles": plan.predicted_serial_cycles,
                "predicted_scheduled_cycles":
                    plan.predicted_scheduled_cycles,
                "measured_s": rep.measured_total_s,
                "rank_corr": rep.rank_corr,
                "numerics_ok": rep.numerics_ok,
                "max_rel_err": rep.max_rel_err,
            })
            table.append([
                aid, sname, rep.n_ops, rep.n_unique,
                f"{plan.predicted_serial_cycles:.4g}",
                f"{plan.predicted_scheduled_cycles:.4g}"
                if plan.predicted_scheduled_cycles else "-",
                f"{rep.measured_total_s * 1e3:.1f}",
                f"{rep.rank_corr:.2f}" if rep.rank_corr is not None
                else "-",
                f"{rep.max_rel_err:.1e}",
                "ok" if rep.numerics_ok else "FAIL"])

    headers = ["model", "scenario", "ops", "unique", "pred serial cyc",
               "pred sched cyc", "measured ms", "rank", "max rel err",
               "numerics"]
    print(md_table(headers, table))
    pooled_rank = spearman([p for p, _ in pooled], [m for _, m in pooled])
    n_bad = sum(not r["numerics_ok"] for r in rows)
    print(f"[exec/{mode}] {len(rows)} (model, scenario) rows, "
          f"{len(pooled)} pooled rank points, pooled spearman "
          f"{pooled_rank if pooled_rank is None else round(pooled_rank, 3)}"
          f", kernels {sorted(kernels_seen)}, "
          f"{n_bad} rows failed numerics")

    payload = {"mode": mode, "interpret": interpret, "rows": rows,
               "pooled_rank_corr": pooled_rank,
               "n_rank_points": len(pooled),
               "kernels": sorted(kernels_seen),
               "wgrad_covered": sorted(wgrad_covered)}
    write_report("exec_lm", payload)

    # --reduced is the CI acceptance path (exec-smoke): enforce the
    # executor's contract instead of warning, so regressions fail the job.
    if reduced:
        for r in rows:
            if not r["numerics_ok"]:
                raise RuntimeError(
                    f"{r['model']}/{r['scenario']}: kernel output diverged "
                    f"from its ref.py oracle (max rel err "
                    f"{r['max_rel_err']:.2e})")
        # pool-level gates (rank statistic, kernel coverage) are calibrated
        # for the full reduced pool — user-narrowed --archs/--scenarios
        # subsets keep the per-row numerics gate only
        full_pool = not archs and not scenarios
        if full_pool and len(pooled) < MIN_RANK_POINTS:
            raise RuntimeError(
                f"only {len(pooled)} rank points — the reduced run must "
                f"exercise >= {MIN_RANK_POINTS} predicted ops")
        if full_pool and pooled_rank is None:
            raise RuntimeError(
                "pooled rank correlation undefined: predicted or measured "
                "side is constant across all ops")
        if full_pool and pooled_rank is not None and \
                pooled_rank < RANK_FLOOR:
            raise RuntimeError(
                f"pooled predicted-vs-measured rank correlation "
                f"{pooled_rank:.3f} < {RANK_FLOOR} (Fig. 4(a) discipline, "
                f"model-vs-execution)")
        missing = {"matmul_int8", "flash_attention", "ssd_scan"} - \
            kernels_seen
        if full_pool and missing:
            raise RuntimeError(f"kernel families never dispatched: "
                               f"{sorted(missing)}")
        no_wgrad = set(arch_ids) - wgrad_covered
        if full_pool and no_wgrad:
            raise RuntimeError(
                f"models that never executed a wGrad GEMM: "
                f"{sorted(no_wgrad)} — the exec_train scenario must cover "
                f"a backward kernel per model")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke-test reductions of the LM configs + "
                         "quick caps + acceptance gates")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer MIP cap (seconds; quick mode clamps)")
    ap.add_argument("--archs", default="",
                    help=f"comma list of arch ids (default: "
                         f"{', '.join(REDUCED_ARCHS)} reduced, else all of "
                         f"{', '.join(ARCH_IDS)})")
    ap.add_argument("--scenarios", default="",
                    help="comma list of exec scenario names (default: "
                         + ",".join(EXEC_SHAPES) + ")")
    ap.add_argument("--mode", default="miredo")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed repeats per unique op (min is reported)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-interpret", action="store_true",
                    help="compile the Pallas kernels for real hardware "
                         "instead of interpret-mode CPU emulation")
    ap.add_argument("--workers", type=int, default=1,
                    help="solver processes (keep 1 once JAX is loaded)")
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        archs=tuple(a for a in args.archs.split(",") if a) or None,
        scenarios=tuple(s for s in args.scenarios.split(",") if s) or None,
        mode=args.mode, repeats=args.repeats, seed=args.seed,
        interpret=not args.no_interpret, workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
