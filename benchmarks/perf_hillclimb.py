"""§Perf hillclimb harness: re-lower a cell under an optimization variant
and diff its roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_hillclimb \
        --cell arctic-480b:train_4k:single --variant moe-scatter

Variants flip the library's implementation switches (module flags /
config transforms / step overrides); every run appends a
hypothesis->before->after record to reports/perf/<cell>__<variant>.json.
"""

# must precede jax import (device count lock) — delegated to dryrun
from repro.launch import dryrun  # noqa: E402  (sets XLA_FLAGS first)

import argparse
import dataclasses
import json
import os

import repro.models.attention as attn_mod
import repro.models.moe as moe_mod
import repro.train.optimizer as opt_mod
import jax.numpy as _jnp
from repro.launch.roofline import roofline_terms


def _pad_heads(cfg):
    ms = 16
    nh = ((cfg.n_heads + ms - 1) // ms) * ms
    return dataclasses.replace(cfg, n_heads=nh, n_kv_heads=nh
                               if cfg.n_kv_heads == cfg.n_heads
                               else cfg.n_kv_heads)


VARIANTS = {
    "moe-scatter": dict(flags={(moe_mod, "MOE_DISPATCH"): "scatter"},
                        hypothesis="sorted scatter/gather dispatch removes "
                        "the O(T*E*C) one-hot dispatch tensors -> memory "
                        "term and HBM footprint collapse"),
    "attn-chunked": dict(flags={(attn_mod, "ATTN_IMPL"): "chunked"},
                         hypothesis="online-softmax KV-block scan never "
                         "materializes the (Lq,Lk) f32 scores -> memory "
                         "term drops on >=4k-seq attention cells"),
    "kv-int8": dict(flags={(attn_mod, "KV_QUANT"): True},
                    hypothesis="int8 KV cache halves decode cache "
                    "footprint (capacity; traffic needs the fused kernel)"),
    "pad-heads": dict(cfg_transform=_pad_heads,
                      hypothesis="padding heads to a multiple of the model "
                      "axis enables attention TP instead of replicated "
                      "attention compute: ~16x less per-device attn work "
                      "for <=14% padded-FLOP overhead"),
    "no-remat": dict(step_overrides={"remat": False},
                     hypothesis="without recompute the memory term drops "
                     "~25% at the cost of activation residency"),
    "combo-best": dict(flags={(moe_mod, "MOE_DISPATCH"): "scatter",
                              (attn_mod, "ATTN_IMPL"): "chunked"},
                       hypothesis="stack the winning moves"),
    "pad-chunked": dict(cfg_transform=_pad_heads,
                        flags={(attn_mod, "ATTN_IMPL"): "chunked"},
                        hypothesis="attention TP via head padding + "
                        "online-softmax chunks: both the replicated "
                        "compute and the L2 score materialization go"),
    "combo-opt16": dict(flags={(moe_mod, "MOE_DISPATCH"): "scatter",
                               (attn_mod, "ATTN_IMPL"): "chunked",
                               (opt_mod, "OPT_STATE_DTYPE"): _jnp.bfloat16},
                        hypothesis="scatter dispatch + chunked attention + "
                        "bf16 Adam moments: 480B params' optimizer slab "
                        "drops from 22.5 to ~15 GB/dev, under the v5e "
                        "16 GB HBM budget"),
}


def run_variant(cell: str, variant: str, out_dir: str = "reports/perf",
                baseline_dir: str = "reports/dryrun") -> dict:
    arch, shape, mesh = cell.split(":")
    multi = mesh == "multi"
    spec = VARIANTS[variant]
    flags = spec.get("flags", {})
    old = {}
    for (mod, name), val in flags.items():
        old[(mod, name)] = getattr(mod, name)
        setattr(mod, name, val)
    try:
        rec = dryrun.lower_cell(
            arch, shape, multi_pod=multi,
            step_overrides=spec.get("step_overrides"),
            plan_overrides=spec.get("plan_overrides"),
            cfg_transform=spec.get("cfg_transform"))
    finally:
        for (mod, name), val in old.items():
            setattr(mod, name, val)
    base_path = os.path.join(
        baseline_dir, f"{arch}__{shape}__{mesh}.json")
    base = json.load(open(base_path)) if os.path.exists(base_path) else None
    result = {
        "cell": cell,
        "variant": variant,
        "hypothesis": spec.get("hypothesis", ""),
        "after_raw": {k: rec.get(k) for k in
                      ("flops_per_device", "bytes_per_device",
                       "collective_bytes_per_device", "memory", "status",
                       "error")},
        "after": roofline_terms(rec) if rec.get("status") == "ok" else None,
        "before": roofline_terms(base) if base else None,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{arch}__{shape}__{mesh}__{variant}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    _print_delta(result)
    return result


def _print_delta(r):
    print(f"\n== {r['cell']} / {r['variant']}")
    print(f"   hypothesis: {r['hypothesis']}")
    b, a = r["before"], r["after"]
    if not a:
        print("   AFTER FAILED:", r["after_raw"].get("error", "?")[:200])
        return
    if not b or b.get("status") != "ok":
        print("   (no baseline)")
        b = None
    higher_better = {"roofline_fraction", "useful_compute_ratio"}
    for term in ("t_compute_s", "t_memory_s", "t_collective_s",
                 "hbm_gb_per_device", "roofline_fraction"):
        before = f"{b[term]:.4g}" if b else "-"
        delta = ""
        if b and a[term] > 0 and b[term] > 0:
            ratio = a[term] / b[term] if term in higher_better \
                else b[term] / a[term]
            delta = f" ({ratio:.2f}x better)" if ratio > 1.001 else (
                f" ({1/ratio:.2f}x WORSE)" if ratio < 0.999 else "")
        print(f"   {term:<22} {before:>12} -> {a[term]:.4g}{delta}")
    print(f"   dominant: {b['dominant'] if b else '?'} -> {a['dominant']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True,
                    help="arch:shape:single|multi")
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    a = ap.parse_args()
    run_variant(a.cell, a.variant)
