"""Multi-chip mesh scaling: an infeasible-on-one-chip model made feasible
— and faster — on 2-4 chips (`core/mesh.py`, DESIGN.md §Mesh optimization).

The showcase model is a stack of structurally distinct GEMM layers whose
combined weights exceed one chip's macro capacity (so the single-chip
scheduler can never keep them resident) but fit a 4-chip mesh. Each row
optimizes the model against an ``n``-chip mesh at fixed link bandwidth
through ``optimize_network(mesh=...)`` — per-layer TP shard choices,
eq. 9-style inter-chip transfer terms, and the (chip, core) placement
scheduler — and reports residency feasibility, the serial/scheduled
cycles, the shard decomposition and the network-mode simulator agreement.
A side sweep varies the link bandwidth at the largest mesh (the DSE axis,
`dse.MeshSpace`).

Registered as the ``mesh`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.mesh_scaling --reduced

``--reduced`` is the CI acceptance path (mesh-smoke) and enforces the
mesh contract instead of warning:

  * the 1-chip mesh reproduces the single-chip result bit for bit
    (totals AND schedule — the `tests/test_mesh.py` invariant, end to
    end);
  * the showcase model is residency-infeasible at 1 chip and feasible at
    4 (`mesh.residency_feasible`);
  * the scheduled makespan strictly improves from 2 to 4 chips at fixed
    link bandwidth;
  * the placement MIP is never worse than the greedy water-filling
    placement (both judged by the scheduled end-to-end cycles);
  * the mesh schedule agrees with the event replay within the Fig. 4(a)
    tolerance (`scheduler.cross_check_mesh`).
"""

from __future__ import annotations

import argparse

from benchmarks.common import md_table, write_report
from repro.core import workload as wl
from repro.core.arch import MeshLink, default_arch
from repro.core.mesh import make_mesh, residency_feasible, total_macro_bytes
from repro.core.network import optimize_network
from repro.core.scheduler import cross_check, cross_check_mesh, schedule_mesh

#: Quick-mode solver knobs (same spirit as benchmarks/sched_lm.py).
QUICK_CAP_S = 2.0
#: Simulator-agreement gate: the tier-1 Fig. 4(a) floor.
SIM_ACC_FLOOR = 0.8
#: Mesh sizes per row; the link-bandwidth sweep runs at the largest.
CHIP_COUNTS = (1, 2, 4)
LINK_BITS_SWEEP = (64, 128, 256, 512)


def showcase_layers() -> tuple[list[wl.Layer], list[int]]:
    """Structurally distinct GEMM stack sized to overflow one chip.

    Eight "block" layers, (M x 96) @ (96 x 96) with distinct M: weight
    footprint 96*96 = 9216 bytes each, 73728 bytes total — over the
    Table-IV chip's 32768 macro bytes (8 cores x 4 KB crossbars) and over
    a 2-chip mesh. Four repeated "head" layers, (M x 48) @ (48 x 48) with
    count 6 (2304 bytes x 6 instances each, 55296 bytes): the depth
    repeats give the scheduler steady-state item streams to pipeline, so
    segment packing — and hence the (chip, core) placement machinery and
    the `cross_check_mesh` replay — genuinely engages at every mesh size.
    Grand total 129024 bytes: infeasible at 1-2 chips, feasible at 4.
    Every split dim divides 2 and 4, so both TP splits stay available."""
    blocks = [wl.gemm(f"blk{i}", m, 96, 96)
              for i, m in enumerate((8, 12, 16, 24, 32, 48, 64, 96))]
    heads = [wl.gemm(f"head{i}", m, 48, 48)
             for i, m in enumerate((16, 24, 32, 40))]
    layers = blocks + heads
    return layers, [1] * len(blocks) + [6] * len(heads)


def run(budget_s: float = 45.0, quick: bool = False, reduced: bool = False,
        mode: str = "miredo", link_bits: int = 256,
        workers: int | None = None) -> dict:
    quick = quick or reduced
    cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
    chip = default_arch()
    layers, counts = showcase_layers()
    link = MeshLink(bandwidth_bits=link_bits)

    # single-chip reference (the N=1 identity target)
    single = optimize_network(layers, chip, mode, counts=counts,
                              per_layer_cap_s=cap, workers=workers)

    rows, table = [], []
    sched_by_n, accs = {}, []
    for n in CHIP_COUNTS:
        mesh = make_mesh(chip, n, link=link)
        net = optimize_network(layers, mesh=mesh, mode=mode, counts=counts,
                               per_layer_cap_s=cap, workers=workers)
        feasible = residency_feasible(layers, counts, mesh)
        s = net.scheduled
        if n == 1:
            acc, n_checked = cross_check(net.schedule, chip)
            shards = "-"
            mip_vs_greedy = None
        else:
            acc, n_checked = cross_check_mesh(net.schedule, mesh)
            shards = ",".join(sorted({lr.record["shard"]["choice"]
                                      for lr in net.layers}))
            greedy_sched = schedule_mesh(net.layers, mesh, use_mip=False)
            mip_vs_greedy = (net.schedule.scheduled_cycles,
                             greedy_sched.scheduled_cycles)
        if n_checked:
            accs.append(acc)
        sched_by_n[n] = s["cycles"]
        rows.append({
            "n_chips": n, "feasible": feasible,
            "serial_cycles": s["serial_cycles"],
            "scheduled_cycles": s["cycles"],
            "n_packed": int(s["n_packed"]), "shards": shards,
            "mip_cycles": mip_vs_greedy[0] if mip_vs_greedy else None,
            "greedy_cycles": mip_vs_greedy[1] if mip_vs_greedy else None,
            "sim_accuracy": acc if n_checked else None,
            "sim_segments": n_checked,
        })
        table.append([n, "yes" if feasible else "NO",
                      f"{s['serial_cycles']:.4g}", f"{s['cycles']:.4g}",
                      int(s["n_packed"]), shards,
                      f"{acc:.3f}" if n_checked else "-"])

    headers = ["chips", "resident-feasible", "serial cyc", "sched cyc",
               "packed", "shards", "sim acc"]
    print(md_table(headers, table))
    need = sum(c * l.operand_elems("W") for l, c in zip(layers, counts))
    print(f"[mesh/{mode}] weights {need} B vs "
          f"{total_macro_bytes(make_mesh(chip, 1))} B/chip; scheduled "
          + " -> ".join(f"{n}: {sched_by_n[n]:.4g}" for n in CHIP_COUNTS))

    # link-bandwidth sweep at the largest mesh (the DSE axis)
    n_top = CHIP_COUNTS[-1]
    sweep = []
    for bits in LINK_BITS_SWEEP:
        mesh = make_mesh(chip, n_top, link=MeshLink(bandwidth_bits=bits))
        net = optimize_network(layers, mesh=mesh, mode=mode, counts=counts,
                               per_layer_cap_s=cap, workers=workers)
        sweep.append({"link_bits": bits,
                      "scheduled_cycles": net.scheduled["cycles"]})
    print(md_table(["link bits", f"sched cyc @ {n_top} chips"],
                   [[s["link_bits"], f"{s['scheduled_cycles']:.4g}"]
                    for s in sweep]))

    mean_acc = sum(accs) / len(accs) if accs else 1.0
    payload = {"mode": mode, "link_bits": link_bits, "rows": rows,
               "link_sweep": sweep, "mean_sim_accuracy": mean_acc,
               "single_chip": {"totals": single.totals,
                               "scheduled": single.scheduled}}
    write_report("mesh_scaling", payload)

    # --reduced is the CI acceptance path (mesh-smoke): enforce the mesh
    # contract instead of warning, so regressions fail the job.
    if reduced:
        mesh1 = optimize_network(layers, mesh=make_mesh(chip, 1, link=link),
                                 mode=mode, counts=counts,
                                 per_layer_cap_s=cap, workers=workers)
        if mesh1.totals != single.totals or \
                mesh1.scheduled != single.scheduled:
            raise RuntimeError(
                f"1-chip mesh is not the single chip: totals "
                f"{mesh1.totals} vs {single.totals}, scheduled "
                f"{mesh1.scheduled} vs {single.scheduled}")
        by_n = {r["n_chips"]: r for r in rows}
        if by_n[1]["feasible"]:
            raise RuntimeError("showcase model unexpectedly fits one chip "
                               "(the benchmark exists to overflow it)")
        if not by_n[CHIP_COUNTS[-1]]["feasible"]:
            raise RuntimeError(
                f"showcase model does not fit {CHIP_COUNTS[-1]} chips")
        if not sched_by_n[4] < sched_by_n[2]:
            raise RuntimeError(
                f"scheduled makespan did not improve 2 -> 4 chips: "
                f"{sched_by_n[2]} -> {sched_by_n[4]}")
        for r in rows:
            if r["mip_cycles"] is not None and \
                    r["mip_cycles"] > r["greedy_cycles"] + 1e-6:
                raise RuntimeError(
                    f"{r['n_chips']} chips: placement MIP worse than "
                    f"greedy ({r['mip_cycles']} > {r['greedy_cycles']})")
            if r["scheduled_cycles"] > r["serial_cycles"]:
                raise RuntimeError(
                    f"{r['n_chips']} chips: scheduled worse than serial")
        if accs and mean_acc < SIM_ACC_FLOOR:
            raise RuntimeError(
                f"mesh simulator agreement {mean_acc:.3f} < "
                f"{SIM_ACC_FLOOR} (Fig. 4(a) tolerance)")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="quick caps + CI acceptance gates (mesh-smoke)")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer MIP cap (seconds; quick mode clamps)")
    ap.add_argument("--mode", default="miredo")
    ap.add_argument("--link-bits", type=int, default=256,
                    help="link bandwidth (bits/cycle) for the scaling rows")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        mode=args.mode, link_bits=args.link_bits, workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
