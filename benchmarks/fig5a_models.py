"""Fig. 5(a): EDP reduction of MIREDO vs the ZigZag-style heuristic across
DNN models (paper: 1.6x – 3.2x), extended with this repo's assigned
LM-architecture block workloads.

Runs through the network-level pipeline (core/network.py): all models'
layers are pooled into one call per mode, so structurally identical layers
across models dedup to a single solve and the MIP solves share a global
MAC-weighted wall-clock budget across worker processes."""

from __future__ import annotations

from benchmarks.common import md_table, write_report
from repro.core.arch import default_arch
from repro.core.network import optimize_network
from repro.core.workload import (MODEL_ZOO, lm_block_gemms)


def model_workloads(quick: bool = False) -> dict:
    out = {
        "resnet18": MODEL_ZOO["resnet18"](),
        "mobilenetv2": MODEL_ZOO["mobilenetv2"](),
        "bert-base": MODEL_ZOO["bert-base"](),
    }
    if not quick:
        out["resnet50"] = MODEL_ZOO["resnet50"]()
        out["vgg16"] = MODEL_ZOO["vgg16"]()
        # assigned-arch LM blocks through the same CIM optimizer
        out["minicpm-2b-block"] = lm_block_gemms(
            "minicpm", 2304, 36, 36, 5760, seq=256)
        out["qwen2-moe-block"] = lm_block_gemms(
            "qwen2moe", 2048, 16, 16, 1408, seq=256, n_experts=60, top_k=4)
    return out


def run(budget_s: float = 45.0, quick: bool = False) -> dict:
    arch = default_arch()
    models = model_workloads(quick)
    pooled = [layer for layers in models.values() for layer in layers]
    # schedule=False: this figure reads per-layer EDP only, and the pooled
    # stream spans independent models the scheduler must not pipeline
    # across (benchmarks/lm_models.py shows the schedule_boundaries
    # alternative when the scheduled number is wanted)
    nets = {mode: optimize_network(pooled, arch, mode,
                                   per_layer_cap_s=budget_s,
                                   schedule=False)
            for mode in ("miredo", "heuristic")}

    rows, ratios = [], {}
    off = 0
    for model, layers in models.items():
        sl = slice(off, off + len(layers))
        off += len(layers)
        edp_m = sum(lr.edp for lr in nets["miredo"].layers[sl])
        edp_h = sum(lr.edp for lr in nets["heuristic"].layers[sl])
        ratios[model] = edp_h / edp_m
        rows.append([model, f"{edp_h:.4g}", f"{edp_m:.4g}",
                     f"{ratios[model]:.2f}x"])
    payload = {"rows": rows, "ratios": ratios,
               "paper_claim": "1.6x-3.2x EDP reduction",
               "pipeline": {
                   m: {"wall_s": n.wall_s, "n_unique": n.n_unique,
                       "n_solved": n.n_solved, "cache_hits": n.cache_hits}
                   for m, n in nets.items()}}
    write_report("fig5a_models", payload)
    print(md_table(["model", "heuristic EDP", "MIREDO EDP", "reduction"],
                   rows))
    print(f"[pipeline] miredo: {nets['miredo'].n_unique} unique layers, "
          f"{nets['miredo'].cache_hits} cached, "
          f"wall {nets['miredo'].wall_s:.0f}s")
    return payload


if __name__ == "__main__":
    run()
