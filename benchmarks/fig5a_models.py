"""Fig. 5(a): EDP reduction of MIREDO vs the ZigZag-style heuristic across
DNN models (paper: 1.6x – 3.2x), extended with this repo's assigned
LM-architecture block workloads."""

from __future__ import annotations

from benchmarks.common import md_table, solve_cached, write_report
from repro.core.arch import default_arch
from repro.core.workload import (MODEL_ZOO, lm_block_gemms)


def model_workloads(quick: bool = False) -> dict:
    out = {
        "resnet18": MODEL_ZOO["resnet18"](),
        "mobilenetv2": MODEL_ZOO["mobilenetv2"](),
        "bert-base": MODEL_ZOO["bert-base"](),
    }
    if not quick:
        out["resnet50"] = MODEL_ZOO["resnet50"]()
        out["vgg16"] = MODEL_ZOO["vgg16"]()
        # assigned-arch LM blocks through the same CIM optimizer
        out["minicpm-2b-block"] = lm_block_gemms(
            "minicpm", 2304, 36, 36, 5760, seq=256)
        out["qwen2-moe-block"] = lm_block_gemms(
            "qwen2moe", 2048, 16, 16, 1408, seq=256, n_experts=60, top_k=4)
    return out


def run(budget_s: float = 45.0, quick: bool = False) -> dict:
    arch = default_arch()
    rows, ratios = [], {}
    for model, layers in model_workloads(quick).items():
        edp_m = edp_h = 0.0
        for layer in layers:
            rm = solve_cached(layer, arch, "miredo", budget_s=budget_s)
            rh = solve_cached(layer, arch, "heuristic", budget_s=budget_s)
            edp_m += rm["edp"]
            edp_h += rh["edp"]
        ratios[model] = edp_h / edp_m
        rows.append([model, f"{edp_h:.4g}", f"{edp_m:.4g}",
                     f"{ratios[model]:.2f}x"])
    payload = {"rows": rows, "ratios": ratios,
               "paper_claim": "1.6x-3.2x EDP reduction"}
    write_report("fig5a_models", payload)
    print(md_table(["model", "heuristic EDP", "MIREDO EDP", "reduction"],
                   rows))
    return payload


if __name__ == "__main__":
    run()
