"""Serial-sum vs multi-core-scheduled end-to-end latency across the LM zoo
(`core/scheduler.py`, DESIGN.md §Network scheduler).

Unlike ``benchmarks/lm_models.py`` — which pools every (model, scenario)
workload into ONE network-pipeline call because per-layer EDP is pooling-
invariant — a *schedule* is a property of one model's ordered layer
stream, so each (model, scenario) pair runs its own ``optimize_network``
call. The shared on-disk cache still dedups the underlying solves across
rows (reduced configs share most GEMM shapes), and every row reports the
serial baseline, the scheduled end-to-end latency, the segment/packing
breakdown and the network-mode event-simulator agreement.

Registered as the ``sched`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python -m benchmarks.sched_lm --reduced
    PYTHONPATH=src python -m benchmarks.sched_lm \\
        --archs minicpm-2b,glm4-9b --reduced --scenarios decode_32k

``--reduced`` is the CI acceptance path (sched-smoke): every row with a
packed segment must strictly beat its serial baseline, no row may ever be
worse than it, at least one row must pack, and the simulator must agree
with the analytical schedule model within the same tolerance the tier-1
suite enforces for single layers (Fig. 4(a) discipline,
``tests/test_latency_model.py::test_simulator_agreement``).
"""

from __future__ import annotations

import argparse

from benchmarks.common import md_table, write_report
from repro.configs import ARCH_IDS, get_config
from repro.core.arch import default_arch
from repro.core.frontend import extract_all
from repro.core.network import optimize_network
from repro.core.scheduler import cross_check

#: Scenario subset for ``--quick`` / ``--reduced`` runs.
QUICK_SCENARIOS = ("prefill_32k", "decode_32k")
#: Quick-mode solver knobs (same spirit as benchmarks/lm_models.py).
QUICK_CAP_S = 2.0
QUICK_AVG_S = 1.0
#: Simulator-agreement gate: mean accuracy over replayed segments — the
#: same floor the tier-1 Fig. 4(a) agreement test uses for single layers.
SIM_ACC_FLOOR = 0.8


def run(budget_s: float = 45.0, quick: bool = False, reduced: bool = False,
        archs: tuple[str, ...] | None = None,
        scenarios: tuple[str, ...] | None = None,
        mode: str = "miredo",
        workers: int | None = None) -> dict:
    quick = quick or reduced
    arch = default_arch()
    arch_ids = tuple(archs) if archs else ARCH_IDS
    scen = tuple(scenarios) if scenarios else (
        QUICK_SCENARIOS if quick else None)

    works = []
    for aid in arch_ids:
        cfg = get_config(aid)
        if reduced:
            cfg = cfg.reduced()
        for work in extract_all(cfg, scen).values():
            works.append((aid, work))

    rows, table, accs = [], [], []
    for aid, work in works:
        cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
        total = QUICK_AVG_S * work.n_unique if quick else None
        net = optimize_network(list(work.layers), arch, mode,
                               counts=list(work.counts),
                               per_layer_cap_s=cap, total_budget_s=total,
                               workers=workers)
        s = net.scheduled
        acc, n_checked = cross_check(net.schedule, arch)
        if n_checked:
            accs.append(acc)
        speedup = s["serial_cycles"] / max(s["cycles"], 1.0)
        rows.append({
            "model": aid, "scenario": work.scenario,
            "layers": len(work), "serial_cycles": s["serial_cycles"],
            "scheduled_cycles": s["cycles"], "speedup": speedup,
            "n_segments": int(s["n_segments"]),
            "n_packed": int(s["n_packed"]),
            "sim_accuracy": acc if n_checked else None,
            "sim_segments": n_checked,
        })
        table.append([aid, work.scenario, len(work),
                      int(s["n_segments"]), int(s["n_packed"]),
                      f"{s['serial_cycles']:.4g}", f"{s['cycles']:.4g}",
                      f"{speedup:.3f}x",
                      f"{acc:.3f}" if n_checked else "-"])

    headers = ["model", "scenario", "layers", "segments", "packed",
               "serial cyc", "sched cyc", "speedup", "sim acc"]
    print(md_table(headers, table))
    mean_acc = sum(accs) / len(accs) if accs else 1.0
    n_packed_rows = sum(r["n_packed"] > 0 for r in rows)
    print(f"[sched/{mode}] {len(rows)} (model, scenario) rows, "
          f"{n_packed_rows} with packed segments, mean simulator "
          f"agreement {mean_acc:.3f} over "
          f"{sum(r['sim_segments'] for r in rows)} segments")

    payload = {"mode": mode, "rows": rows, "mean_sim_accuracy": mean_acc,
               "n_packed_rows": n_packed_rows}
    write_report("sched_lm", payload)

    # --reduced is the CI acceptance path (sched-smoke): enforce the
    # scheduler's contract instead of warning, so regressions fail the job.
    if reduced:
        for r in rows:
            if r["n_packed"] > 0 and not \
                    r["scheduled_cycles"] < r["serial_cycles"]:
                raise RuntimeError(
                    f"{r['model']}/{r['scenario']}: {r['n_packed']} packed "
                    f"segments but scheduled {r['scheduled_cycles']} !< "
                    f"serial {r['serial_cycles']}")
            if r["scheduled_cycles"] > r["serial_cycles"]:
                raise RuntimeError(
                    f"{r['model']}/{r['scenario']}: scheduled worse than "
                    f"serial ({r['scheduled_cycles']} > "
                    f"{r['serial_cycles']})")
        if n_packed_rows == 0:
            raise RuntimeError("no (model, scenario) row packed a segment "
                               "(acceptance: scheduling must engage on the "
                               "reduced zoo)")
        if accs and mean_acc < SIM_ACC_FLOOR:
            raise RuntimeError(
                f"network-mode simulator agreement {mean_acc:.3f} < "
                f"{SIM_ACC_FLOOR} (Fig. 4(a) tolerance)")
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke-test reductions of the LM configs + "
                         "quick caps + acceptance gates")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer MIP cap (seconds; quick mode clamps)")
    ap.add_argument("--archs", default="",
                    help=f"comma list of arch ids (default: all of "
                         f"{', '.join(ARCH_IDS)})")
    ap.add_argument("--scenarios", default="",
                    help="comma list of ShapeSpec names (default: all "
                         "applicable; quick: " + ",".join(QUICK_SCENARIOS)
                         + ")")
    ap.add_argument("--mode", default="miredo")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        archs=tuple(a for a in args.archs.split(",") if a) or None,
        scenarios=tuple(s for s in args.scenarios.split(",") if s) or None,
        mode=args.mode, workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
