"""Fig. 5(b–d): robustness across hardware configurations — macro geometry,
core count, buffer capacities (paper shows consistent EDP reductions).

Each preset is a `default_arch` knob variant whose layer set runs through
`network.optimize_network` (structural dedup, MAC-weighted budgets,
process fan-out — DESIGN.md §Network pipeline); records land in the
shared arch-keyed cache, so presets never collide and reruns are
incremental. This benchmark reproduces the paper's three hand-picked
sweeps; *systematic* architecture exploration — screened grids, Pareto
frontier over (latency, energy, area) — is `benchmarks/dse_pareto.py` on
top of `core/dse.py` (DESIGN.md §Co-design DSE)."""

from __future__ import annotations

from benchmarks.common import md_table, write_report
from repro.core.arch import default_arch
from repro.core.network import optimize_network
from repro.core.workload import resnet18

SWEEPS = {
    "macro": [
        ("64x32", dict(macro_rows=64, macro_cols=32)),
        ("128x32", dict(macro_rows=128, macro_cols=32)),
        ("256x64", dict(macro_rows=256, macro_cols=64)),
    ],
    "cores": [
        ("4", dict(n_cores=4)),
        ("8", dict(n_cores=8)),
        ("16", dict(n_cores=16)),
    ],
    "gbuf": [
        ("4KB", dict(gbuf_kb=4)),
        ("8KB", dict(gbuf_kb=8)),
        ("32KB", dict(gbuf_kb=32)),
    ],
}

# representative subset (multiplicity-weighted layers dominate ResNet-18)
LAYERS = ("conv2_x", "conv3_x", "conv4_x", "conv5_x")


def run(budget_s: float = 45.0, quick: bool = False) -> dict:
    layers = [l for l in resnet18() if l.name in LAYERS]
    if quick:
        layers = layers[:2]
    rows = []
    results = {}
    for sweep, variants in SWEEPS.items():
        for tag, kw in variants:
            arch = default_arch(name=f"{sweep}-{tag}", **kw)
            # schedule=False: the sweep compares serial-sum EDP ratios only
            nets = {mode: optimize_network(layers, arch, mode,
                                           per_layer_cap_s=budget_s,
                                           schedule=False)
                    for mode in ("miredo", "heuristic")}
            edp_m = nets["miredo"].totals["edp"]
            edp_h = nets["heuristic"].totals["edp"]
            ratio = edp_h / edp_m
            results[f"{sweep}/{tag}"] = ratio
            rows.append([sweep, tag, f"{edp_h:.4g}", f"{edp_m:.4g}",
                         f"{ratio:.2f}x"])
    payload = {"rows": rows, "ratios": results}
    write_report("fig5bcd_hw_sweep", payload)
    print(md_table(["sweep", "config", "heuristic EDP", "MIREDO EDP",
                    "reduction"], rows))
    return payload


if __name__ == "__main__":
    run()
