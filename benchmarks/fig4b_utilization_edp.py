"""Fig. 4(b): macro utilization vs EDP trade-off for a representative layer
(WS maximizes spatial utilization but lands on a worse EDP; MIREDO trades a
little utilization for a much better system-level point)."""

from __future__ import annotations

from benchmarks.common import md_table, solve_cached, write_report
from repro.core.arch import default_arch
from repro.core.workload import resnet18


def run(budget_s: float = 60.0, layer_name: str = "conv3_x") -> dict:
    arch = default_arch()
    layer = next(l for l in resnet18() if l.name == layer_name)
    rows = []
    recs = {}
    for mode in ("greedy", "ws", "heuristic", "miredo"):
        r = solve_cached(layer, arch, mode, budget_s=budget_s)
        recs[mode] = r
        rows.append([mode, f"{r['spatial_util']:.3f}",
                     f"{r['temporal_util']:.3f}", f"{r['cycles']:.4g}",
                     f"{r['edp']:.4g}"])
    payload = {"layer": layer_name, "rows": rows,
               "edp_gain_vs_ws": recs["ws"]["edp"] / recs["miredo"]["edp"],
               "edp_gain_vs_heuristic":
                   recs["heuristic"]["edp"] / recs["miredo"]["edp"]}
    write_report("fig4b_utilization_edp", payload)
    print(md_table(["dataflow", "spatial util", "temporal util", "cycles",
                    "EDP"], rows))
    print(f"\nEDP reduction vs WS: {payload['edp_gain_vs_ws']:.2f}x, "
          f"vs heuristic: {payload['edp_gain_vs_heuristic']:.2f}x")
    return payload


if __name__ == "__main__":
    run()
