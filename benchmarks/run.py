"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``

One benchmark per paper table/figure, plus the beyond-paper jobs: the TPU
bridge, the ``lm`` job (the whole LM model zoo lowered through the model
frontend, ``benchmarks/lm_models.py``), the ``dse`` job (hardware/
dataflow co-design Pareto frontier, ``benchmarks/dse_pareto.py``), the
``sched`` job (serial-sum vs multi-core-scheduled end-to-end latency,
``benchmarks/sched_lm.py``), the ``serve`` job (request-level serving
under traffic with continuous batching, ``benchmarks/serve_sim.py``) and
the ``exec`` job (optimized plans executed on the Pallas kernels,
predicted vs measured, ``benchmarks/exec_lm.py``), the ``mesh`` job
(multi-chip mesh scaling with TP sharding and (chip, core) placement,
``benchmarks/mesh_scaling.py``) and the ``train`` job (training
workloads: backward-pass + optimizer-step lowering with per-model
fwd/bwd/update splits, ``benchmarks/train_lm_workloads.py``).
``--quick`` trims solve budgets; results cache under reports/cache so
reruns are incremental, and ``--cache-dir`` points the solve-record cache
at a persistent location shared across runs/machines (equivalent to
setting ``MIREDO_CACHE``). Unknown ``--only`` names fail the run — a typo
must not produce an empty, green harness.
"""

from __future__ import annotations

import argparse
import os
import time
import traceback


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke-test reductions + acceptance gates for "
                         "the jobs that support them (implies --quick)")
    ap.add_argument("--only", default="",
                    help="comma list: fig4a,fig4b,fig4c,fig5a,fig5bcd,"
                         "flexfact,bridge,lm,dse,sched,serve,exec,optspeed,"
                         "mesh,train")
    ap.add_argument("--cache-dir", default="",
                    help="persistent solve-record cache directory (sets "
                         "MIREDO_CACHE; default reports/cache)")
    ap.add_argument("--portfolio", action="store_true",
                    help="optspeed job only: run the racing-solver-"
                         "portfolio gate (incumbent-unimproved rate "
                         "before vs after at equal budget) instead of "
                         "the throughput race")
    args = ap.parse_args(argv)
    if args.reduced:
        args.quick = True
    if args.cache_dir:
        # Every ResultCache() resolves its directory through
        # cache.default_cache_dir(), which reads MIREDO_CACHE — setting it
        # here routes all jobs (including process-pool workers, which
        # inherit the environment) at the shared store.
        os.environ["MIREDO_CACHE"] = args.cache_dir
    budget = 20.0 if args.quick else 60.0
    only = set(filter(None, args.only.split(","))) if args.only else None

    from benchmarks import (dse_pareto, exec_lm, fig4a_model_accuracy,
                            fig4b_utilization_edp, fig4c_per_layer,
                            fig5a_models, fig5bcd_hw_sweep, lm_models,
                            mesh_scaling, opt_speed, sched_lm, serve_sim,
                            tab_flexfact, tpu_bridge_bench,
                            train_lm_workloads)

    jobs = [
        ("fig4a", lambda: fig4a_model_accuracy.run(
            budget_mappings=24 if args.quick else 60)),
        ("fig4b", lambda: fig4b_utilization_edp.run(budget_s=budget)),
        ("fig4c", lambda: fig4c_per_layer.run(budget_s=budget)),
        ("fig5a", lambda: fig5a_models.run(budget_s=budget,
                                           quick=args.quick)),
        ("fig5bcd", lambda: fig5bcd_hw_sweep.run(
            budget_s=budget, quick=args.quick)),
        ("flexfact", lambda: tab_flexfact.run(budget_s=min(budget, 45.0))),
        ("bridge", tpu_bridge_bench.run),
        ("lm", lambda: lm_models.run(budget_s=budget, quick=args.quick)),
        ("dse", lambda: dse_pareto.run(budget_s=budget, quick=args.quick,
                                       reduced=args.quick)),
        ("sched", lambda: sched_lm.run(budget_s=budget, quick=args.quick,
                                       reduced=args.quick)),
        # Request-level serving under traffic: continuous batching vs the
        # serial baseline, percentile latencies and SLO-goodput arch
        # ranking (benchmarks/serve_sim.py).
        ("serve", lambda: serve_sim.run(budget_s=budget, quick=args.quick,
                                        reduced=args.quick)),
        # exec always runs reduced: interpret mode emulates every grid step
        # in Python, so full-size configs are a real-hardware exercise
        # (benchmarks/exec_lm.py --no-interpret), not a harness target.
        ("exec", lambda: exec_lm.run(budget_s=budget, quick=args.quick,
                                     reduced=True)),
        # scalar-vs-batched throughput race + exact-agreement check; the
        # cold/warm DSE timing is its standalone --dse flag (minutes) and
        # the solver-portfolio gate its --portfolio flag.
        ("optspeed", lambda: opt_speed.run(quick=args.quick,
                                           portfolio=args.portfolio)),
        # Multi-chip mesh scaling: infeasible-on-one-chip model on 2-4
        # chips, TP sharding + (chip, core) placement
        # (benchmarks/mesh_scaling.py).
        ("mesh", lambda: mesh_scaling.run(budget_s=budget, quick=args.quick,
                                          reduced=args.reduced)),
        # Training workloads: backward-pass + optimizer-step lowering,
        # per-model fwd/dGrad/wGrad/update cycle splits and the layers
        # whose optimal backward dataflow differs from the forward's
        # (benchmarks/train_lm_workloads.py).
        ("train", lambda: train_lm_workloads.run(
            budget_s=budget, quick=args.quick, reduced=args.reduced)),
    ]
    # A typo'd --only used to run zero jobs and still print "All benchmarks
    # complete" with exit 0 — validate against the job list instead.
    known = {name for name, _ in jobs}
    if only is not None:
        unknown = only - known
        if unknown or not only:
            what = ", ".join(sorted(unknown)) if unknown else "(none given)"
            print(f"unknown --only job(s): {what}; "
                  f"known: {', '.join(name for name, _ in jobs)}")
            return 2
    failures = []
    for name, fn in jobs:
        if only and name not in only:
            continue
        print(f"\n{'='*70}\n== {name}\n{'='*70}", flush=True)
        t0 = time.monotonic()
        try:
            fn()
            print(f"[{name}] done in {time.monotonic()-t0:.0f}s", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print(f"\nFAILED: {failures}")
        return 1
    print("\nAll benchmarks complete; JSON under reports/benchmarks/.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
