"""Fig. 4(c): per-layer latency of MIREDO vs the ZigZag-style heuristic vs
the constrained weight-stationary dataflow, on ResNet-18 — through the
network pipeline (one parallel budgeted pass per mode, block-repeat
multiplicity handled by ``counts``)."""

from __future__ import annotations

from benchmarks.common import md_table, write_report
from repro.core.arch import default_arch
from repro.core.network import optimize_network
from repro.core.workload import RESNET18_MULTIPLICITY, resnet18


def run(budget_s: float = 60.0) -> dict:
    arch = default_arch()
    layers = resnet18()
    counts = [RESNET18_MULTIPLICITY.get(l.name, 1) for l in layers]
    # schedule=False: the figure reports per-layer latencies only
    nets = {mode: optimize_network(layers, arch, mode, counts=counts,
                                   per_layer_cap_s=budget_s,
                                   schedule=False)
            for mode in ("miredo", "ws", "heuristic")}
    rows = []
    for i, layer in enumerate(layers):
        recs = {m: nets[m].layers[i].record for m in nets}
        rows.append([
            layer.name,
            f"{recs['heuristic']['cycles']:.3g}",
            f"{recs['ws']['cycles']:.3g}",
            f"{recs['miredo']['cycles']:.3g}",
            f"{recs['heuristic']['cycles'] / recs['miredo']['cycles']:.2f}x",
            f"{recs['ws']['cycles'] / recs['miredo']['cycles']:.2f}x",
        ])
    total = {m: nets[m].totals["cycles"] for m in nets}
    rows.append(["TOTAL(weighted)", f"{total['heuristic']:.4g}",
                 f"{total['ws']:.4g}", f"{total['miredo']:.4g}",
                 f"{total['heuristic'] / total['miredo']:.2f}x",
                 f"{total['ws'] / total['miredo']:.2f}x"])
    payload = {"rows": rows, "totals": total,
               "speedup_vs_heuristic": total["heuristic"] / total["miredo"],
               "speedup_vs_ws": total["ws"] / total["miredo"]}
    write_report("fig4c_per_layer", payload)
    print(md_table(["layer", "heuristic", "WS", "MIREDO",
                    "speedup vs heur", "speedup vs WS"], rows))
    return payload


if __name__ == "__main__":
    run()
