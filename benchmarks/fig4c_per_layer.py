"""Fig. 4(c): per-layer latency of MIREDO vs the ZigZag-style heuristic vs
the constrained weight-stationary dataflow, on ResNet-18."""

from __future__ import annotations

from benchmarks.common import md_table, solve_cached, write_report
from repro.core.arch import default_arch
from repro.core.workload import RESNET18_MULTIPLICITY, resnet18


def run(budget_s: float = 60.0) -> dict:
    arch = default_arch()
    rows = []
    total = {"miredo": 0.0, "ws": 0.0, "heuristic": 0.0}
    for layer in resnet18():
        recs = {m: solve_cached(layer, arch, m, budget_s=budget_s)
                for m in ("miredo", "ws", "heuristic")}
        mult = RESNET18_MULTIPLICITY.get(layer.name, 1)
        for m in total:
            total[m] += recs[m]["cycles"] * mult
        rows.append([
            layer.name,
            f"{recs['heuristic']['cycles']:.3g}",
            f"{recs['ws']['cycles']:.3g}",
            f"{recs['miredo']['cycles']:.3g}",
            f"{recs['heuristic']['cycles'] / recs['miredo']['cycles']:.2f}x",
            f"{recs['ws']['cycles'] / recs['miredo']['cycles']:.2f}x",
        ])
    rows.append(["TOTAL(weighted)", f"{total['heuristic']:.4g}",
                 f"{total['ws']:.4g}", f"{total['miredo']:.4g}",
                 f"{total['heuristic'] / total['miredo']:.2f}x",
                 f"{total['ws'] / total['miredo']:.2f}x"])
    payload = {"rows": rows, "totals": total,
               "speedup_vs_heuristic": total["heuristic"] / total["miredo"],
               "speedup_vs_ws": total["ws"] / total["miredo"]}
    write_report("fig4c_per_layer", payload)
    print(md_table(["layer", "heuristic", "WS", "MIREDO",
                    "speedup vs heur", "speedup vs WS"], rows))
    return payload


if __name__ == "__main__":
    run()
