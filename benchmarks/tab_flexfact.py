"""Flexible Factorization ablation (paper §IV-B / Alg. 1): factor-pool size,
MIP size, solve time and mapping quality vs (alpha, k_min)."""

from __future__ import annotations

import math
import time

from benchmarks.common import md_table, write_report
from repro.core.arch import default_arch
from repro.core.factorization import flexible_factorization, prime_factors
from repro.core.formulation import FormulationConfig, optimize_layer
from repro.core.workload import resnet18

SETTINGS = [
    ("prime (no merge)", 0.0, 99),
    ("k_min=4, a=0.05", 0.05, 4),
    ("k_min=3, a=0.15 (default)", 0.15, 3),
    ("k_min=2, a=0.4", 0.4, 2),
]


def run(budget_s: float = 45.0, layer_name: str = "conv4_x") -> dict:
    arch = default_arch()
    layer = next(l for l in resnet18() if l.name == layer_name)
    rows = []
    for tag, alpha, k_min in SETTINGS:
        n_factors = sum(
            len(flexible_factorization(layer.bound(d), alpha, k_min))
            for d in ("K", "C", "OY", "OX", "FY", "FX"))
        t0 = time.monotonic()
        try:
            cfg = FormulationConfig(alpha=alpha, k_min=k_min,
                                    time_limit_s=budget_s)
            res = optimize_layer(layer, arch, cfg)
            cyc, nv = res.eval_latency, res.n_vars
        except Exception as e:          # prime pools can explode combos
            cyc, nv = math.nan, -1
        rows.append([tag, n_factors, nv, f"{time.monotonic()-t0:.0f}s",
                     f"{cyc:.4g}"])
    payload = {"layer": layer_name, "rows": rows}
    write_report("tab_flexfact", payload)
    print(md_table(["setting", "total factors", "MIP vars", "wall",
                    "cycles"], rows))
    return payload


if __name__ == "__main__":
    run()
