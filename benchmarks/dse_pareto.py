"""Hardware/dataflow co-design Pareto frontier (`core/dse.py`,
DESIGN.md §Co-design DSE): sweep a ``CimArch`` grid against an LM-frontend
(or conv-zoo) workload — cheap incumbent screening prunes the grid, the
survivors get warm-started MIP solves through `network.optimize_over_archs`
with one shared arch-keyed cache — and report the non-dominated
(scheduled end-to-end latency, energy, area = macros x crossbar bits)
points, every frontier mapping re-checked by the mapping validator. The
latency objective is the multi-core schedule's (`core/scheduler.py`), so
core/macro-rich archs are credited for cross-layer parallelism.

Registered as the ``dse`` job in ``benchmarks.run``; standalone CLI:

    PYTHONPATH=src python benchmarks/dse_pareto.py --reduced
    PYTHONPATH=src python benchmarks/dse_pareto.py \\
        --models minicpm-2b --scenarios decode_32k --workload lm
    PYTHONPATH=src python benchmarks/dse_pareto.py --workload resnet18
"""

from __future__ import annotations

import argparse
import os
import sys

if __package__ in (None, ""):      # `python benchmarks/dse_pareto.py`
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import md_table, write_report
from repro.core.dse import ArchSpace, run_dse
from repro.core.workload import RESNET18_MULTIPLICITY, resnet18

#: Default LM workload: two small registry models; ``--reduced`` swaps in
#: their CPU smoke-test reductions so the whole frontier lands in minutes.
DEFAULT_MODELS = ("minicpm-2b", "glm4-9b")
DEFAULT_SCENARIOS = ("decode_32k", "prefill_32k")
#: Quick-mode solver knobs (same spirit as benchmarks/lm_models.py): a
#: small per-layer cap plus ~1 s of global budget per unique layer per
#: arch; the warm start keeps every capped solve feasible.
QUICK_CAP_S = 2.0
QUICK_AVG_S = 1.0


def default_space() -> ArchSpace:
    """24-point grid: 3 macro geometries x 2 core counts x 2 GBuf x 2 LBuf
    capacities. Buffer knobs deliberately include small points — they
    create the dominated/tied archs the screening pass exists to prune."""
    return ArchSpace(macro=((64, 32), (128, 32), (256, 64)),
                     n_cores=(4, 16),
                     gbuf_kb=(2.0, 8.0),
                     lbuf_kb=(16.0, 256.0))


def lm_workload(models: tuple[str, ...], scenarios: tuple[str, ...],
                reduced: bool) -> tuple[list, list, list]:
    """(layers, counts, boundaries): pooled across (model, scenario) pairs
    for dedup/budgeting, with each pair's start index recorded so the
    scheduler never pipelines across independent workloads."""
    from repro.configs import get_config
    from repro.core.frontend import extract_all

    layers, counts, bounds = [], [], []
    for mid in models:
        cfg = get_config(mid)
        if reduced:
            cfg = cfg.reduced()
        for work in extract_all(cfg, scenarios).values():
            bounds.append(len(layers))
            layers += list(work.layers)
            counts += list(work.counts)
    return layers, counts, bounds


def run(budget_s: float = 45.0, quick: bool = False, reduced: bool = False,
        workload: str = "lm",
        models: tuple[str, ...] = DEFAULT_MODELS,
        scenarios: tuple[str, ...] = DEFAULT_SCENARIOS,
        mode: str = "miredo", slack: float = 0.25,
        screen_samples: int = 64, no_screen: bool = False,
        rank_by: str = "latency",
        workers: int | None = None) -> dict:
    quick = quick or reduced
    bounds = None
    if workload == "lm":
        layers, counts, bounds = lm_workload(models, scenarios, reduced)
        wl_name = f"lm[{','.join(models)}|{','.join(scenarios)}" + \
            ("|reduced]" if reduced else "]")
    elif workload == "resnet18":
        layers = resnet18()
        counts = [RESNET18_MULTIPLICITY.get(l.name, 1) for l in layers]
        wl_name = "resnet18"
    else:
        raise ValueError(f"unknown workload {workload!r}")

    space = default_space()
    from repro.core.network import dedup_layers
    n_unique = len(dedup_layers(layers)[0])
    cap = min(QUICK_CAP_S, budget_s) if quick else budget_s
    total = QUICK_AVG_S * n_unique if quick else None
    print(f"[dse] workload {wl_name}: {len(layers)} layers, {n_unique} "
          f"unique; grid {space.size} archs, cap {cap:g}s/layer")

    serve = None
    if rank_by == "slo_goodput":
        # Traffic scenario behind the goodput objective: the same models
        # under a seeded Poisson stream (serve_sim's SLO regime); iteration
        # costs are cheap greedy anchors, so this adds seconds, not solves.
        from benchmarks.serve_sim import (CONTEXT_LEN,
                                          MEAN_INTERARRIVAL_CYCLES,
                                          SERVE_CFG)
        from repro.core.serving import ServeScenario
        serve = ServeScenario(
            model_ids=models if workload == "lm" else ("minicpm-2b",),
            reduced=reduced,
            mean_interarrival_cycles=MEAN_INTERARRIVAL_CYCLES,
            serve=SERVE_CFG, context_len=CONTEXT_LEN,
            per_layer_cap_s=cap)

    res = run_dse(layers, counts, space, mode,
                  screen=not no_screen, screen_slack=slack,
                  screen_samples=screen_samples,
                  per_layer_cap_s=cap, total_budget_s=total,
                  workers=workers, schedule_boundaries=bounds,
                  rank_by=rank_by, serve=serve,
                  verbose=True)

    frontier_names = {p.arch_name for p in res.frontier}
    rows = []
    for name, sp in res.screen_points.items():
        mp = res.points.get(name)
        rows.append([
            name,
            f"{sp.area_bits:,}",
            f"{sp.cycles:.3g}", f"{sp.energy_pj:.3g}",
            f"{mp.cycles:.3g}" if mp else "pruned",
            f"{mp.energy_pj:.3g}" if mp else "-",
            f"{mp.edp:.4g}" if mp else "-",
            ("FRONTIER" if name in frontier_names else
             ("" if mp else "pruned")),
        ])
    # "sched cyc" = the MIP pass's scheduled end-to-end latency (the
    # frontier objective); screening columns stay incumbent serial sums.
    print(md_table(["arch", "area bits", "screen cyc", "screen pJ",
                    "sched cyc", "MIP pJ", "MIP EDP", ""], rows))

    n_bad = sum(bool(v) for v in res.validation.values())
    print(f"[dse] pruned {len(res.pruned)}/{len(res.archs)} "
          f"({100 * res.prune_fraction:.0f}%), frontier "
          f"{len(res.frontier)} non-dominated archs, "
          f"{'ALL mappings valid' if n_bad == 0 else f'{n_bad} INVALID'}, "
          f"wall {res.wall_s:.0f}s")
    if n_bad:
        bad = {n: v for n, v in res.validation.items() if v}
        raise RuntimeError(f"invalid frontier mappings: {bad}")
    # --reduced is the CI acceptance path (dse-smoke): enforce the frontier
    # quality gates instead of warning, so regressions fail the job.
    if reduced and not no_screen and res.prune_fraction < 0.5:
        raise RuntimeError(
            f"screening pruned only {100 * res.prune_fraction:.0f}% "
            f"of the grid (acceptance: >=50%)")
    if reduced and len(res.frontier) < 3:
        raise RuntimeError(
            f"degenerate frontier: {len(res.frontier)} archs "
            f"(acceptance: >=3 non-dominated)")
    if res.prune_fraction < 0.5:
        print("[dse] WARNING: screening pruned <50% of the grid")
    if len(res.frontier) < 3:
        print("[dse] WARNING: degenerate frontier (<3 archs)")

    payload = {
        "workload": wl_name, "mode": mode,
        "grid": len(res.archs), "survivors": len(res.survivors),
        "pruned": len(res.pruned), "prune_fraction": res.prune_fraction,
        "frontier": [
            {"arch": p.arch_name, "cycles": p.cycles,
             "serial_cycles": p.serial_cycles,
             "energy_pj": p.energy_pj, "area_bits": p.area_bits,
             "edp": p.edp, "valid": not res.validation.get(p.arch_name)}
            for p in res.frontier],
        "frontier_validated": n_bad == 0,
        "points": {n: {"cycles": p.cycles,
                       "serial_cycles": p.serial_cycles,
                       "energy_pj": p.energy_pj,
                       "area_bits": p.area_bits, "edp": p.edp}
                   for n, p in res.points.items()},
        "screen": {n: {"cycles": p.cycles, "energy_pj": p.energy_pj}
                   for n, p in res.screen_points.items()},
        "wall_s": res.wall_s,
        "rank_by": rank_by,
    }
    if rank_by == "slo_goodput":
        pts = res.points
        latency_order = sorted(pts, key=lambda n: (pts[n].cycles, n))
        goodput_order = sorted(
            pts, key=lambda n: (-(pts[n].goodput_tok_s or 0.0), n))
        payload["goodput"] = {
            "latency_order": latency_order,
            "goodput_order": goodput_order,
            "orderings_differ": latency_order != goodput_order,
            "latency_frontier": [p.arch_name
                                 for p in res.frontier_by("latency")],
            "goodput_tok_s": {n: pts[n].goodput_tok_s for n in pts},
        }
        print(f"[dse] goodput ranking "
              f"{'differs from' if latency_order != goodput_order else 'coincides with'}"
              f" latency ranking "
              f"(goodput frontier {[p.arch_name for p in res.frontier]})")
    write_report("dse_pareto", payload)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="quick solver caps (implied by --reduced)")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU smoke-test reductions of the LM configs "
                         "+ quick caps")
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer MIP cap (seconds; quick mode clamps)")
    ap.add_argument("--workload", default="lm",
                    choices=("lm", "resnet18"))
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS),
                    help="comma list of registry arch ids (lm workload)")
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS),
                    help="comma list of ShapeSpec names (lm workload)")
    ap.add_argument("--mode", default="miredo")
    ap.add_argument("--slack", type=float, default=0.25,
                    help="screening prune slack (see DESIGN.md)")
    ap.add_argument("--screen-samples", type=int, default=64)
    ap.add_argument("--no-screen", action="store_true",
                    help="exhaustive MIP over the whole grid (no pruning)")
    ap.add_argument("--rank-by", default="latency",
                    choices=("latency", "slo_goodput"),
                    help="frontier objective: scheduled single-pass "
                         "latency, or sustained tokens/sec under SLO from "
                         "the request-level serving simulator "
                         "(core/serving.py)")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args(argv)
    run(budget_s=args.budget, quick=args.quick, reduced=args.reduced,
        workload=args.workload,
        models=tuple(m for m in args.models.split(",") if m),
        scenarios=tuple(s for s in args.scenarios.split(",") if s),
        mode=args.mode, slack=args.slack,
        screen_samples=args.screen_samples, no_screen=args.no_screen,
        rank_by=args.rank_by, workers=args.workers)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
