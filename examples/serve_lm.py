"""Serving example: prefill + batched autoregressive decode with KV caches
(reduced glm4-9b config on CPU; the same step functions the dry-run lowers
for the production mesh), then the CIM side of the same question: the model
frontend (core/frontend.py) lowers this exact serving config to its
weight-GEMM workload, MIREDO reports the optimized dataflow mapping, and
the measured-execution backend (core/executor.py) actually *runs* the
served decode step's optimized plan on the Pallas kernels — every kernel
checked against its ref.py oracle, wall-clock vs predicted cycles.

    PYTHONPATH=src python examples/serve_lm.py

``--traffic`` skips the single-step demo and instead serves a seeded
Poisson request stream through the request-level simulator
(core/serving.py): continuous batching vs the serial baseline, with
iteration costs anchored on this config's own scheduled solves.

    PYTHONPATH=src python examples/serve_lm.py --traffic
"""

import argparse
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--traffic", action="store_true",
                    help="traffic-driven mode: serve a Poisson request "
                         "stream through the continuous-batching "
                         "simulator instead of the single-step demo")
    ap.add_argument("--n-requests", type=int, default=16,
                    help="stream length for --traffic")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.traffic:
        traffic_demo(n_requests=args.n_requests, seed=args.seed)
    else:
        decode_demo()
    print("OK")


def decode_demo():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.train.steps import (StepConfig, init_train_state,
                                   make_decode_step, make_prefill_step)

    cfg = get_config("glm4-9b").reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    params = state.params
    batch, prompt_len, gen_len = 4, 12, 20
    # The KV cache needs exactly prompt + generated positions: the decode
    # step appends one token per call via a one-hot(length) scatter, which
    # silently drops any write past the padded length — so an undersized
    # max_seq truncates the cache while the token loop keeps "working".
    max_seq = prompt_len + gen_len

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, step_cfg))
    decode = jax.jit(make_decode_step(cfg, step_cfg))

    t0 = time.monotonic()
    logits, caches = prefill(params, {"tokens": prompt})
    # pad caches to max_seq so decode can append
    def pad(t):
        if t.ndim == 5 and t.shape[2] == prompt_len:
            return jnp.pad(t, [(0, 0), (0, 0),
                               (0, max_seq - prompt_len), (0, 0), (0, 0)])
        return t
    caches = jax.tree.map(pad, caches)
    print(f"prefill {batch}x{prompt_len}: {time.monotonic()-t0:.2f}s")

    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    t0 = time.monotonic()
    for _ in range(gen_len):
        logits, caches = decode(params, {"tokens": toks[-1]}, caches)
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    dt = time.monotonic() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decoded {gen_len} tokens/seq x {batch} seqs in {dt:.2f}s "
          f"({batch*gen_len/dt:.1f} tok/s on CPU)")
    print("sample token ids:", np.asarray(out[0])[:12], "...")
    assert out.shape == (batch, gen_len + 1)
    assert np.all(np.asarray(out) >= 0)
    # The decode loop must never have written past max_seq: the final
    # cache length is exactly every prompt + generated token, and the last
    # written position is in bounds (a dropped scatter would leave it 0).
    final_len = int(np.max(np.asarray(caches.length)))
    assert final_len == prompt_len + gen_len <= max_seq, \
        f"cache length {final_len} != {prompt_len + gen_len}"
    assert np.any(np.asarray(caches.k)[:, :, max_seq - 1] != 0), \
        "last decode wrote past the padded cache (write was dropped)"

    report_cim_dataflow(cfg, batch, context_len=max_seq)


def traffic_demo(n_requests: int = 16, seed: int = 0):
    """Serve a request stream against the same reduced config: iteration
    costs from the real stack, continuous batching vs serial baseline."""
    from repro.configs import get_config
    from repro.core.arch import default_arch
    from repro.core.serving import (NetworkCostModel, RequestStream,
                                    ServeConfig, serial_baseline,
                                    simulate_serving)

    cfg = get_config("glm4-9b").reduced()
    arch = default_arch()
    serve_cfg = ServeConfig(kv_capacity_tokens=512, max_batch_requests=16,
                            max_batch_tokens=128)
    cost = NetworkCostModel(cfg, arch, max_m=serve_cfg.max_batch_tokens,
                            context_len=256, mode="greedy")
    stream = RequestStream.poisson(n_requests, seed=seed,
                                   mean_interarrival_cycles=150_000.0)
    rep = simulate_serving(stream, cost, serve_cfg)
    ser = serial_baseline(stream, cost, serve_cfg)
    f = cost.freq_ghz
    s, ss = rep.summary(f), ser.summary(f)
    to_ms = 1.0 / (f * 1e6)
    print(f"served {n_requests} requests on {arch.name} "
          f"({cost.n_solves} anchor solves):")
    print(f"  TTFT p50/p99: {s['ttft_p50_cycles'] * to_ms:.3f} / "
          f"{s['ttft_p99_cycles'] * to_ms:.3f} ms   "
          f"ITL p50/p99: {s['itl_p50_cycles'] * to_ms:.3f} / "
          f"{s['itl_p99_cycles'] * to_ms:.3f} ms")
    print(f"  continuous batching: {s['tokens_per_sec']:.4g} tok/s "
          f"({int(s['n_merged_iterations'])} merged iterations) vs "
          f"serial {ss['tokens_per_sec']:.4g} tok/s")
    assert rep.makespan_cycles <= ser.makespan_cycles


def report_cim_dataflow(cfg, batch: int, budget_s: float = 2.0,
                        context_len: int = 64):
    """What dataflow should a CIM accelerator use for this serving config?

    Lowers the decode step of the served config to its weight-GEMM
    workload and runs the network pipeline (one MIP per unique GEMM,
    warm-started so the capped solves stay feasible)."""
    from repro.configs.base import ShapeSpec
    from repro.core.arch import default_arch
    from repro.core.frontend import extract_workload
    from repro.core.network import optimize_network

    arch = default_arch()
    # seq_len is the serving context: the decode GEMMs only see the batch
    # (m_tokens), but the executor's decode attention step attends a KV
    # cache of this length — seq_len=1 would make it a one-key softmax.
    spec = ShapeSpec("serve_decode", seq_len=context_len,
                     global_batch=batch, kind="decode")
    work = extract_workload(cfg, spec)
    # workers=1: this process already initialized JAX; forking a solver
    # pool after that risks deadlock, and the reduced config only has a
    # handful of unique solves anyway.
    net = optimize_network(list(work.layers), arch, "miredo",
                           counts=list(work.counts),
                           per_layer_cap_s=budget_s, workers=1)
    print(f"\nCIM dataflow for {cfg.name} decode (batch={batch}): "
          f"{len(work)} GEMMs, {net.n_unique} unique solves, "
          f"aggregate EDP {net.totals['edp']:.3e} "
          f"({net.totals['cycles']:.3g} cycles serial-sum)")
    s = net.scheduled
    print(f"multi-core schedule: {s['cycles']:.3g} cycles end-to-end "
          f"({s['serial_cycles'] / max(s['cycles'], 1.0):.2f}x vs serial, "
          f"{int(s['n_segments'])} segments, {int(s['n_packed'])} packed "
          f"weight-resident)")
    top = max(net.layers, key=lambda lr: lr.edp * lr.count)
    mp = top.record["mapping"]
    # GEMM-speak (M x K) @ (K x N): loop-nest N=M, C=K(reduction), K=N
    print(f"heaviest GEMM {top.layer.name} "
          f"(M={top.layer.bound('N')}, N={top.layer.bound('K')}, "
          f"K={top.layer.bound('C')}) x{top.count}:")
    print("  spatial :", mp["spatial"])
    print("  temporal:", mp["temporal"])
    print("  dbl-buf :", mp["double_buf"])

    # And actually RUN the served decode step's optimized plan on the
    # Pallas kernels (interpret mode): every GEMM on matmul_int8 with
    # mapping-derived blocks, the decode attention step on flash_attention
    # against the KV cache, each invocation checked against its ref.py.
    from repro.core.executor import execute_plan, lower_plan
    plan = lower_plan(cfg, spec, net, arch)
    rep = execute_plan(plan)
    rank = f"{rep.rank_corr:.2f}" if rep.rank_corr is not None else "n/a"
    print(f"measured execution: {rep.n_unique} unique kernels "
          f"({rep.n_ops} ops), {rep.measured_total_s * 1e3:.1f} ms "
          f"wall-clock vs {net.totals['cycles']:.3g} predicted cycles, "
          f"rank corr {rank}, numerics "
          f"{'OK' if rep.numerics_ok else 'FAILED'} "
          f"(max rel err {rep.max_rel_err:.1e})")
    assert rep.numerics_ok, "kernel output diverged from its ref oracle"


if __name__ == "__main__":
    main()
