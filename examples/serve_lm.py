"""Serving example: prefill + batched autoregressive decode with KV caches
(reduced glm4-9b config on CPU; the same step functions the dry-run lowers
for the production mesh).

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.train.steps import (StepConfig, init_train_state,
                               make_decode_step, make_prefill_step)


def main():
    cfg = get_config("glm4-9b").reduced()
    step_cfg = StepConfig(remat=False, compute_dtype=jnp.float32)
    state = init_train_state(jax.random.PRNGKey(0), cfg, step_cfg)
    params = state.params
    batch, prompt_len, gen_len, max_seq = 4, 12, 20, 64

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(make_prefill_step(cfg, step_cfg))
    decode = jax.jit(make_decode_step(cfg, step_cfg))

    t0 = time.monotonic()
    logits, caches = prefill(params, {"tokens": prompt})
    # pad caches to max_seq so decode can append
    def pad(t):
        if t.ndim == 5 and t.shape[2] == prompt_len:
            return jnp.pad(t, [(0, 0), (0, 0),
                               (0, max_seq - prompt_len), (0, 0), (0, 0)])
        return t
    caches = jax.tree.map(pad, caches)
    print(f"prefill {batch}x{prompt_len}: {time.monotonic()-t0:.2f}s")

    toks = [jnp.argmax(logits, -1).astype(jnp.int32)[:, None]]
    t0 = time.monotonic()
    for _ in range(gen_len):
        logits, caches = decode(params, {"tokens": toks[-1]}, caches)
        toks.append(jnp.argmax(logits, -1).astype(jnp.int32)[:, None])
    dt = time.monotonic() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decoded {gen_len} tokens/seq x {batch} seqs in {dt:.2f}s "
          f"({batch*gen_len/dt:.1f} tok/s on CPU)")
    print("sample token ids:", np.asarray(out[0])[:12], "...")
    assert out.shape == (batch, gen_len + 1)
    assert np.all(np.asarray(out) >= 0)
    print("OK")


if __name__ == "__main__":
    main()
