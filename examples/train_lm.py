"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack (sharded init, deterministic data pipeline,
checkpoint/restart, WSD schedule) — on CPU with a width-reduced config.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Loss must drop substantially (the synthetic stream is a learnable Markov
process); the script asserts it and demonstrates a mid-run restart from
the checkpoint.

``--dataflow`` asks the CIM side of the same question serve_lm.py asks
for decode: the exact training config that just ran is lowered through
``optimize_training(kind="train")`` — forward + dGrad/wGrad GEMMs plus
the once-per-step optimizer bill — and the optimized forward/backward
mappings are printed side by side, with the lowered token and parameter
counts asserted against the live model.

    PYTHONPATH=src python examples/train_lm.py --dataflow
"""

import argparse
import dataclasses
import shutil
import tempfile

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--dataflow", action="store_true",
                    help="short training run + MIREDO-optimized "
                         "fwd/dGrad/wGrad dataflow report for this exact "
                         "training config")
    args = ap.parse_args()
    if args.dataflow:
        dataflow_demo(args)
        print("OK: training dataflow report matches the live model.")
        return
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # Phase 1: train to 60% of steps, checkpointing.
        mid = int(args.steps * 0.6)
        losses1 = train_main([
            "--arch", "minicpm-2b", "--reduced",
            "--steps", str(mid), "--batch", str(args.batch),
            "--seq", str(args.seq), "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "50",
        ])
        print(f"\n--- simulating failure + restart from {ckpt_dir} ---\n")
        # Phase 2: 'restart' — resumes from the latest checkpoint.
        losses2 = train_main([
            "--arch", "minicpm-2b", "--reduced",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "50",
        ])
        first = sum(losses1[:10]) / 10
        last = sum(losses2[-10:]) / 10
        print(f"\nloss {first:.3f} -> {last:.3f}")
        assert last < first * 0.7, "training did not converge"
        print("OK: loss decreased through a checkpoint restart.")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


def dataflow_demo(args, budget_s: float = 2.0):
    """Train briefly, then report the MIREDO-optimized training dataflow
    for this exact config (mirroring serve_lm.report_cim_dataflow).

    The lowered workload is cross-checked against the live model: the LM
    head's training GEMMs must carry exactly the tokens of one step, and
    the optimizer bill must cover exactly the live trainable matmul
    parameters (the '/w' kernels plus the tied embedding table)."""
    import jax
    import jax.numpy as jnp

    from repro.configs.base import ShapeSpec
    from repro.core.arch import default_arch
    from repro.core.training import (backward_dataflow_diffs, optimize_training,
                                     phase_of)
    from repro.train.steps import StepConfig, init_train_state

    # A short real training run of the same (arch, seq, batch) config.
    steps = max(10, min(args.steps, 40))
    losses = train_main([
        "--arch", "minicpm-2b", "--reduced", "--steps", str(steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
    ])
    assert len(losses) == steps

    cfg = get_config("minicpm-2b").reduced()
    spec = ShapeSpec("train_demo", args.seq, args.batch, kind="train")
    # workers=1: this process already initialized JAX; forking a solver
    # pool after that risks deadlock (see serve_lm.report_cim_dataflow).
    res = optimize_training(cfg, spec, default_arch(),
                            per_layer_cap_s=budget_s, workers=1)
    net, update = res.net, res.update

    # --- lowered-vs-live cross-checks -----------------------------------
    # Tokens: the training LM head computes logits at every position, so
    # its forward GEMM carries M = seq at count = batch — one step's
    # tokens exactly.
    (head,) = [lr for lr in net.layers
               if lr.layer.name == f"{cfg.name}.lm_head"
               and phase_of(lr.layer) == "fwd"]
    lowered_tokens = head.layer.bound("N") * head.count
    assert lowered_tokens == args.seq * args.batch, \
        (lowered_tokens, args.seq * args.batch)
    # Parameters: the optimizer bill must cover the live matmul kernels
    # (every '/w' leaf) plus the embedding table (tied LM head; stored
    # pre-padded to padded_vocab, matching the lowered head GEMM).
    params = init_train_state(jax.random.PRNGKey(0), cfg,
                              StepConfig(compute_dtype=jnp.float32)).params
    live = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        if name.endswith("/w") or ("embed" in name and "table" in name):
            live += leaf.size
    assert update.n_params == live, (update.n_params, live)

    # --- the report ------------------------------------------------------
    s = net.scheduled
    print(f"\nCIM training dataflow for {cfg.name} "
          f"(seq={args.seq}, batch={args.batch}): {len(net.layers)} GEMMs, "
          f"{net.n_unique} unique solves")
    print(f"cycle split: fwd {res.splits['fwd']:.3g} / "
          f"dgrad {res.splits['dgrad']:.3g} / "
          f"wgrad {res.splits['wgrad']:.3g}; optimizer update "
          f"{update.total_cycles:.3g} cycles over {update.n_params} params")
    print(f"multi-core schedule: {s['cycles']:.3g} cycles end-to-end "
          f"({s['serial_cycles'] / max(s['cycles'], 1.0):.2f}x vs serial); "
          f"one step = {res.step_cycles:.3g} cycles")
    # heaviest forward GEMM and its backward pair, side by side
    top = max((lr for lr in net.layers if phase_of(lr.layer) == "fwd"),
              key=lambda lr: lr.edp * lr.count)
    by_name = {lr.layer.name: lr for lr in net.layers}
    # GEMM-speak (M x K) @ (K x N): loop-nest N=M, C=K(reduction), K=N
    print(f"heaviest forward GEMM {top.layer.name} "
          f"(M={top.layer.bound('N')}, N={top.layer.bound('K')}, "
          f"K={top.layer.bound('C')}) x{top.count}:")
    for suffix in ("", ".dgrad", ".wgrad"):
        lr = by_name[top.layer.name + suffix]
        mp = lr.record["mapping"]
        print(f"  {suffix or '.fwd':7s} spatial {mp['spatial']} "
              f"temporal {mp['temporal']}")
    diffs = backward_dataflow_diffs(net)
    differing = [d["layer"] for d in diffs if d["differs"]]
    print(f"wGrad dataflow differs from forward on {len(differing)}/"
          f"{len(diffs)} layers: {differing}")


if __name__ == "__main__":
    main()
