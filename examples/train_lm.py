"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the full production stack (sharded init, deterministic data pipeline,
checkpoint/restart, WSD schedule) — on CPU with a width-reduced config.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Loss must drop substantially (the synthetic stream is a learnable Markov
process); the script asserts it and demonstrates a mid-run restart from
the checkpoint.
"""

import argparse
import dataclasses
import shutil
import tempfile

from repro.configs import get_config
from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        # Phase 1: train to 60% of steps, checkpointing.
        mid = int(args.steps * 0.6)
        losses1 = train_main([
            "--arch", "minicpm-2b", "--reduced",
            "--steps", str(mid), "--batch", str(args.batch),
            "--seq", str(args.seq), "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "50",
        ])
        print(f"\n--- simulating failure + restart from {ckpt_dir} ---\n")
        # Phase 2: 'restart' — resumes from the latest checkpoint.
        losses2 = train_main([
            "--arch", "minicpm-2b", "--reduced",
            "--steps", str(args.steps), "--batch", str(args.batch),
            "--seq", str(args.seq), "--ckpt-dir", ckpt_dir,
            "--ckpt-every", "50",
        ])
        first = sum(losses1[:10]) / 10
        last = sum(losses2[-10:]) / 10
        print(f"\nloss {first:.3f} -> {last:.3f}")
        assert last < first * 0.7, "training did not converge"
        print("OK: loss decreased through a checkpoint restart.")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
