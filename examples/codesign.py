"""Co-design example: which CIM accelerator should serve this LM config?

The serving question `examples/serve_lm.py` answers for ONE hand-picked
architecture — "what dataflow should a CIM accelerator use for this model's
decode step" — becomes a co-design question here: sweep an architecture
grid (`core/dse.py`), let cheap incumbent screening prune it, run
warm-started MIPs on the survivors, and pick from the Pareto frontier the
best-EDP arch that fits an area budget.

    PYTHONPATH=src python examples/codesign.py [--area-kbit 512]
"""

import argparse

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.core.dse import ArchSpace, run_dse
from repro.core.frontend import extract_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="glm4-9b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--area-kbit", type=float, default=512.0,
                    help="area budget in kilobits of CIM crossbar cells")
    ap.add_argument("--budget", type=float, default=2.0,
                    help="per-layer MIP cap (seconds)")
    args = ap.parse_args()

    # The same reduced serving config serve_lm.py runs on CPU.
    cfg = get_config(args.model).reduced()
    spec = ShapeSpec("serve_decode", seq_len=1, global_batch=args.batch,
                     kind="decode")
    work = extract_workload(cfg, spec)
    print(f"workload: {cfg.name} decode (batch={args.batch}) -> "
          f"{len(work)} weight GEMMs, {work.n_unique} unique")

    space = ArchSpace(macro=((64, 32), (128, 32), (256, 64)),
                      n_cores=(4, 8, 16), lbuf_kb=(16.0, 256.0))
    res = run_dse(list(work.layers), list(work.counts), space,
                  per_layer_cap_s=args.budget, verbose=True)

    print(f"\nPareto frontier ({len(res.frontier)} archs, "
          f"{100 * res.prune_fraction:.0f}% of the grid screened out):")
    for p in res.frontier:
        errs = res.validation.get(p.arch_name, [])
        print(f"  {p.arch_name:<42} area {p.area_bits / 1024:>6.0f} kbit  "
              f"{p.cycles:>10,.0f} cyc  {p.energy_pj:>12,.0f} pJ"
              f"{'  INVALID: ' + errs[0] if errs else ''}")

    budget_bits = args.area_kbit * 1024
    best = res.best_under_area(budget_bits)
    if best is None:
        print(f"\nno frontier arch fits {args.area_kbit:g} kbit")
        return
    net = res.networks[best.arch_name]
    print(f"\nbest EDP under {args.area_kbit:g} kbit: {best.arch_name}")
    print(f"  EDP {best.edp:.3e}  ({best.cycles:,.0f} cycles, "
          f"{best.energy_pj:,.0f} pJ, area {best.area_bits / 1024:.0f} kbit)")
    top = max(net.layers, key=lambda lr: lr.edp * lr.count)
    print(f"  heaviest GEMM {top.layer.name}: "
          f"spatial {top.record['mapping']['spatial']}")


if __name__ == "__main__":
    main()
