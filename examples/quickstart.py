"""Quickstart: optimize one conv layer's dataflow with MIREDO and compare
against the baselines. Runs in ~2 minutes on a laptop CPU.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import conv, default_arch
from repro.core.baselines import greedy_mapping, heuristic_search
from repro.core.energy import evaluate_edp
from repro.core.formulation import FormulationConfig, optimize_layer
from repro.core.latency import evaluate
from repro.core.simulator import simulate


def main():
    arch = default_arch()                 # the paper's Table IV accelerator
    layer = conv("resnet18.conv3_x", 1, 128, 128, 28, 28, 3, 3)
    print(f"workload: {layer.name}  MACs={layer.macs:,}")

    greedy = greedy_mapping(layer, arch)
    g = evaluate_edp(greedy, layer, arch)
    print(f"\n[greedy]     {g.cycles:>12,.0f} cycles  EDP {g.edp:.3e}")

    heur = heuristic_search(layer, arch, budget=1500, seed=0)
    h = evaluate_edp(heur.mapping, layer, arch)
    print(f"[zigzag-like]{h.cycles:>12,.0f} cycles  EDP {h.edp:.3e} "
          f"(idealized model picked {heur.chosen_by_cost:,.0f})")

    res = optimize_layer(layer, arch, FormulationConfig(time_limit_s=90))
    m = evaluate_edp(res.mapping, layer, arch)
    print(f"[MIREDO]     {m.cycles:>12,.0f} cycles  EDP {m.edp:.3e} "
          f"({res.status.name}, {res.solve_seconds:.0f}s, "
          f"{res.n_vars} vars)")
    print(f"\nspeedup vs heuristic: {h.cycles / m.cycles:.2f}x   "
          f"EDP reduction: {h.edp / m.edp:.2f}x")

    print("\noptimal dataflow:")
    print("  spatial :", dict(res.mapping.spatial))
    print("  temporal:", res.mapping.temporal)
    print("  levels  :", res.mapping.level_of)
    print("  dbl-buf :", sorted(res.mapping.double_buf))

    sim = simulate(res.mapping, layer, arch)
    acc = 1 - abs(sim.total_cycles - m.cycles) / sim.total_cycles
    print(f"\nevent-simulator check: {sim.total_cycles:,.0f} cycles "
          f"(analytical model accuracy {acc:.1%})")


if __name__ == "__main__":
    main()
