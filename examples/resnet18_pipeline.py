"""Full-network pipeline: optimize every ResNet-18 layer (the paper's
baseline workload, §V-A) end to end and report network-level latency/EDP
against the ZigZag-style heuristic and the WS dataflow.

    PYTHONPATH=src python examples/resnet18_pipeline.py [--budget 45]
"""

import argparse

from benchmarks.common import solve_cached
from repro.core.arch import default_arch
from repro.core.workload import RESNET18_MULTIPLICITY, resnet18


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=45.0)
    args = ap.parse_args()
    arch = default_arch()
    totals = {m: {"cycles": 0.0, "edp": 0.0}
              for m in ("heuristic", "ws", "miredo")}
    print(f"{'layer':<12} {'heuristic':>12} {'WS':>12} {'MIREDO':>12} "
          f"{'speedup':>8}")
    for layer in resnet18():
        mult = RESNET18_MULTIPLICITY.get(layer.name, 1)
        recs = {m: solve_cached(layer, arch, m, budget_s=args.budget)
                for m in totals}
        for m in totals:
            totals[m]["cycles"] += recs[m]["cycles"] * mult
            totals[m]["edp"] += recs[m]["edp"] * mult
        print(f"{layer.name:<12} {recs['heuristic']['cycles']:>12,.0f} "
              f"{recs['ws']['cycles']:>12,.0f} "
              f"{recs['miredo']['cycles']:>12,.0f} "
              f"{recs['heuristic']['cycles']/recs['miredo']['cycles']:>7.2f}x")
    print("-" * 60)
    print(f"network latency: heuristic {totals['heuristic']['cycles']:,.0f} "
          f"| WS {totals['ws']['cycles']:,.0f} "
          f"| MIREDO {totals['miredo']['cycles']:,.0f}")
    print(f"network EDP reduction vs heuristic: "
          f"{totals['heuristic']['edp']/totals['miredo']['edp']:.2f}x, "
          f"vs WS: {totals['ws']['edp']/totals['miredo']['edp']:.2f}x")


if __name__ == "__main__":
    main()
