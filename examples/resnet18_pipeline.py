"""Full-network pipeline: optimize every ResNet-18 layer (the paper's
baseline workload, §V-A) end to end via the network-level pipeline
(core/network.py) and report network latency/EDP against the ZigZag-style
heuristic and the WS dataflow.

The pipeline dedups structurally identical layers, splits a global
MAC-weighted solver budget across the unique ones, fans the MIP solves out
over worker processes and caches every record on disk.

    PYTHONPATH=src python examples/resnet18_pipeline.py [--budget 45]
"""

import argparse

from repro.core.arch import default_arch
from repro.core.network import optimize_network
from repro.core.workload import RESNET18_MULTIPLICITY, resnet18


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=45.0,
                    help="per-layer solver cap (s); the global budget "
                         "defaults to half of cap * unique layers")
    ap.add_argument("--total-budget", type=float, default=None,
                    help="explicit global MIP wall-clock budget (s)")
    ap.add_argument("--workers", type=int, default=None)
    args = ap.parse_args()
    arch = default_arch()
    layers = resnet18()
    counts = [RESNET18_MULTIPLICITY.get(l.name, 1) for l in layers]
    nets = {}
    for mode in ("heuristic", "ws", "miredo"):
        nets[mode] = optimize_network(
            layers, arch, mode, counts=counts,
            per_layer_cap_s=args.budget, total_budget_s=args.total_budget,
            workers=args.workers)
    print(f"{'layer':<12} {'heuristic':>12} {'WS':>12} {'MIREDO':>12} "
          f"{'speedup':>8}")
    for i, layer in enumerate(layers):
        recs = {m: nets[m].layers[i].record for m in nets}
        print(f"{layer.name:<12} {recs['heuristic']['cycles']:>12,.0f} "
              f"{recs['ws']['cycles']:>12,.0f} "
              f"{recs['miredo']['cycles']:>12,.0f} "
              f"{recs['heuristic']['cycles']/recs['miredo']['cycles']:>7.2f}x")
    print("-" * 60)
    t = {m: nets[m].totals for m in nets}
    print(f"network latency: heuristic {t['heuristic']['cycles']:,.0f} "
          f"| WS {t['ws']['cycles']:,.0f} "
          f"| MIREDO {t['miredo']['cycles']:,.0f}")
    print(f"network EDP reduction vs heuristic: "
          f"{t['heuristic']['edp']/t['miredo']['edp']:.2f}x, "
          f"vs WS: {t['ws']['edp']/t['miredo']['edp']:.2f}x")
    mn = nets["miredo"]
    print(f"pipeline: {mn.n_unique} unique layers "
          f"({len(mn.layers)} instances), {mn.cache_hits} cache hits, "
          f"MIP wall {mn.wall_s:.0f}s")


if __name__ == "__main__":
    main()
